//! ML-library agnosticism (RQ2) at the API level: the identical job runs
//! over every model backend the manifest declares — the coordinator never
//! names a model family, exactly as FLsim never names torch/tf/sklearn.
//!
//! ```bash
//! cargo run --release --example library_agnostic
//! ```

use anyhow::Result;

use flsim::metrics::dashboard;
use flsim::prelude::*;

fn main() -> Result<()> {
    flsim::util::logging::init_from_env();
    let rt = Runtime::shared("artifacts")?;

    // Discover backends from the manifest — no hardcoded model list.
    let backends: Vec<String> = rt.manifest.backends.keys().cloned().collect();
    println!("manifest declares backends: {backends:?}");

    let orch = Orchestrator::new(rt.clone());
    let mut reports = Vec::new();
    for backend in &backends {
        let mut job = JobConfig::default_cnn("fedavg");
        job.name = backend.clone();
        job.backend = backend.clone();
        job.rounds = 3;
        job.dataset.n = 1200;
        if backend == "logreg" {
            // The MNIST-shaped backend needs the MNIST-shaped dataset.
            job.dataset = DatasetSpec::mnist_iid(1200);
            job.train.learning_rate = 0.05;
        }
        let report = orch.run(&job, RunOptions::default())?;
        println!("{}", dashboard::run_line(&report));
        reports.push(report);
    }

    println!();
    println!(
        "{}",
        dashboard::comparison("one job config, every backend", &reports)
    );
    assert_eq!(reports.len(), backends.len());
    Ok(())
}
