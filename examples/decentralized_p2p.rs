//! Decentralized (Fedstellar-style) federated learning on a full mesh and
//! on a ring, with fault injection: one peer goes down mid-training and the
//! Logic Controller's timeout arm keeps the experiment alive (Algorithm 1).
//!
//! ```bash
//! cargo run --release --example decentralized_p2p
//! ```

use anyhow::Result;

use flsim::controller::sync::FaultPlan;
use flsim::metrics::dashboard;
use flsim::prelude::*;

fn main() -> Result<()> {
    flsim::util::logging::init_from_env();
    let rt = Runtime::shared("artifacts")?;
    let orch = Orchestrator::new(rt);

    // Full mesh.
    let mut mesh = JobConfig::default_cnn("fedstellar");
    mesh.name = "p2p_mesh".into();
    mesh.rounds = 6;
    mesh.dataset.n = 1500;
    mesh.n_clients = 6;
    let mesh_report = orch.run(&mesh, RunOptions::default())?;
    println!("{}", dashboard::run_line(&mesh_report));

    // Ring topology, fewer exchanges per round.
    let mut ring = mesh.clone();
    ring.name = "p2p_ring".into();
    ring.topology = TopologyKind::Ring;
    let ring_report = orch.run(&ring, RunOptions::default())?;
    println!("{}", dashboard::run_line(&ring_report));

    // The mesh gossips O(n²) models per round, the ring O(n) — the mesh
    // must cost strictly more bandwidth (paper Fig 11e's shape).
    assert!(
        mesh_report.total_net_bytes() > ring_report.total_net_bytes(),
        "mesh should out-traffic the ring"
    );
    println!(
        "bandwidth: mesh {} KiB > ring {} KiB ✓",
        mesh_report.total_net_bytes() / 1024,
        ring_report.total_net_bytes() / 1024
    );

    // Fault injection: peer_2 drops in round 3, crashes for good at 5.
    let faults = FaultPlan::none()
        .drop_in_round("peer_2", 3)
        .crash_from("peer_2", 5);
    let mut faulty = mesh.clone();
    faulty.name = "p2p_mesh_faulty".into();
    let faulty_report = orch.run(&faulty, RunOptions::default().faults(faults))?;
    println!("{}", dashboard::run_line(&faulty_report));
    assert_eq!(faulty_report.rounds.len() as u64, faulty.rounds);
    println!("fault-tolerant run completed all rounds despite peer_2 failures ✓");
    Ok(())
}
