//! Implementing a *custom* FL strategy against the public API — the paper's
//! core modularity pitch (define train/aggregate, the framework does the
//! rest). Here: "FedTrimmed", FedAvg clients + a trimmed-mean robust
//! aggregator, wired into the standard orchestrated flow without touching
//! framework code.
//!
//! ```bash
//! cargo run --release --example custom_strategy
//! ```

use anyhow::Result;

use flsim::aggregate::mean::AggPlan;
use flsim::aggregate::robust::trimmed_mean;
use flsim::controller::sync::FaultPlan;
use flsim::metrics::dashboard;
use flsim::orchestrator::JobState;
use flsim::prelude::*;
use flsim::strategy::{ClientCtx, ClientUpdate, Strategy};
use flsim::util::rng::Rng as FlRng;

/// The user-defined strategy: standard local SGD + trimmed-mean aggregation.
struct FedTrimmed {
    trim: usize,
}

impl Strategy for FedTrimmed {
    fn name(&self) -> &'static str {
        "fedtrimmed"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let lr = ctx.lr;
        let start = ctx.global.to_vec();
        let (params, mean_loss) = ctx.run_epochs(&start, |b, p, x, y| b.sgd(p, x, y, lr))?;
        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params: params.into(),
            weight: ctx.n_examples as f64,
            extra: None,
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        _global: &[f32],
        _plan: AggPlan,
        _rng: &mut FlRng,
    ) -> Result<Vec<f32>> {
        let refs: Vec<&[f32]> = updates.iter().map(|u| u.params.as_ref()).collect();
        trimmed_mean(&refs, self.trim)
    }
}

fn main() -> Result<()> {
    flsim::util::logging::init_from_env();

    let mut job = JobConfig::default_cnn("fedavg");
    job.name = "custom_fedtrimmed".into();
    job.rounds = 6;
    job.dataset.n = 1500;

    let rt = Runtime::shared("artifacts")?;

    // Scaffold the job state through the public API, then swap in the
    // user strategy — the "plug your own algorithm" workflow.
    let mut state = JobState::scaffold(rt, &job, FaultPlan::none())?;
    state.strategy = Box::new(FedTrimmed { trim: 2 });

    let mut report = state.report.clone();
    for round in 1..=job.rounds {
        let metrics = flsim::orchestrator::run_standard_round(&mut state, round)?;
        println!(
            "round {:>2}: accuracy {:.4} loss {:.4}",
            round, metrics.test_accuracy, metrics.test_loss
        );
        report.rounds.push(metrics);
    }
    println!("{}", dashboard::run_line(&report));
    // Trimmed-mean discards 4/10 updates per round, so it learns slower
    // than dense FedAvg — require steady progress, not a fixed bar.
    assert!(
        report.final_accuracy() > report.rounds[0].test_accuracy
            && report.final_loss() < report.rounds[0].test_loss,
        "custom strategy failed to learn"
    );
    Ok(())
}
