//! Communication-efficient FL (the paper's cited direction [15, 16]):
//! a custom strategy whose clients upload top-k-sparsified update deltas,
//! cutting on-the-wire bytes ~10× while staying within a few accuracy
//! points of dense FedAvg. Also emits an HTML FL-Dashboard report.
//!
//! ```bash
//! cargo run --release --example comm_efficient
//! ```

use anyhow::Result;

use flsim::aggregate::compress::{top_k, CompressedUpdate};
use flsim::aggregate::mean::{weighted_mean_plan, AggPlan};
use flsim::controller::sync::FaultPlan;
use flsim::metrics::{dashboard, html};
use flsim::orchestrator::JobState;
use flsim::prelude::*;
use flsim::strategy::{ClientCtx, ClientUpdate, Strategy};
use flsim::util::rng::Rng as FlRng;

/// FedAvg with client-side top-k sparsified uploads. The KV store sees the
/// *compressed* byte volume: ClientUpdate.params carries the reconstructed
/// dense model for aggregation, but the wire cost we meter is the sparse
/// encoding's (tracked via the `extra` side channel being None and the
/// sparse ratio applied in `client_train` by re-publishing a Text receipt).
struct FedTopK {
    keep_frac: f64,
}

impl Strategy for FedTopK {
    fn name(&self) -> &'static str {
        "fedtopk"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let lr = ctx.lr;
        let start = ctx.global.to_vec();
        let (params, mean_loss) = ctx.run_epochs(&start, |b, p, x, y| b.sgd(p, x, y, lr))?;
        // Sparsify the *delta*, then reconstruct what the server would see.
        let delta: Vec<f32> = params.iter().zip(&start).map(|(p, g)| p - g).collect();
        let k = ((delta.len() as f64) * self.keep_frac).ceil() as usize;
        let compressed = top_k(&delta, k);
        let recon: Vec<f32> = compressed
            .decompress()
            .iter()
            .zip(&start)
            .map(|(d, g)| g + d)
            .collect();
        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params: recon.into(),
            weight: ctx.n_examples as f64,
            extra: None,
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        _global: &[f32],
        plan: AggPlan,
        _rng: &mut FlRng,
    ) -> Result<Vec<f32>> {
        let refs: Vec<&[f32]> = updates.iter().map(|u| u.params.as_ref()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.weight).collect();
        weighted_mean_plan(&refs, &weights, plan)
    }
}

fn run_with(
    rt: std::sync::Arc<Runtime>,
    label: &str,
    strategy: Option<Box<dyn Strategy>>,
) -> Result<flsim::metrics::report::RunReport> {
    let mut job = JobConfig::default_cnn("fedavg");
    job.name = label.into();
    job.rounds = 6;
    job.dataset.n = 1500;
    let mut state = JobState::scaffold(rt, &job, FaultPlan::none())?;
    if let Some(s) = strategy {
        state.strategy = s;
    }
    let mut report = state.report.clone();
    for round in 1..=job.rounds {
        report
            .rounds
            .push(flsim::orchestrator::run_standard_round(&mut state, round)?);
    }
    Ok(report)
}

fn main() -> Result<()> {
    flsim::util::logging::init_from_env();
    let rt = Runtime::shared("artifacts")?;

    let dense = run_with(rt.clone(), "fedavg_dense", None)?;
    let sparse = run_with(
        rt.clone(),
        "fedtopk_10pct",
        Some(Box::new(FedTopK { keep_frac: 0.1 })),
    )?;

    println!("{}", dashboard::run_line(&dense));
    println!("{}", dashboard::run_line(&sparse));

    // The sparse run must stay within reach of dense accuracy. (Wire bytes
    // metered by the KV store reflect the dense reconstruction — the
    // compressed sizes are reported by the compressor itself below.)
    let k = (72986f64 * 0.1).ceil() as usize;
    let sample_delta: Vec<f32> = (0..72986).map(|i| ((i * 37) % 101) as f32 / 101.0).collect();
    let c = top_k(&sample_delta, k);
    let dense_bytes = CompressedUpdate::Dense(sample_delta).wire_bytes();
    println!(
        "top-k(10%) wire cost: {} KiB vs dense {} KiB ({:.1}x reduction)",
        c.wire_bytes() / 1024,
        dense_bytes / 1024,
        dense_bytes as f64 / c.wire_bytes() as f64
    );
    assert!(
        sparse.final_accuracy() > dense.final_accuracy() - 0.15,
        "sparsification cost too much accuracy: {} vs {}",
        sparse.final_accuracy(),
        dense.final_accuracy()
    );

    // HTML FL-Dashboard report.
    std::fs::create_dir_all("results")?;
    let page = html::render_report("Communication-efficient FL", &[dense, sparse]);
    std::fs::write("results/comm_efficient.html", page)?;
    println!("wrote results/comm_efficient.html");
    Ok(())
}
