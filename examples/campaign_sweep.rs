//! Campaign engine demo: a 2×2 sweep (strategy × seed) built through the
//! declarative builder API, run twice against the content-addressed result
//! store — the second pass is all cache hits — then aggregated into one
//! campaign report. A final pass runs the same grid under the ASHA
//! scheduler: the bottom half of the cells is stopped at the first rung,
//! so the campaign spends strictly fewer rounds than the full grid.
//!
//! ```bash
//! cargo run --release --example campaign_sweep
//! ```

use anyhow::Result;

use flsim::metrics::dashboard;
use flsim::prelude::*;

fn main() -> Result<()> {
    flsim::util::logging::init_from_env();

    let mut base = JobConfig::default_cnn("fedavg");
    base.name = "sweep_base".into();
    base.rounds = 2;
    base.dataset.n = 600;
    base.n_clients = 4;

    let spec = CampaignSpec::builder("sweep_demo", base)
        .axis_strs("strategy", &["fedavg", "fedprox"])
        .axis_ints("seed", &[1, 2])
        .jobs(2) // two cells in flight; results are schedule-invariant
        .build();

    let store = ResultStore::open("campaigns/cache")?;
    let rt = Runtime::shared("artifacts")?;

    let first = flsim::campaign::run(rt.clone(), &spec, &store)?;
    println!("{}", first.summary());

    // An immediate re-run resumes every cell from the result store.
    let second = flsim::campaign::run(rt, &spec, &store)?;
    println!("{}", second.summary());
    assert!(second.all_cached(), "second pass must hit the result cache");

    let report = CampaignReport::from_outcome(&second);
    let (csv, json) = report.save("campaigns")?;
    println!("wrote {} and {}", csv.display(), json.display());

    println!();
    println!(
        "{}",
        dashboard::comparison("campaign sweep_demo", &second.reports())
    );

    // The same grid under ASHA: rung budgets 1, 2 — after every cell has
    // run one round, only the top half continues to the full two rounds.
    // (A fresh store: the grid cache above holds *complete* runs, which
    // would serve every rung and make this demo a no-op.)
    let asha_spec = CampaignSpec::builder("sweep_demo_asha", spec.base.clone())
        .axis_strs("strategy", &["fedavg", "fedprox"])
        .axis_ints("seed", &[1, 2])
        .jobs(2)
        .asha(2, 1)
        .build();
    let asha_store = ResultStore::open("campaigns/cache_asha")?;
    let rt = Runtime::shared("artifacts")?;
    let adaptive = flsim::campaign::run(rt, &asha_spec, &asha_store)?;
    println!();
    println!("{}", adaptive.summary());
    println!(
        "asha ran {} total rounds vs {} for the full grid",
        adaptive.total_rounds(),
        second.cells.len() as u64 * asha_spec.base.rounds
    );
    assert!(adaptive.total_rounds() < second.cells.len() as u64 * asha_spec.base.rounds);
    Ok(())
}
