//! Quickstart: the end-to-end driver (DESIGN.md §"End-to-end validation").
//!
//! Scaffolds the paper's standard setting — 10 clients, synthetic CIFAR-10,
//! Dirichlet(0.5), CNN backend, FedAvg — runs a full federated training job
//! through the AOT/PJRT pipeline, logs the per-round loss/accuracy curve,
//! and writes results/quickstart.csv.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;

use flsim::metrics::dashboard;
use flsim::prelude::*;

fn main() -> Result<()> {
    flsim::util::logging::init_from_env();

    let mut job = JobConfig::default_cnn("fedavg");
    job.name = "quickstart".into();
    job.rounds = std::env::var("FLSIM_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    job.dataset.n = std::env::var("FLSIM_DATASET_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2500);

    println!(
        "quickstart: {} clients, {} rounds, backend={}, strategy={}",
        job.n_clients,
        job.rounds,
        job.backend,
        job.strategy.name()
    );

    let rt = Runtime::shared("artifacts")?;
    let report = Orchestrator::new(rt).run(&job, RunOptions::default())?;

    println!();
    for r in &report.rounds {
        println!(
            "round {:>2}: accuracy {:.4}  loss {:.4}  train-loss {:.4}  \
             {:>6.2}s  sim {:>6.2}s  {:>7} KiB  hash {}",
            r.round,
            r.test_accuracy,
            r.test_loss,
            r.train_loss,
            r.wall_secs,
            r.sim_round_secs,
            r.net_bytes / 1024,
            r.model_hash,
        );
    }
    println!();
    println!("{}", dashboard::run_line(&report));

    std::fs::create_dir_all("results")?;
    report.save_csv("results/quickstart.csv")?;
    println!("wrote results/quickstart.csv");

    // The curve must actually learn — fail loudly if it does not.
    assert!(
        report.final_accuracy() > 0.3,
        "quickstart failed to learn (final accuracy {:.3})",
        report.final_accuracy()
    );
    Ok(())
}
