//! Heterogeneous-fleet straggler simulation on the virtual-clock fabric.
//!
//! Demonstrates the three fabric knobs this framework adds on top of the
//! paper's topology configs:
//!
//! * `network`  — per-edge-class link models (EDGE uplinks vs LAN tier),
//! * `heterogeneity` — deterministic per-client compute-speed spread,
//! * `round_deadline_secs` — drop clients whose *simulated*
//!   download + train + upload time overruns the deadline, through the
//!   Logic Controller's barrier timeout arm (Algorithm 1's straggler path,
//!   emergent rather than scripted via a FaultPlan).
//!
//! ```bash
//! cargo run --release --example heterogeneous_network
//! ```

use anyhow::Result;

use flsim::orchestrator::{run_standard_round, JobState};
use flsim::prelude::*;

fn main() -> Result<()> {
    flsim::util::logging::init_from_env();

    let mut job = JobConfig::default_cnn("fedavg");
    job.name = "heterogeneous_network".into();
    job.rounds = 4;
    job.dataset.n = 1200;
    // A slow last-mile uplink and a 2x compute spread across the fleet.
    job.network.edge = LinkModel {
        latency_ms: 120.0,
        bandwidth_mbps: 1.5,
    };
    job.heterogeneity = 1.0;

    // Pass 1: observe the fleet's virtual finish times (no deadline — the
    // clock is purely observational and results are bitwise-identical to a
    // run without any fabric config).
    let rt = Runtime::shared("artifacts")?;
    let mut state = JobState::scaffold(rt.clone(), &job, FaultPlan::none())?;
    let m = run_standard_round(&mut state, 1)?;
    println!(
        "round 1 virtual makespan: {:.2}s (on-wire {:.2}s)",
        m.sim_round_secs, m.sim_net_secs
    );
    let mut finishes: Vec<(String, f64)> = state
        .client_virtual_secs
        .iter()
        .map(|(k, v)| (k.clone(), *v))
        .collect();
    finishes.sort_by(|a, b| a.1.total_cmp(&b.1));
    for (name, secs) in &finishes {
        println!("  {name:<10} finishes at {secs:>6.2}s (virtual)");
    }

    // Pass 2: set a deadline that cuts off the slowest client; it trains
    // but its upload never lands — the barrier resolves without it.
    let slowest = finishes.last().expect("clients exist").clone();
    let runner_up = finishes[finishes.len() - 2].1;
    job.round_deadline_secs = Some((runner_up + slowest.1) / 2.0);
    println!(
        "\nsetting round_deadline_secs = {:.2} (drops {})",
        job.round_deadline_secs.unwrap(),
        slowest.0
    );
    let report = Orchestrator::new(rt).run(&job, RunOptions::default())?;
    for r in &report.rounds {
        println!(
            "round {}: accuracy {:.4}  makespan {:.2}s  hash {}",
            r.round, r.test_accuracy, r.sim_round_secs, r.model_hash
        );
    }
    println!(
        "straggler {} dropped each round; surviving quorum kept learning.",
        slowest.0
    );
    Ok(())
}
