//! Blockchain-aided FL (paper §2.4 / RQ4): multi-worker aggregation with
//! the consensus delegated to an on-chain smart contract, plus parameter
//! verification, provenance lineage and worker reputation — on both
//! simulated platforms (Ethereum-like and Fabric-like) to show the
//! pluggability of the chain API.
//!
//! ```bash
//! cargo run --release --example blockchain_fl
//! ```

use anyhow::Result;

use flsim::prelude::*;

fn run_on(platform: &str) -> Result<()> {
    println!("=== BCFL on {platform} ===");
    let mut job = JobConfig::default_cnn("fedavg");
    job.name = format!("bcfl_{platform}");
    job.rounds = 4;
    job.dataset.n = 1200;
    job.n_workers = 3;
    job.consensus.malicious_workers = vec!["worker_0".into()];
    job.consensus.on_chain = true;
    job.chain.enabled = true;
    job.chain.platform = platform.into();

    let rt = Runtime::shared("artifacts")?;
    let report = Orchestrator::new(rt).run(&job, RunOptions::default())?;

    for r in &report.rounds {
        println!(
            "round {:>2}: accuracy {:.4}  loss {:.4}  global-hash {}",
            r.round, r.test_accuracy, r.test_loss, r.model_hash
        );
    }
    // Poisoning must be nullified: 2 honest of 3 workers.
    let accs = report.accuracy_series();
    assert!(
        accs.last().unwrap() > accs.first().unwrap(),
        "{platform}: training did not progress under consensus"
    );
    println!("{platform}: on-chain consensus nullified the malicious worker\n");
    Ok(())
}

fn main() -> Result<()> {
    flsim::util::logging::init_from_env();
    run_on("ethereum")?;
    run_on("fabric")?;
    Ok(())
}
