"""L1 — Pallas tiled matmul kernels (the compute hot-spot of every FLsim model).

The dense layers of every model backend (CNN head, MLP hidden stack, logistic
regression) route through these kernels, so they sit on the hot path of every
AOT-compiled train step and eval function.

TPU mapping (see DESIGN.md §4): BlockSpec tiles are MXU-aligned (multiples of
128 on the N/K contraction axes); the grid walks (M/bm, N/bn, K/bk) with the
K axis innermost so each (i, j) output tile stays resident in VMEM across the
K loop (accumulate-in-place). VMEM footprint is bm*bk + bk*bn + bm*bn floats
(~192 KiB at 128³), far under the ~16 MiB VMEM budget, leaving room for
double-buffered prefetch of the next K tile.

`interpret=True` is mandatory here: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO so the same
artifact runs on any backend. Real-TPU efficiency is *estimated* in
EXPERIMENTS.md §Perf from the tile arithmetic intensity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile sizes. bm=256 (clamped to M, so train-batch calls use 64); bn/bk are
# 128-multiples so the systolic array is fully fed on TPU. Large bk/bn keep
# the interpret-mode grid short (each grid step costs a dynamic-slice loop
# iteration on CPU); VMEM at (64, 1024, 256) is ~1.4 MiB — well under budget.
DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 1024


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """o[i, j] = sum_k x[i, k] @ y[k, j], accumulated across the K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_bias_act_kernel(x_ref, y_ref, b_ref, o_ref, *, nk: int, act: str):
    """Fused o = act(x @ y + b): bias add + activation applied on the last K
    step, while the output tile is still resident in VMEM (saves one full
    HBM round-trip per layer versus a separate bias/act op)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        r = o_ref[...] + b_ref[...]
        if act == "relu":
            r = jnp.maximum(r, 0.0)
        elif act == "tanh":
            r = jnp.tanh(r)
        elif act != "linear":
            raise ValueError(f"unknown activation {act!r}")
        o_ref[...] = r


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _pick_tiles(m: int, k: int, n: int, bm: int, bk: int, bn: int):
    """Shrink default tiles for small operands (e.g. logreg 784x10) so the
    grid stays non-degenerate and padding waste is bounded."""
    bm = min(bm, max(8, m))
    bn = min(bn, max(16, n))
    bk = min(bk, max(16, k))
    return bm, bk, bn


def _matmul_impl(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
) -> jax.Array:
    """Tiled f32 matmul via Pallas. Pads to tile multiples, slices back."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm, bk, bn = _pick_tiles(m, k, n, bm, bk, bn)
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    yp = _pad_to(_pad_to(y, 0, bk), 1, bn)
    mp, kp = xp.shape
    _, np_ = yp.shape
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def _matmul_bias_act_impl(
    x: jax.Array,
    y: jax.Array,
    b: jax.Array,
    *,
    act: str = "relu",
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
) -> jax.Array:
    """Fused act(x @ y + b) via Pallas; the dense-layer hot path."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    bm, bk, bn = _pick_tiles(m, k, n, bm, bk, bn)
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    yp = _pad_to(_pad_to(y, 0, bk), 1, bn)
    bp = _pad_to(b[None, :], 1, bn)
    mp, kp = xp.shape
    _, np_ = yp.shape
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_bias_act_kernel, nk=nk, act=act),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp, bp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Differentiable wrappers. pallas_call with pl.when/program_id has no JVP
# rule, so autodiff is provided via custom_vjp where the *backward* pass is
# also built from Pallas matmuls — the kernel stays on the hot path of both
# the forward and backward HLO of every train step.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Differentiable tiled Pallas matmul (see _matmul_impl)."""
    return _matmul_impl(x, y)


def _matmul_fwd(x, y):
    return _matmul_impl(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # dX = g @ Y^T ; dY = X^T @ g — both tiled Pallas matmuls.
    return _matmul_impl(g, y.T), _matmul_impl(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul_bias_act(x: jax.Array, y: jax.Array, b: jax.Array, act: str = "relu"):
    """Differentiable fused act(x @ y + b) (see _matmul_bias_act_impl)."""
    return _matmul_bias_act_impl(x, y, b, act=act)


def _mba_fwd(x, y, b, act):
    out = _matmul_bias_act_impl(x, y, b, act=act)
    return out, (x, y, out)


def _mba_bwd(act, res, g):
    x, y, out = res
    if act == "relu":
        dpre = g * (out > 0.0).astype(g.dtype)
    elif act == "tanh":
        dpre = g * (1.0 - out * out)
    elif act == "linear":
        dpre = g
    else:
        raise ValueError(f"unknown activation {act!r}")
    dx = _matmul_impl(dpre, y.T)
    dy = _matmul_impl(x.T, dpre)
    db = jnp.sum(dpre, axis=0)
    return dx, dy, db


matmul_bias_act.defvjp(_mba_fwd, _mba_bwd)


def dense(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "relu") -> jax.Array:
    """Dense layer entry point used by the L2 models."""
    return matmul_bias_act(x, w, b, act)


def vmem_report(m: int, k: int, n: int, bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                bn: int = DEFAULT_BN) -> dict:
    """Static VMEM/MXU estimate for EXPERIMENTS.md §Perf (no TPU here)."""
    bm, bk, bn = _pick_tiles(m, k, n, bm, bk, bn)
    vmem_bytes = 4 * (bm * bk + bk * bn + bm * bn)
    flops_per_tile = 2 * bm * bk * bn
    hbm_bytes_per_tile = 4 * (bm * bk + bk * bn)  # out tile stays in VMEM
    return {
        "tiles": (bm, bk, bn),
        "grid": ((m + bm - 1) // bm, (n + bn - 1) // bn, (k + bk - 1) // bk),
        "vmem_bytes": vmem_bytes,
        "arithmetic_intensity_flops_per_byte": flops_per_tile / hbm_bytes_per_tile,
        "mxu_aligned": bn % 128 == 0 and bk % 128 == 0,
    }
