"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every Pallas kernel in this package must match its oracle here to within
float32 tolerance over a hypothesis-driven sweep of shapes (see
python/tests/test_kernels.py). The oracles are deliberately the most naive
possible jnp expressions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def matmul_bias_act_ref(
    x: jax.Array, y: jax.Array, b: jax.Array, act: str = "relu"
) -> jax.Array:
    r = jnp.dot(x, y, preferred_element_type=jnp.float32) + b[None, :]
    if act == "relu":
        return jnp.maximum(r, 0.0)
    if act == "tanh":
        return jnp.tanh(r)
    if act == "linear":
        return r
    raise ValueError(f"unknown activation {act!r}")


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "relu") -> jax.Array:
    return matmul_bias_act_ref(x, w, b, act)
