"""AOT compiler: lower every (backend × step) function to HLO *text* and emit
a manifest.json describing the artifact set for the Rust runtime.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Python runs ONCE at build time; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import models, steps

F32 = "f32"
S32 = "s32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_entry(shape, dtype):
    return {"shape": list(shape), "dtype": dtype}


class ArtifactSet:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {
            "format": 1,
            "generated_unix": int(time.time()),
            "jax_version": jax.__version__,
            "train_batch": steps.TRAIN_BATCH,
            "eval_batch": steps.EVAL_BATCH,
            "backends": {},
        }

    def add(self, backend: str, step_name: str, fn, arg_specs, input_desc,
            n_outputs: int):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{backend}_{step_name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        self.manifest["backends"].setdefault(backend, {"artifacts": {}})
        self.manifest["backends"][backend]["artifacts"][step_name] = {
            "file": fname,
            "inputs": input_desc,
            "n_outputs": n_outputs,
            "sha256_16": digest,
            "hlo_bytes": len(text),
        }
        print(f"  [{backend}/{step_name}] {len(text)/1024:.0f} KiB -> {fname}")

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"manifest -> {path}")


def build_backend(aset: ArtifactSet, name: str, use_pallas: bool = True,
                  full: bool = True):
    """Lower init/sgd/eval for every backend; the strategy-specific steps
    (prox/scaffold/moon) only for the cnn backend (the paper's Fig 8 model)."""
    backend = models.BACKENDS[name]
    p, _ = steps.flat_spec(backend)
    bt, be = steps.TRAIN_BATCH, steps.EVAL_BATCH
    ishape = backend.input_shape
    aset.manifest["backends"].setdefault(name, {"artifacts": {}})
    aset.manifest["backends"][name]["param_count"] = p
    aset.manifest["backends"][name]["input_shape"] = list(ishape)
    aset.manifest["backends"][name]["use_pallas"] = use_pallas

    flat = _spec((p,))
    xt = _spec((bt,) + ishape)
    yt = _spec((bt,), jnp.int32)
    xe = _spec((be,) + ishape)
    ye = _spec((be,), jnp.int32)
    me = _spec((be,))
    lr = _spec((), jnp.float32)

    aset.add(name, "init", steps.make_init(backend), [_spec((), jnp.int32)],
             [_shape_entry((), S32)], 1)
    aset.add(name, "sgd", steps.make_sgd_step(backend, use_pallas),
             [flat, xt, yt, lr],
             [_shape_entry((p,), F32), _shape_entry((bt,) + ishape, F32),
              _shape_entry((bt,), S32), _shape_entry((), F32)], 2)
    aset.add(name, "eval", steps.make_eval(backend, use_pallas),
             [flat, xe, ye, me],
             [_shape_entry((p,), F32), _shape_entry((be,) + ishape, F32),
              _shape_entry((be,), S32), _shape_entry((be,), F32)], 2)

    if full:
        mu = _spec((), jnp.float32)
        tau = _spec((), jnp.float32)
        aset.add(name, "prox", steps.make_prox_step(backend, use_pallas),
                 [flat, flat, xt, yt, lr, mu],
                 [_shape_entry((p,), F32), _shape_entry((p,), F32),
                  _shape_entry((bt,) + ishape, F32), _shape_entry((bt,), S32),
                  _shape_entry((), F32), _shape_entry((), F32)], 2)
        aset.add(name, "scaffold", steps.make_scaffold_step(backend, use_pallas),
                 [flat, flat, flat, xt, yt, lr],
                 [_shape_entry((p,), F32)] * 3 +
                 [_shape_entry((bt,) + ishape, F32), _shape_entry((bt,), S32),
                  _shape_entry((), F32)], 2)
        aset.add(name, "moon", steps.make_moon_step(backend, use_pallas),
                 [flat, flat, flat, xt, yt, lr, mu, tau],
                 [_shape_entry((p,), F32)] * 3 +
                 [_shape_entry((bt,) + ishape, F32), _shape_entry((bt,), S32),
                  _shape_entry((), F32), _shape_entry((), F32),
                  _shape_entry((), F32)], 2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--no-pallas", action="store_true",
                    help="ablation: use the pure-jnp dense path everywhere")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    use_pallas = not args.no_pallas

    t0 = time.time()
    aset = ArtifactSet(args.out_dir)
    # cnn gets the full strategy set (Fig 8); others need init/sgd/eval only.
    build_backend(aset, "cnn", use_pallas, full=True)
    build_backend(aset, "cnn_v2", use_pallas, full=False)
    build_backend(aset, "mlp", use_pallas, full=False)
    build_backend(aset, "logreg", use_pallas, full=False)
    aset.finish()
    print(f"AOT done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
