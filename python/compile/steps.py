"""L2 — AOT-able train/eval/init step functions over FLAT parameter vectors.

The Rust coordinator only ever sees `f32[P]` parameter vectors (plus batch
tensors and scalar hyper-parameters), which makes aggregation, consensus
hashing, poisoning, clipping and DP noising trivial on the Rust side. The
pytree structure lives entirely inside these jitted functions via
``ravel_pytree``'s unflattener, which is a static closure at lowering time.

Strategy coverage (paper Fig 8):
  sgd_step       — FedAvg [1], FedAvgM [2] (server momentum in Rust),
                   DP-FL [7] (clip+noise in Rust), FL+HC [26], Fedstellar [24]
  prox_step      — FedProx [3] style client regularization (extension)
  scaffold_step  — SCAFFOLD [5] batch step with control-variate correction
                   (c_local update after the local epoch is element-wise and
                   runs in Rust: ci' = ci - c + (w0 - wK)/(K*lr))
  moon_step      — MOON [4] model-contrastive step (needs global + previous
                   local representations)

Every function is lowered per-backend by aot.py with fixed shapes
(train batch 64, eval batch 256 — the paper's setting).
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from compile import models

TRAIN_BATCH = 64
EVAL_BATCH = 256


def flat_spec(backend: models.Backend) -> Tuple[int, Callable]:
    """(param_count, unravel_fn) for a backend."""
    params = backend.init(jax.random.PRNGKey(0))
    flat, unravel = ravel_pytree(params)
    return int(flat.shape[0]), unravel


def xent(logits: jax.Array, y: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def make_init(backend: models.Backend):
    def init(seed: jax.Array) -> Tuple[jax.Array]:
        key = jax.random.PRNGKey(seed)
        flat, _ = ravel_pytree(backend.init(key))
        return (flat,)

    return init


# ---------------------------------------------------------------------------
# plain SGD step
# ---------------------------------------------------------------------------

def make_sgd_step(backend: models.Backend, use_pallas: bool = True):
    _, unravel = flat_spec(backend)

    def loss_fn(flat, x, y):
        logits, _ = backend.apply(unravel(flat), x, use_pallas=use_pallas)
        return xent(logits, y)

    def step(flat, x, y, lr):
        loss, g = jax.value_and_grad(loss_fn)(flat, x, y)
        return flat - lr * g, loss

    return step


# ---------------------------------------------------------------------------
# FedProx client step: + (mu/2)||w - w_global||^2
# ---------------------------------------------------------------------------

def make_prox_step(backend: models.Backend, use_pallas: bool = True):
    _, unravel = flat_spec(backend)

    def loss_fn(flat, gflat, x, y, mu):
        logits, _ = backend.apply(unravel(flat), x, use_pallas=use_pallas)
        prox = 0.5 * mu * jnp.sum((flat - gflat) ** 2)
        return xent(logits, y) + prox

    def step(flat, gflat, x, y, lr, mu):
        loss, g = jax.value_and_grad(loss_fn)(flat, gflat, x, y, mu)
        return flat - lr * g, loss

    return step


# ---------------------------------------------------------------------------
# SCAFFOLD batch step: w <- w - lr * (g - c_local + c_global)
# ---------------------------------------------------------------------------

def make_scaffold_step(backend: models.Backend, use_pallas: bool = True):
    _, unravel = flat_spec(backend)

    def loss_fn(flat, x, y):
        logits, _ = backend.apply(unravel(flat), x, use_pallas=use_pallas)
        return xent(logits, y)

    def step(flat, c_global, c_local, x, y, lr):
        loss, g = jax.value_and_grad(loss_fn)(flat, x, y)
        return flat - lr * (g - c_local + c_global), loss

    return step


# ---------------------------------------------------------------------------
# MOON step: cross-entropy + mu * model-contrastive loss on representations.
# ---------------------------------------------------------------------------

def _cos(a, b, eps=1e-8):
    num = jnp.sum(a * b, axis=-1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + eps
    return num / den


def make_moon_step(backend: models.Backend, use_pallas: bool = True):
    _, unravel = flat_spec(backend)

    def loss_fn(flat, gflat, pflat, x, y, mu, tau):
        logits, z = backend.apply(unravel(flat), x, use_pallas=use_pallas)
        _, z_g = backend.apply(unravel(gflat), x, use_pallas=use_pallas)
        _, z_p = backend.apply(unravel(pflat), x, use_pallas=use_pallas)
        z_g = jax.lax.stop_gradient(z_g)
        z_p = jax.lax.stop_gradient(z_p)
        sim_g = _cos(z, z_g) / tau
        sim_p = _cos(z, z_p) / tau
        # -log( exp(sim_g) / (exp(sim_g) + exp(sim_p)) )
        con = jnp.mean(jnp.logaddexp(sim_g, sim_p) - sim_g)
        return xent(logits, y) + mu * con

    def step(flat, gflat, pflat, x, y, lr, mu, tau):
        loss, g = jax.value_and_grad(loss_fn)(flat, gflat, pflat, x, y, mu, tau)
        return flat - lr * g, loss

    return step


# ---------------------------------------------------------------------------
# eval: summed loss + correct count over a fixed-size padded batch. `mask`
# zeroes out padding rows so Rust can evaluate arbitrary test-set sizes.
# ---------------------------------------------------------------------------

def make_eval(backend: models.Backend, use_pallas: bool = True):
    def evaluate(flat, x, y, mask):
        logits, _ = backend.apply(_unravel_cache(backend)(flat), x,
                                  use_pallas=use_pallas)
        logp = jax.nn.log_softmax(logits, axis=-1)
        per = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        pred = jnp.argmax(logits, axis=-1)
        correct = jnp.sum((pred == y).astype(jnp.float32) * mask)
        loss_sum = jnp.sum(per * mask)
        return loss_sum, correct

    return evaluate


@functools.lru_cache(maxsize=None)
def _unravel_cache_key(name: str):
    backend = models.BACKENDS[name]
    _, unravel = flat_spec(backend)
    return unravel


def _unravel_cache(backend: models.Backend):
    return _unravel_cache_key(backend.name)
