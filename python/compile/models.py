"""L2 — model definitions for the FLsim backends.

Four model families, mirroring the paper's experiments (DESIGN.md §2):

  cnn     — 3 conv layers + 2-layer dense head  (paper's PyTorch CNN, Fig 8/9/10/11, Tab 1-2)
  cnn_v2  — same macro-architecture, tanh/avg-pool/wider head  (paper's TensorFlow CNN, Fig 9)
  mlp     — 4-hidden-layer MLP on flattened images  (paper's Scikit-Learn MLP, Fig 9)
  logreg  — logistic regression  (paper's MNIST scalability run, Fig 12)

Every dense layer routes through the Pallas kernel (kernels.matmul.dense) so
the L1 kernel sits on the hot path of every AOT artifact; set
``use_pallas=False`` to swap in the pure-jnp oracle (used by the pytest
equivalence suite and as an ablation artifact).

All models expose:
  init(key)        -> param pytree
  apply(p, x)      -> (logits, representation)   # representation feeds MOON
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels import matmul as pk
from compile.kernels import ref as kref

Params = dict


def _dense_fn(use_pallas: bool) -> Callable:
    return pk.dense if use_pallas else kref.dense_ref


# ---------------------------------------------------------------------------
# Initializers (explicitly seeded; the seed arrives as an artifact input so
# Rust controls all randomness — DESIGN.md §7).
# ---------------------------------------------------------------------------

def _prod(xs):
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _he(key, shape):
    fan_in = _prod(shape[:-1])
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def _glorot(key, shape):
    fan_in = _prod(shape[:-1])
    fan_out = shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


# ---------------------------------------------------------------------------
# CNN (paper's 3-conv + FC head, NHWC 32x32x3 -> 10)
# ---------------------------------------------------------------------------

# Three conv layers + FC head (the paper fixes the macro-architecture but not
# the widths; widths are sized for the single-core CPU testbed).
CNN_CHANNELS = (8, 16, 32)
CNN_HIDDEN = 128
IMG_SHAPE = (32, 32, 3)
NUM_CLASSES = 10


def _conv(x, w, b, stride=1):
    # 3x3 same conv, NHWC / HWIO. Downsampling is done with stride-2 convs
    # rather than pooling: XLA-CPU's select-and-scatter (maxpool backward) is
    # an order of magnitude slower than the conv itself on this 1-core box.
    y = lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b[None, None, None, :]


def _avgpool2(x):
    s = lax.reduce_window(x, 0.0, lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    return s / 4.0


def cnn_init(key) -> Params:
    ks = jax.random.split(key, 5)
    c1, c2, c3 = CNN_CHANNELS
    flat = 4 * 4 * c3
    return {
        "w1": _he(ks[0], (3, 3, 3, c1)), "b1": jnp.zeros((c1,)),
        "w2": _he(ks[1], (3, 3, c1, c2)), "b2": jnp.zeros((c2,)),
        "w3": _he(ks[2], (3, 3, c2, c3)), "b3": jnp.zeros((c3,)),
        "wh": _he(ks[3], (flat, CNN_HIDDEN)), "bh": jnp.zeros((CNN_HIDDEN,)),
        "wo": _he(ks[4], (CNN_HIDDEN, NUM_CLASSES)), "bo": jnp.zeros((NUM_CLASSES,)),
    }


def cnn_apply(p: Params, x: jax.Array, *, use_pallas: bool = True):
    dense = _dense_fn(use_pallas)
    h = jnp.maximum(_conv(x, p["w1"], p["b1"], 2), 0.0)
    h = jnp.maximum(_conv(h, p["w2"], p["b2"], 2), 0.0)
    h = jnp.maximum(_conv(h, p["w3"], p["b3"], 2), 0.0)
    h = h.reshape(h.shape[0], -1)
    z = dense(h, p["wh"], p["bh"], "relu")          # representation (MOON)
    logits = kref.dense_ref(z, p["wo"], p["bo"], "linear")
    return logits, z


# ---------------------------------------------------------------------------
# CNN v2 ("TensorFlow" backend): tanh conv stack, avg-pool, wider 2-layer head.
# Deliberately heavier so its wall-time profile differs (paper Fig 9c: the TF
# implementation is the slowest).
# ---------------------------------------------------------------------------

CNN2_HIDDEN = (256, 128)


def cnn_v2_init(key) -> Params:
    ks = jax.random.split(key, 6)
    c1, c2, c3 = CNN_CHANNELS
    flat = 4 * 4 * c3
    h1, h2 = CNN2_HIDDEN
    return {
        "w1": _glorot(ks[0], (3, 3, 3, c1)), "b1": jnp.zeros((c1,)),
        "w2": _glorot(ks[1], (3, 3, c1, c2)), "b2": jnp.zeros((c2,)),
        "w3": _glorot(ks[2], (3, 3, c2, c3)), "b3": jnp.zeros((c3,)),
        "wh1": _glorot(ks[3], (flat, h1)), "bh1": jnp.zeros((h1,)),
        "wh2": _glorot(ks[4], (h1, h2)), "bh2": jnp.zeros((h2,)),
        "wo": _glorot(ks[5], (h2, NUM_CLASSES)), "bo": jnp.zeros((NUM_CLASSES,)),
    }


def cnn_v2_apply(p: Params, x: jax.Array, *, use_pallas: bool = True):
    dense = _dense_fn(use_pallas)
    h = _avgpool2(jnp.tanh(_conv(x, p["w1"], p["b1"])))
    h = _avgpool2(jnp.tanh(_conv(h, p["w2"], p["b2"])))
    h = _avgpool2(jnp.tanh(_conv(h, p["w3"], p["b3"])))
    h = h.reshape(h.shape[0], -1)
    # (stride-1 conv stack + pooling makes this backend measurably slower
    # than `cnn`, mirroring the paper's TF-vs-torch wall-time gap in Fig 9c)
    h = dense(h, p["wh1"], p["bh1"], "tanh")
    z = dense(h, p["wh2"], p["bh2"], "tanh")
    logits = kref.dense_ref(z, p["wo"], p["bo"], "linear")
    return logits, z


# ---------------------------------------------------------------------------
# MLP ("Scikit-Learn" backend): 4 hidden layers over flattened 3072-d input.
# Largest parameter vector of the backends => highest communication cost
# (paper Fig 9e: sklearn MLP uses the most bandwidth).
# ---------------------------------------------------------------------------

MLP_HIDDEN = (256, 128, 64, 32)
MLP_IN = 32 * 32 * 3


def mlp_init(key) -> Params:
    dims = (MLP_IN,) + MLP_HIDDEN + (NUM_CLASSES,)
    ks = jax.random.split(key, len(dims) - 1)
    p = {}
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        p[f"w{i}"] = _he(ks[i], (din, dout))
        p[f"b{i}"] = jnp.zeros((dout,))
    return p


def mlp_apply(p: Params, x: jax.Array, *, use_pallas: bool = True):
    dense = _dense_fn(use_pallas)
    h = x.reshape(x.shape[0], -1)
    n_layers = len(MLP_HIDDEN) + 1
    for i in range(n_layers - 1):
        h = dense(h, p[f"w{i}"], p[f"b{i}"], "relu")
    z = h
    logits = kref.dense_ref(z, p[f"w{n_layers-1}"], p[f"b{n_layers-1}"], "linear")
    return logits, z


# ---------------------------------------------------------------------------
# Logistic regression (MNIST-like 784 -> 10) for the Fig 12 scalability run.
# ---------------------------------------------------------------------------

LOGREG_IN = 28 * 28


def logreg_init(key) -> Params:
    return {
        "w": 0.01 * jax.random.normal(key, (LOGREG_IN, NUM_CLASSES), jnp.float32),
        "b": jnp.zeros((NUM_CLASSES,)),
    }


def logreg_apply(p: Params, x: jax.Array, *, use_pallas: bool = True):
    x = x.reshape(x.shape[0], -1)
    if use_pallas:
        logits = pk.matmul(x, p["w"]) + p["b"][None, :]
    else:
        logits = kref.matmul_ref(x, p["w"]) + p["b"][None, :]
    return logits, x


# ---------------------------------------------------------------------------
# Registry consumed by steps.py / aot.py
# ---------------------------------------------------------------------------

class Backend:
    def __init__(self, name, init, apply, input_shape):
        self.name = name
        self.init = init
        self.apply = apply
        self.input_shape = input_shape  # per-example shape


BACKENDS: Dict[str, Backend] = {
    "cnn": Backend("cnn", cnn_init, cnn_apply, IMG_SHAPE),
    "cnn_v2": Backend("cnn_v2", cnn_v2_init, cnn_v2_apply, IMG_SHAPE),
    "mlp": Backend("mlp", mlp_init, mlp_apply, IMG_SHAPE),
    "logreg": Backend("logreg", logreg_init, logreg_apply, (LOGREG_IN,)),
}
