"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps).

This is the core correctness signal for the kernel layer: every shape/act
combination the models can emit must match ref.py to f32 tolerance, and the
custom_vjp backward passes must match jnp autodiff of the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as pk
from compile.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=300)
acts = st.sampled_from(["relu", "tanh", "linear"])


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, m, k), _rand(rng, k, n)
    got = pk.matmul(x, y)
    want = kref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, act=acts, seed=st.integers(0, 2**31 - 1))
def test_matmul_bias_act_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, y, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    got = pk.matmul_bias_act(x, y, b, act)
    want = kref.matmul_bias_act_ref(x, y, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 96), k=st.integers(1, 200), n=st.integers(1, 96),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_grad_matches_ref_grad(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, m, k), _rand(rng, k, n)

    def f_pallas(x, y):
        return jnp.sum(jnp.sin(pk.matmul(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.sin(kref.matmul_ref(x, y)))

    gx, gy = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    rx, ry = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gy, ry, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(act=acts, seed=st.integers(0, 2**31 - 1))
def test_dense_grad_matches_ref_grad(act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, 32, 70), _rand(rng, 70, 40), _rand(rng, 40)

    def f_pallas(x, w, b):
        return jnp.mean(pk.matmul_bias_act(x, w, b, act) ** 2)

    def f_ref(x, w, b):
        return jnp.mean(kref.matmul_bias_act_ref(x, w, b, act) ** 2)

    g = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    r = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for gi, ri in zip(g, r):
        np.testing.assert_allclose(gi, ri, rtol=1e-4, atol=1e-4)


def test_matmul_exact_tile_shapes():
    # Shapes exactly on tile boundaries (no padding path).
    rng = np.random.default_rng(0)
    x, y = _rand(rng, 256, 1024), _rand(rng, 1024, 256)
    np.testing.assert_allclose(
        pk.matmul(x, y), kref.matmul_ref(x, y), rtol=1e-5, atol=1e-3)


def test_matmul_vector_edge():
    rng = np.random.default_rng(1)
    x, y = _rand(rng, 1, 1), _rand(rng, 1, 1)
    np.testing.assert_allclose(pk.matmul(x, y), x * y, rtol=1e-6)


def test_bad_activation_raises():
    rng = np.random.default_rng(2)
    x, y, b = _rand(rng, 4, 4), _rand(rng, 4, 4), _rand(rng, 4)
    with pytest.raises(ValueError):
        pk.matmul_bias_act(x, y, b, "gelu")


def test_contraction_mismatch_asserts():
    rng = np.random.default_rng(3)
    with pytest.raises(AssertionError):
        pk.matmul(_rand(rng, 4, 5), _rand(rng, 6, 4))


def test_vmem_report_within_budget():
    rep = pk.vmem_report(64, 3072, 256)
    assert rep["vmem_bytes"] < 16 * 1024 * 1024
    assert rep["mxu_aligned"]
    assert all(g >= 1 for g in rep["grid"])


def test_vmem_report_small_operand():
    rep = pk.vmem_report(64, 784, 10)
    assert rep["grid"][1] == 1  # N fits one tile
    assert rep["vmem_bytes"] < 16 * 1024 * 1024
