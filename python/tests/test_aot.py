"""AOT pipeline tests: HLO text emission + manifest contract for Rust."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, models, steps

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_emits_hlo_module():
    lowered = jax.jit(lambda x: (x + 1.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ROOT" in text


def test_logreg_artifact_set(tmp_path):
    aset = aot.ArtifactSet(str(tmp_path))
    aot.build_backend(aset, "logreg", use_pallas=True, full=False)
    aset.finish()

    man = json.loads((tmp_path / "manifest.json").read_text())
    lb = man["backends"]["logreg"]
    assert set(lb["artifacts"]) == {"init", "sgd", "eval"}
    p = lb["param_count"]
    assert p == steps.flat_spec(models.BACKENDS["logreg"])[0]

    sgd = lb["artifacts"]["sgd"]
    assert sgd["n_outputs"] == 2
    # input order: flat, x, y, lr
    assert sgd["inputs"][0]["shape"] == [p]
    assert sgd["inputs"][1]["shape"] == [man["train_batch"], 784]
    assert sgd["inputs"][2]["dtype"] == "s32"
    assert sgd["inputs"][3]["shape"] == []

    for art in lb["artifacts"].values():
        path = tmp_path / art["file"]
        assert path.exists()
        head = path.read_text()[:200]
        assert head.startswith("HloModule")


def test_manifest_batches_match_steps(tmp_path):
    aset = aot.ArtifactSet(str(tmp_path))
    assert aset.manifest["train_batch"] == steps.TRAIN_BATCH == 64
    assert aset.manifest["eval_batch"] == steps.EVAL_BATCH == 256
