"""L2 train/eval step semantics (the contracts the Rust coordinator relies on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, steps

jax.config.update("jax_platform_name", "cpu")


def _data(backend, n=64, seed=0, classes=10):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n,) + backend.input_shape,
                                        dtype=np.float32))
    y = jnp.asarray(rng.integers(0, classes, n).astype(np.int32))
    return x, y


@pytest.fixture(scope="module")
def logreg():
    return models.BACKENDS["logreg"]


@pytest.fixture(scope="module")
def cnn():
    return models.BACKENDS["cnn"]


def test_sgd_decreases_loss(logreg):
    step = jax.jit(steps.make_sgd_step(logreg))
    flat = steps.make_init(logreg)(jnp.int32(0))[0]
    x, y = _data(logreg)
    _, l0 = step(flat, x, y, jnp.float32(0.1))
    f = flat
    for _ in range(25):
        f, loss = step(f, x, y, jnp.float32(0.1))
    assert float(loss) < float(l0) * 0.7


def test_sgd_lr_zero_is_identity(logreg):
    step = jax.jit(steps.make_sgd_step(logreg))
    flat = steps.make_init(logreg)(jnp.int32(1))[0]
    x, y = _data(logreg, seed=2)
    f2, _ = step(flat, x, y, jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(f2), np.asarray(flat))


def test_prox_mu_zero_matches_sgd(logreg):
    sgd = jax.jit(steps.make_sgd_step(logreg))
    prox = jax.jit(steps.make_prox_step(logreg))
    flat = steps.make_init(logreg)(jnp.int32(3))[0]
    g = steps.make_init(logreg)(jnp.int32(4))[0]
    x, y = _data(logreg, seed=5)
    fs, ls = sgd(flat, x, y, jnp.float32(0.05))
    fp, lp = prox(flat, g, x, y, jnp.float32(0.05), jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fp), rtol=1e-6)
    assert abs(float(ls) - float(lp)) < 1e-6


def test_prox_pulls_toward_global(logreg):
    prox = jax.jit(steps.make_prox_step(logreg))
    flat = steps.make_init(logreg)(jnp.int32(3))[0]
    g = jnp.zeros_like(flat)
    x, y = _data(logreg, seed=6)
    f_small, _ = prox(flat, g, x, y, jnp.float32(0.05), jnp.float32(0.0))
    f_big, _ = prox(flat, g, x, y, jnp.float32(0.05), jnp.float32(10.0))
    # Stronger mu => result closer to the global (zero) vector.
    assert float(jnp.linalg.norm(f_big)) < float(jnp.linalg.norm(f_small))


def test_scaffold_zero_cv_matches_sgd(logreg):
    sgd = jax.jit(steps.make_sgd_step(logreg))
    sca = jax.jit(steps.make_scaffold_step(logreg))
    flat = steps.make_init(logreg)(jnp.int32(7))[0]
    z = jnp.zeros_like(flat)
    x, y = _data(logreg, seed=8)
    fs, _ = sgd(flat, x, y, jnp.float32(0.05))
    fc, _ = sca(flat, z, z, x, y, jnp.float32(0.05))
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fc), rtol=1e-6)


def test_scaffold_cv_correction_applied(logreg):
    sca = jax.jit(steps.make_scaffold_step(logreg))
    flat = steps.make_init(logreg)(jnp.int32(7))[0]
    c = jnp.ones_like(flat)
    ci = jnp.zeros_like(flat)
    x, y = _data(logreg, seed=8)
    lr = 0.05
    f_zero, _ = sca(flat, jnp.zeros_like(c), ci, x, y, jnp.float32(lr))
    f_one, _ = sca(flat, c, ci, x, y, jnp.float32(lr))
    # w' = w - lr*(g - ci + c): adding c=1 shifts the update by exactly -lr.
    np.testing.assert_allclose(
        np.asarray(f_one), np.asarray(f_zero) - lr, rtol=1e-5, atol=1e-6)


def test_moon_mu_zero_matches_sgd(cnn):
    sgd = jax.jit(steps.make_sgd_step(cnn))
    moon = jax.jit(steps.make_moon_step(cnn))
    flat = steps.make_init(cnn)(jnp.int32(9))[0]
    g = steps.make_init(cnn)(jnp.int32(10))[0]
    x, y = _data(cnn)
    fs, ls = sgd(flat, x, y, jnp.float32(0.01))
    fm, lm = moon(flat, g, g, x, y, jnp.float32(0.01), jnp.float32(0.0),
                  jnp.float32(0.5))
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fm),
                               rtol=1e-4, atol=1e-5)


def test_moon_contrastive_term_positive(cnn):
    moon = jax.jit(steps.make_moon_step(cnn))
    sgd = jax.jit(steps.make_sgd_step(cnn))
    flat = steps.make_init(cnn)(jnp.int32(9))[0]
    g = steps.make_init(cnn)(jnp.int32(10))[0]
    p = steps.make_init(cnn)(jnp.int32(11))[0]
    x, y = _data(cnn)
    _, l_sgd = sgd(flat, x, y, jnp.float32(0.01))
    _, l_moon = moon(flat, g, p, x, y, jnp.float32(0.01), jnp.float32(5.0),
                     jnp.float32(0.5))
    assert float(l_moon) > float(l_sgd)  # xent + mu*con > xent


def test_eval_mask_excludes_padding(logreg):
    ev = jax.jit(steps.make_eval(logreg))
    flat = steps.make_init(logreg)(jnp.int32(12))[0]
    x, y = _data(logreg, n=steps.EVAL_BATCH, seed=13)
    full = jnp.ones((steps.EVAL_BATCH,), jnp.float32)
    half = full.at[steps.EVAL_BATCH // 2:].set(0.0)
    loss_f, corr_f = ev(flat, x, y, full)
    loss_h, corr_h = ev(flat, x, y, half)
    assert float(loss_h) < float(loss_f)
    assert float(corr_h) <= float(corr_f)
    # Zero mask => exactly zero contributions.
    loss_z, corr_z = ev(flat, x, y, jnp.zeros_like(full))
    assert float(loss_z) == 0.0 and float(corr_z) == 0.0


def test_eval_counts_correct_predictions(logreg):
    ev = jax.jit(steps.make_eval(logreg))
    flat = steps.make_init(logreg)(jnp.int32(14))[0]
    x, _ = _data(logreg, n=steps.EVAL_BATCH, seed=15)
    # Labels = model's own argmax => everything correct.
    p, unravel = steps.flat_spec(models.BACKENDS["logreg"])
    logits, _ = models.BACKENDS["logreg"].apply(
        steps._unravel_cache(models.BACKENDS["logreg"])(flat), x)
    y = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    mask = jnp.ones((steps.EVAL_BATCH,), jnp.float32)
    _, corr = ev(flat, x, y, mask)
    assert int(corr) == steps.EVAL_BATCH


def test_xent_uniform_logits():
    logits = jnp.zeros((8, 10))
    y = jnp.arange(8, dtype=jnp.int32) % 10
    assert abs(float(steps.xent(logits, y)) - np.log(10)) < 1e-5
