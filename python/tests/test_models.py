"""L2 model tests: shapes, init determinism, pallas/jnp equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, steps

jax.config.update("jax_platform_name", "cpu")

ALL = list(models.BACKENDS)


def _batch(backend, n=8, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n,) + backend.input_shape,
                                        dtype=np.float32))
    return x


@pytest.mark.parametrize("name", ALL)
def test_apply_shapes(name):
    b = models.BACKENDS[name]
    p = b.init(jax.random.PRNGKey(0))
    x = _batch(b)
    logits, z = b.apply(p, x)
    assert logits.shape == (8, models.NUM_CLASSES)
    assert z.shape[0] == 8 and z.ndim == 2


@pytest.mark.parametrize("name", ALL)
def test_init_deterministic(name):
    b = models.BACKENDS[name]
    f1 = steps.make_init(b)(jnp.int32(7))[0]
    f2 = steps.make_init(b)(jnp.int32(7))[0]
    f3 = steps.make_init(b)(jnp.int32(8))[0]
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    assert not np.array_equal(np.asarray(f1), np.asarray(f3))


@pytest.mark.parametrize("name", ALL)
def test_pallas_and_jnp_paths_agree(name):
    b = models.BACKENDS[name]
    p = b.init(jax.random.PRNGKey(3))
    x = _batch(b, seed=4)
    lp, zp = b.apply(p, x, use_pallas=True)
    lj, zj = b.apply(p, x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lj),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(zp), np.asarray(zj),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ALL)
def test_param_counts_positive_and_stable(name):
    b = models.BACKENDS[name]
    p1, _ = steps.flat_spec(b)
    p2, _ = steps.flat_spec(b)
    assert p1 == p2 > 0


def test_param_count_ordering_matches_paper_bandwidth_story():
    # Fig 9e: the sklearn MLP moves the most bytes; logreg the least (Fig 12).
    counts = {n: steps.flat_spec(models.BACKENDS[n])[0] for n in ALL}
    assert counts["mlp"] > counts["cnn_v2"] > counts["cnn"] > counts["logreg"]


def test_cnn_representation_dim():
    b = models.BACKENDS["cnn"]
    p = b.init(jax.random.PRNGKey(0))
    _, z = b.apply(p, _batch(b))
    assert z.shape[1] == models.CNN_HIDDEN
