//! Client / Worker node runtime (paper §2.1 component 4).
//!
//! Nodes are in-process actors driven by the Logic Controller: clients hold
//! their dataset shard (pre-uploaded as PJRT literals) and per-strategy
//! state; workers hold their aggregation role and an optional malicious
//! behaviour (for the Fig 10 poisoning experiments).

use std::sync::Arc;

use anyhow::Result;

use crate::controller::phases::NodeStage;
use crate::data::dataset::Dataset;
use crate::runtime::backend::ModelBackend;
use crate::runtime::tensor::Literal;
use crate::strategy::ctx::ClientState;
use crate::util::rng::Rng;

/// A client node: local data + strategy state.
pub struct ClientNode {
    pub name: String,
    pub stage: NodeStage,
    pub n_examples: usize,
    /// Pre-uploaded training batches.
    pub batches: Vec<(Literal, Literal)>,
    pub state: ClientState,
    /// Decentralized mode: the peer's own current model (shared handle —
    /// gossip merges hand the same allocation to the KV store and back).
    pub local_model: Option<Arc<[f32]>>,
    /// Simulated compute-speed multiplier (virtual train time per batch
    /// step = `SIM_STEP_SECS × speed_factor`). 1.0 = the baseline device;
    /// the scaffold derives larger factors deterministically from the seed
    /// when the job's `heterogeneity` knob is set.
    pub speed_factor: f64,
}

impl ClientNode {
    /// Build a client from its downloaded dataset chunk: fixed-size batches
    /// in a seed-derived order (wrap-around fill when the shard is smaller
    /// than one batch, so tiny non-IID shards still train).
    pub fn from_chunk(
        name: &str,
        chunk: &Dataset,
        backend: &ModelBackend,
        rng: &mut Rng,
    ) -> Result<ClientNode> {
        let bs = backend.train_batch;
        let n = chunk.len();
        assert!(n > 0, "client {name} received an empty chunk");
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);

        let n_batches = (n / bs).max(1);
        let f = chunk.feature_len();
        let mut batches = Vec::with_capacity(n_batches);
        for b in 0..n_batches {
            let mut x = Vec::with_capacity(bs * f);
            let mut y = Vec::with_capacity(bs);
            for k in 0..bs {
                let idx = order[(b * bs + k) % n];
                x.extend_from_slice(chunk.features(idx));
                y.push(chunk.y[idx]);
            }
            batches.push(backend.batch_lits(&x, &y)?);
        }
        Ok(ClientNode {
            name: name.to_string(),
            stage: NodeStage::NotReady,
            n_examples: n,
            batches,
            state: ClientState::default(),
            local_model: None,
            speed_factor: 1.0,
        })
    }

    /// Simulated seconds this client's local training takes in one round.
    pub fn sim_train_secs(&self, local_epochs: usize) -> f64 {
        (local_epochs * self.batches.len()) as f64
            * crate::kvstore::netsim::SIM_STEP_SECS
            * self.speed_factor
    }
}

/// Worker behaviour: honest, or a model-poisoning attacker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerBehavior {
    Honest,
    /// Scales the aggregate by a negative factor and injects noise — the
    /// classic sign-flip poisoning attack of the Fig 10 scenario.
    Malicious,
}

/// A worker/aggregator node.
pub struct WorkerNode {
    pub name: String,
    pub stage: NodeStage,
    pub behavior: WorkerBehavior,
}

impl WorkerNode {
    pub fn new(name: &str, behavior: WorkerBehavior) -> WorkerNode {
        WorkerNode {
            name: name.to_string(),
            stage: NodeStage::NotReady,
            behavior,
        }
    }

    /// Apply the worker's behaviour to its aggregate before proposing.
    pub fn transform_aggregate(&self, mut params: Vec<f32>, rng: &mut Rng) -> Vec<f32> {
        match self.behavior {
            WorkerBehavior::Honest => params,
            WorkerBehavior::Malicious => {
                let mut noise = rng.derive("poison", 0);
                for v in params.iter_mut() {
                    *v = -*v + 0.1 * noise.normal_f32();
                }
                params
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn malicious_transform_changes_params() {
        let w = WorkerNode::new("worker_0", WorkerBehavior::Malicious);
        let p = vec![1.0f32; 8];
        let out = w.transform_aggregate(p.clone(), &mut Rng::seed_from(1));
        assert_ne!(out, p);
        assert!(out[0] < 0.0);
        // Deterministic poison (reproducibility even for attacks).
        let out2 = w.transform_aggregate(p, &mut Rng::seed_from(1));
        assert_eq!(out, out2);
    }

    #[test]
    fn honest_transform_is_identity() {
        let w = WorkerNode::new("worker_0", WorkerBehavior::Honest);
        let p = vec![1.0f32, -2.0];
        assert_eq!(w.transform_aggregate(p.clone(), &mut Rng::seed_from(1)), p);
    }
}
