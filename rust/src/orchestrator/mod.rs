//! Job Orchestrator (paper §2.1 component 1): scaffolds the whole FL
//! experiment from a [`JobConfig`] — dataset distribution, overlay network,
//! node creation, strategy/consensus/blockchain wiring — and drives the
//! round loop through the Logic Controller.
//!
//! Four round flows cover the paper's evaluation matrix:
//! * **standard**      — client-server (1..n workers + consensus), Fig 8/9/10
//! * **hierarchical**  — leaf-cluster aggregation + root merge, Fig 11
//! * **clustered**     — FL+HC per-cluster models after the clustering round
//! * **decentralized** — Fedstellar-style P2P gossip, Fig 8/11

pub mod eval;
mod flows;
mod setup;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::job::JobConfig;
use crate::controller::sync::FaultPlan;
use crate::metrics::report::RunReport;
use crate::runtime::pjrt::Runtime;
use crate::strategy::StrategyMode;
use crate::topology::TopologyKind;

pub use flows::{
    clustered_round as run_clustered_round, decentralized_round as run_decentralized_round,
    hierarchical_round as run_hierarchical_round, standard_round as run_standard_round,
};
pub use setup::JobState;

/// Strategy-mode ↔ topology compatibility. Shared with campaign grid
/// expansion so an invalid cell fails at expand time (before any cell has
/// run) instead of mid-campaign.
pub fn check_topology(job: &JobConfig) -> Result<()> {
    if job.strategy.mode() == StrategyMode::Decentralized
        && !matches!(
            job.topology,
            TopologyKind::FullyConnected | TopologyKind::Ring
        )
    {
        bail!(
            "decentralized strategy '{}' requires a p2p topology, got {}",
            job.strategy.name(),
            job.topology.name()
        );
    }
    Ok(())
}

pub struct Orchestrator {
    rt: Arc<Runtime>,
}

impl Orchestrator {
    pub fn new(rt: Arc<Runtime>) -> Orchestrator {
        Orchestrator { rt }
    }

    /// Run a job to completion and return the per-round report.
    pub fn run(&self, job: &JobConfig) -> Result<RunReport> {
        self.run_with_faults(job, FaultPlan::none())
    }

    /// Run with injected node faults (stragglers / crashes).
    pub fn run_with_faults(&self, job: &JobConfig, faults: FaultPlan) -> Result<RunReport> {
        job.validate()?;
        check_topology(job)?;
        let mut state = setup::JobState::scaffold(self.rt.clone(), job, faults)?;
        let mode = job.strategy.mode();

        for round in 1..=job.rounds {
            let metrics = match (mode, job.topology) {
                (StrategyMode::Decentralized, _) => flows::decentralized_round(&mut state, round)?,
                (StrategyMode::Clustered, _) => flows::clustered_round(&mut state, round)?,
                (_, TopologyKind::Hierarchical) => flows::hierarchical_round(&mut state, round)?,
                _ => flows::standard_round(&mut state, round)?,
            };
            state.report.rounds.push(metrics);
            // Bound broker memory (long/large runs).
            state.kv.truncate_before(round);
        }

        if state.chain.is_some() {
            state.verify_chain()?;
        }
        Ok(state.report)
    }
}
