//! Job Orchestrator (paper §2.1 component 1): scaffolds the whole FL
//! experiment from a [`JobConfig`] — dataset distribution, overlay network,
//! node creation, strategy/consensus/blockchain wiring — and drives the
//! round loop through the Logic Controller.
//!
//! Four round flows cover the paper's evaluation matrix:
//! * **standard**      — client-server (1..n workers + consensus), Fig 8/9/10
//! * **hierarchical**  — leaf-cluster aggregation + root merge, Fig 11
//! * **clustered**     — FL+HC per-cluster models after the clustering round
//! * **decentralized** — Fedstellar-style P2P gossip, Fig 8/11

pub mod eval;
mod flows;
pub mod population;
mod setup;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::job::{JobConfig, PopulationMode};
use crate::controller::cancel::CancelToken;
use crate::controller::sync::FaultPlan;
use crate::metrics::report::{RoundMetrics, RunReport};
use crate::runtime::pjrt::Runtime;
use crate::strategy::{StrategyKind, StrategyMode};
use crate::topology::TopologyKind;

pub use flows::{
    clustered_round as run_clustered_round, decentralized_round as run_decentralized_round,
    hierarchical_round as run_hierarchical_round, standard_round as run_standard_round,
};
pub(crate) use flows::name_index;
pub use setup::JobState;

/// Strategy-mode ↔ topology compatibility. Shared with campaign grid
/// expansion so an invalid cell fails at expand time (before any cell has
/// run) instead of mid-campaign.
pub fn check_topology(job: &JobConfig) -> Result<()> {
    if job.strategy.mode() == StrategyMode::Decentralized
        && !matches!(
            job.topology,
            TopologyKind::FullyConnected | TopologyKind::Ring
        )
    {
        bail!(
            "decentralized strategy '{}' requires a p2p topology, got {}",
            job.strategy.name(),
            job.topology.name()
        );
    }
    Ok(())
}

/// A per-round metric observer: streamed the round's metrics the moment the
/// round commits, before the run finishes. Campaign schedulers hang their
/// live rung-decision channel off this.
pub type RoundSink = Box<dyn Fn(&RoundMetrics) + Send + Sync>;

/// How a driven run may be bounded: a cooperative [`CancelToken`] observed
/// at every round boundary, an optional round budget (run *up to* round
/// `round_budget`, then pause), and an optional per-round metric sink.
///
/// Both stop paths are clean: the in-flight round either commits fully or
/// never starts, so a stopped run's report is always a valid bitwise prefix
/// of the full run.
#[derive(Default)]
pub struct RunControl {
    pub cancel: CancelToken,
    /// Inclusive upper round bound for this drive (`None` = the job's own
    /// `rounds`). Values above the job budget are clamped to it.
    pub round_budget: Option<u64>,
    pub on_round: Option<RoundSink>,
}

impl RunControl {
    /// Unbounded: run to the job's configured budget.
    pub fn unbounded() -> RunControl {
        RunControl::default()
    }

    /// Run up to `rounds` completed rounds, then pause.
    pub fn budget(rounds: u64) -> RunControl {
        RunControl {
            round_budget: Some(rounds),
            ..RunControl::default()
        }
    }
}

/// Everything that shapes *how* a job is driven, as opposed to *what* runs
/// (the [`JobConfig`]): the cancellation/budget/metric-sink control and the
/// injected fault plan. `RunOptions::default()` is the plain
/// run-to-completion path, so the common call reads
/// `orc.run(&job, RunOptions::default())`; chain the builders for more:
///
/// ```ignore
/// orc.run(&job, RunOptions::default()
///     .faults(FaultPlan::none().crash_from("client_3", 5))
///     .control(RunControl::budget(10)))?;
/// ```
#[derive(Default)]
pub struct RunOptions {
    pub control: RunControl,
    pub faults: FaultPlan,
}

impl RunOptions {
    /// Drive under this [`RunControl`] (cancel token / round budget / sink).
    pub fn control(mut self, control: RunControl) -> RunOptions {
        self.control = control;
        self
    }

    /// Inject this [`FaultPlan`] (stragglers / crashes / churn).
    pub fn faults(mut self, faults: FaultPlan) -> RunOptions {
        self.faults = faults;
        self
    }
}

/// Why [`RunHandle::advance`] returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunStatus {
    /// The job's full round budget is done.
    Completed,
    /// The drive's `round_budget` was reached; the run is paused and can be
    /// advanced further.
    BudgetReached,
    /// The cancel token fired; the run stopped at a round boundary.
    Cancelled,
}

/// A paused, resumable run: the scaffolded [`JobState`] plus the loop
/// cursor. Campaign schedulers keep promoted cells' handles alive between
/// rungs so deepening a cell never recomputes its earlier rounds.
pub struct RunHandle {
    state: setup::JobState,
    mode: StrategyMode,
    /// 1-based next round to execute.
    next_round: u64,
}

impl RunHandle {
    /// Validate + scaffold a job without running any round.
    pub fn start(rt: Arc<Runtime>, job: &JobConfig, faults: FaultPlan) -> Result<RunHandle> {
        job.validate()?;
        check_topology(job)?;
        let state = setup::JobState::scaffold(rt, job, faults)?;
        let mode = job.strategy.mode();
        Ok(RunHandle {
            state,
            mode,
            next_round: 1,
        })
    }

    /// Whether a paused run of this job can be reconstructed from `(partial
    /// report, global model params)` alone — i.e. whether checkpoints are
    /// sound for it. True exactly when the global parameter vector is the
    /// *only* cross-round mutable state: central aggregation on the
    /// client-server flow with the eager (materialized) population and no
    /// blockchain. Everything else each round — client sampling, per-node
    /// RNG streams, fault/churn draws, DP accounting, network metering — is
    /// re-derived deterministically from the config and the round number.
    ///
    /// Deliberately conservative: strategies with server-side optimizer
    /// state (fedavgm/fedopt), per-client state (scaffold/moon), clustering,
    /// decentralized gossip, chains, and the virtual population all return
    /// false and simply replay from round 1 — slower, never wrong. The gate
    /// is a pure function of the config, so writers and readers of a
    /// checkpoint always agree on whether one can exist.
    pub fn checkpointable(job: &JobConfig) -> bool {
        matches!(
            job.strategy,
            StrategyKind::FedAvg | StrategyKind::FedProx { .. } | StrategyKind::DpFl { .. }
        ) && job.topology == TopologyKind::ClientServer
            && !job.chain.enabled
            && job.population == PopulationMode::Eager
    }

    /// The global model exactly as it stands now — the payload for a
    /// [`crate::campaign::Checkpoint`] — or `None` when this job is not
    /// [`RunHandle::checkpointable`].
    pub fn checkpoint_params(&self) -> Option<Arc<[f32]>> {
        RunHandle::checkpointable(&self.state.job).then(|| self.state.global.clone())
    }

    /// Reconstruct a paused run from a stored partial report and the
    /// checkpointed global model, positioned to continue at round
    /// `prefix.rounds_completed() + 1`. The caller guarantees `prefix` and
    /// `params` come from the *same* stored cell (the store keys both by
    /// the job's content hash); depth and length mismatches are errors.
    pub fn resume(
        rt: Arc<Runtime>,
        job: &JobConfig,
        faults: FaultPlan,
        prefix: &RunReport,
        params: &[f32],
    ) -> Result<RunHandle> {
        if !RunHandle::checkpointable(job) {
            bail!(
                "job '{}' is not checkpointable (strategy/topology/population \
                 carries cross-round state beyond the global model)",
                job.name
            );
        }
        let done = prefix.rounds_completed();
        if done == 0 || done > job.rounds {
            bail!(
                "cannot resume '{}' at round {done} of a {}-round budget",
                job.name,
                job.rounds
            );
        }
        let mut handle = RunHandle::start(rt, job, faults)?;
        if params.len() != handle.state.global.len() {
            bail!(
                "checkpoint holds {} params, job '{}' scaffolds {}",
                params.len(),
                job.name,
                handle.state.global.len()
            );
        }
        handle.state.global = params.into();
        handle.state.report.rounds = prefix.rounds.clone();
        handle.state.report.stopped_early = false;
        handle.next_round = done + 1;
        Ok(handle)
    }

    /// Rounds completed so far.
    pub fn rounds_done(&self) -> u64 {
        self.next_round - 1
    }

    /// Drive the round loop under `ctl`: run rounds until the job budget,
    /// the control's round budget, or cancellation — whichever is first.
    /// Each committed round is pushed to the report (and streamed to
    /// `ctl.on_round`) before the next one starts.
    pub fn advance(&mut self, ctl: &RunControl) -> Result<RunStatus> {
        let total = self.state.job.rounds;
        let until = ctl.round_budget.unwrap_or(total).min(total);
        while self.next_round <= until {
            if ctl.cancel.is_cancelled() {
                return Ok(RunStatus::Cancelled);
            }
            let round = self.next_round;
            let metrics = match (self.mode, self.state.job.topology) {
                (StrategyMode::Decentralized, _) => {
                    flows::decentralized_round(&mut self.state, round)?
                }
                (StrategyMode::Clustered, _) => flows::clustered_round(&mut self.state, round)?,
                (_, TopologyKind::Hierarchical) => {
                    flows::hierarchical_round(&mut self.state, round)?
                }
                _ => flows::standard_round(&mut self.state, round)?,
            };
            self.state.report.rounds.push(metrics);
            // Bound broker memory (long/large runs).
            self.state.kv.truncate_before(round);
            self.next_round += 1;
            if let Some(sink) = &ctl.on_round {
                sink(self.state.report.rounds.last().expect("round just pushed"));
            }
        }
        Ok(if self.rounds_done() == total {
            RunStatus::Completed
        } else if ctl.cancel.is_cancelled() {
            RunStatus::Cancelled
        } else {
            RunStatus::BudgetReached
        })
    }

    /// Snapshot the report so far, `stopped_early` stamped when the run is
    /// short of its configured budget. Always a valid (prefix) report.
    pub fn partial_report(&self) -> RunReport {
        let mut report = self.state.report.clone();
        report.stopped_early = self.rounds_done() < self.state.job.rounds;
        report
    }

    /// Consume a *completed* run: chain verification + the final report.
    /// Call only after [`RunHandle::advance`] returned
    /// [`RunStatus::Completed`] (a short run errors rather than laundering a
    /// partial report as complete — use [`RunHandle::partial_report`]).
    pub fn finish(self) -> Result<RunReport> {
        if self.rounds_done() < self.state.job.rounds {
            bail!(
                "run '{}' finished at round {} of {} — partial runs report via partial_report()",
                self.state.job.name,
                self.rounds_done(),
                self.state.job.rounds
            );
        }
        if self.state.chain.is_some() {
            self.state.verify_chain()?;
        }
        Ok(self.state.report)
    }
}

pub struct Orchestrator {
    rt: Arc<Runtime>,
}

impl Orchestrator {
    pub fn new(rt: Arc<Runtime>) -> Orchestrator {
        Orchestrator { rt }
    }

    /// Run a job and return the per-round report. This is the single
    /// entrypoint: `RunOptions::default()` runs to completion with no
    /// faults; a control whose budget or cancel token stops the loop early
    /// yields a valid partial report marked `stopped_early` (a bitwise
    /// prefix of the full run); a fault plan injects stragglers/crashes.
    pub fn run(&self, job: &JobConfig, opts: RunOptions) -> Result<RunReport> {
        let mut handle = RunHandle::start(self.rt.clone(), job, opts.faults)?;
        match handle.advance(&opts.control)? {
            RunStatus::Completed => handle.finish(),
            RunStatus::BudgetReached | RunStatus::Cancelled => Ok(handle.partial_report()),
        }
    }
}
