//! Global-model evaluation over the shared test set (the strategy-agnostic
//! `test()` half of the paper's Strategy class).

use anyhow::Result;

use crate::data::dataset::Dataset;
use crate::runtime::backend::ModelBackend;
use crate::runtime::tensor::Literal;

/// The test set, pre-uploaded as fixed-size masked eval batches.
pub struct EvalSet {
    batches: Vec<(Literal, Literal, Literal)>,
    pub n_examples: usize,
}

impl EvalSet {
    pub fn build(test: &Dataset, backend: &ModelBackend) -> Result<EvalSet> {
        let bs = backend.eval_batch;
        let f = test.feature_len();
        let n = test.len();
        let n_batches = n.div_ceil(bs).max(1);
        let mut batches = Vec::with_capacity(n_batches);
        for b in 0..n_batches {
            let mut x = vec![0f32; bs * f];
            let mut y = vec![0i32; bs];
            let mut mask = vec![0f32; bs];
            for k in 0..bs {
                let idx = b * bs + k;
                if idx < n {
                    x[k * f..(k + 1) * f].copy_from_slice(test.features(idx));
                    y[k] = test.y[idx];
                    mask[k] = 1.0;
                }
            }
            batches.push(backend.eval_lits(&x, &y, &mask)?);
        }
        Ok(EvalSet {
            batches,
            n_examples: n,
        })
    }

    /// Evaluate parameters: returns (mean loss, accuracy).
    pub fn evaluate(&self, backend: &ModelBackend, params: &[f32]) -> Result<(f64, f64)> {
        let p = backend.params_lit(params)?;
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        for (x, y, mask) in &self.batches {
            let (l, c) = backend.eval_batch(&p, x, y, mask)?;
            loss_sum += l as f64;
            correct += c as f64;
        }
        let n = self.n_examples.max(1) as f64;
        Ok((loss_sum / n, correct / n))
    }
}
