//! Job scaffolding: everything Figure 1's "load job" arrow implies —
//! dataset generation + distribution, overlay construction, node creation,
//! strategy / consensus / blockchain instantiation, controller init.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::aggregate::mean::{apply_dp_noise, clip_update, AggPlan, StreamingMean};
use crate::aggregate::robust::{coordinate_median, krum, trimmed_mean};
use crate::chain::{self, Blockchain};
use crate::config::adversary::{AttackKind, RobustAggKind};
use crate::config::job::{JobConfig, PopulationMode};
use crate::consensus::{self, Consensus};
use crate::controller::phases::NodeStage;
use crate::controller::sync::{FaultPlan, LogicController};
use crate::data::dataset::Dataset;
use crate::data::distributor::Distributor;
use crate::data::partition::Partition;
use crate::data::synthetic;
use crate::info;
use crate::kvstore::arena::RoundArena;
use crate::kvstore::netsim::NetSim;
use crate::kvstore::store::KvStore;
use crate::metrics::report::RunReport;
use crate::node::{ClientNode, WorkerBehavior, WorkerNode};
use crate::orchestrator::eval::EvalSet;
use crate::orchestrator::population::Population;
use crate::runtime::backend::ModelBackend;
use crate::runtime::pjrt::Runtime;
use crate::strategy::{ClientUpdate, Strategy};
use crate::topology::graph::Overlay;
use crate::util::rng::Rng;

/// The shared ingredients a *virtual* fleet derives every client from
/// (`job.population = virtual`): instead of `n_clients` resident
/// [`ClientNode`]s, the scaffold keeps the rank tables, the training split
/// and the shard assignments, and each round's sampled cohort is
/// materialized on demand — bitwise-identical to the node the eager
/// scaffold would have built (test-enforced).
pub struct VirtualFleet {
    /// Bijection between numeric client ids and lexicographic ranks.
    pub population: Population,
    /// The full training split client shards subset from.
    pub train: Dataset,
    /// Shard assignments, `min(n_clients, train.len())` of them; clients
    /// beyond that wrap around (`rank % n_shards`).
    pub partition: Partition,
}

/// All live state of a running job.
pub struct JobState {
    pub job: JobConfig,
    pub backend: ModelBackend,
    pub overlay: Overlay,
    pub clients: BTreeMap<String, ClientNode>,
    pub workers: BTreeMap<String, WorkerNode>,
    pub controller: LogicController,
    pub kv: KvStore,
    pub net: NetSim,
    /// Round-buffer arena every per-round `Vec<f32> → Arc<[f32]>`
    /// conversion goes through (client updates, proposals, cluster / peer /
    /// global models). Pass-through when `job.arena` is off.
    pub arena: RoundArena,
    pub strategy: Box<dyn Strategy>,
    pub consensus: Box<dyn Consensus>,
    pub chain: Option<Box<dyn Blockchain>>,
    pub eval: EvalSet,
    pub distributor: Distributor,
    /// Current global model (standard/hierarchical flows). A shared handle:
    /// the broadcast publish, every client's starting point and the
    /// evaluation pass all reference this one allocation.
    pub global: Arc<[f32]>,
    /// FL+HC: cluster id per client (None until the clustering round).
    pub clusters: Option<BTreeMap<String, usize>>,
    /// FL+HC: per-cluster global models.
    pub cluster_models: BTreeMap<usize, Arc<[f32]>>,
    /// Compromised clients (seed-derived `attack_fraction` draw ∪ explicit
    /// `adversary.nodes`). Empty when the adversary config is inactive.
    pub adversaries: BTreeSet<String>,
    /// Virtual-population state (`job.population = virtual`): the shard
    /// source and rank tables lazy cohort materialization derives clients
    /// from. `None` for eager fleets.
    pub fleet: Option<VirtualFleet>,
    pub root_rng: Rng,
    pub report: RunReport,
    /// Virtual-clock record of the last parallel training phase: per-client
    /// simulated finish times (download + train + upload) ...
    pub client_virtual_secs: BTreeMap<String, f64>,
    /// ... and its makespan (max over on-time clients, capped at the round
    /// deadline when one is configured).
    pub last_phase_secs: f64,
}

impl JobState {
    pub fn scaffold(rt: Arc<Runtime>, job: &JobConfig, mut faults: FaultPlan) -> Result<JobState> {
        let root_rng = Rng::seed_from(job.seed);

        // Backend + capability check (ML-library agnosticism boundary).
        let backend = ModelBackend::new(rt, &job.backend)?;
        let step = job.strategy.required_artifact();
        if !backend.supports(step) {
            bail!(
                "backend '{}' does not provide the '{step}' artifact required by \
                 strategy '{}' — rebuild artifacts or pick another backend",
                job.backend,
                job.strategy.name()
            );
        }

        // Dataset: generate -> split -> partition -> archive.
        let ds = synthetic::by_name(&job.dataset.name, job.dataset.n, job.seed)
            .ok_or_else(|| anyhow!("unknown dataset '{}'", job.dataset.name))?;
        let mut split_rng = root_rng.derive("split", 0);
        let (train, test) = ds.split(job.dataset.train_frac, &mut split_rng);

        // Overlay + roles. A virtual fleet keeps only the worker tier
        // resident — clients exist as an overlay *count*, priced by the
        // netsim star fast path and materialized per sampled cohort.
        let virtualized = job.population == PopulationMode::Virtual;
        let overlay = if virtualized {
            Overlay::client_server_virtual(job.n_clients, job.n_workers)
        } else {
            Overlay::build(job.topology, job.n_clients, job.n_workers)
        };
        overlay.validate()?;
        let client_names = overlay.clients(); // empty in virtual mode
        let worker_names = overlay.workers();
        let population = if virtualized {
            Some(Population::new(job.n_clients)?)
        } else {
            None
        };
        let fleet_size = if virtualized {
            job.n_clients
        } else {
            client_names.len()
        };

        // Shard count: one per client eagerly; capped at the training-set
        // size for virtual fleets larger than the data (clients then share
        // shards, `rank % n_shards`). For N ≤ train.len() the partition draw
        // is identical to the eager one.
        let n_shards = if virtualized {
            job.n_clients.min(train.len()).max(1)
        } else {
            client_names.len()
        };
        let mut part_rng = root_rng.derive("partition", 0);
        let partition =
            Partition::build(&train, n_shards, &job.dataset.distribution, &mut part_rng);

        // Virtual fleets skip the content-addressed archive entirely:
        // cohort members subset the training split directly at
        // materialization time, which is bitwise what `archive_partition` +
        // `download` roundtrips to (the codec is exact).
        let mut distributor = Distributor::new();
        if !virtualized {
            distributor.archive_partition(&train, &partition, &client_names, &test)?;
        }

        // Adversarial scenario: resolve the compromised cohort (seed-derived
        // draw ∪ explicit list) and fold the declarative `faults:` schedule
        // (explicit events + churn draws) into the caller's plan. Inactive
        // sections resolve to an empty set / empty plan without drawing from
        // any RNG stream.
        let adversaries = match &population {
            Some(pop) => {
                crate::adversary::select_adversaries_virtual(&job.adversary, &root_rng, pop)?
            }
            None => crate::adversary::select_adversaries(&job.adversary, &root_rng, &client_names)?,
        };
        if !adversaries.is_empty() {
            info!(
                "orchestrator",
                "adversary: {} compromised client(s) running '{}': {:?}",
                adversaries.len(),
                job.adversary.attack.name(),
                adversaries
            );
        }
        if virtualized {
            faults.merge(crate::adversary::materialize_faults_virtual(job));
        } else {
            faults.merge(crate::adversary::materialize_faults(job, &client_names));
        }

        // Controller over every node; stage flow of Algorithm 1 lines 1-13.
        let all_nodes: Vec<String> = overlay.roles.keys().cloned().collect();
        let mut controller = LogicController::new(&all_nodes);
        controller.fault_plan = faults;
        controller.allow_timeout = true;

        for n in &all_nodes {
            controller.update_stage(n, NodeStage::ReadyForJob)?;
        }
        controller.barrier(&all_nodes, NodeStage::ReadyForJob, 0, all_nodes.len())?;

        // Clients download their chunks and build device-resident batches.
        // Each also gets a deterministic compute-speed profile: a factor in
        // [1, 1 + heterogeneity) derived from the seed and the client name,
        // scaling its *simulated* train time (virtual clock only).
        let mut clients = BTreeMap::new();
        for (i, name) in client_names.iter().enumerate() {
            let mut chunk = distributor.download(name, "train")?;
            // Label-flip is a *data* poisoning attack: corrupt the local
            // chunk before batching, then train honestly on the bad labels.
            if job.adversary.attack == AttackKind::LabelFlip && adversaries.contains(name) {
                let k = chunk.num_classes as i32;
                for y in &mut chunk.y {
                    *y = (*y + 1) % k;
                }
            }
            let mut batch_rng = root_rng.derive("batching", i as u64);
            let mut node = ClientNode::from_chunk(name, &chunk, &backend, &mut batch_rng)?;
            let mut speed_rng = root_rng.derive("speed", super::flows::name_index(name));
            node.speed_factor = 1.0 + job.heterogeneity * speed_rng.next_f64();
            clients.insert(name.clone(), node);
            controller.update_stage(name, NodeStage::ReadyWithDataset)?;
        }
        let mut workers = BTreeMap::new();
        for name in &worker_names {
            let malicious = job.consensus.malicious_workers.contains(name);
            workers.insert(
                name.clone(),
                WorkerNode::new(
                    name,
                    if malicious {
                        WorkerBehavior::Malicious
                    } else {
                        WorkerBehavior::Honest
                    },
                ),
            );
            controller.update_stage(name, NodeStage::ReadyWithDataset)?;
        }
        controller.barrier(&all_nodes, NodeStage::ReadyWithDataset, 0, all_nodes.len())?;
        controller.emit("All nodes ready with dataset.");

        // Eval set on the shared test chunk.
        let eval = EvalSet::build(&test, &backend)?;

        // Strategy / consensus / chain.
        let strategy = job.strategy.build();
        let consensus = consensus::by_name(&job.consensus.runnable)?;
        let chain = if job.chain.enabled {
            Some(chain::by_platform(&job.chain.platform)?)
        } else {
            None
        };

        // Deterministic global init (node seed synchronization, RQ6).
        let global: Arc<[f32]> = backend.init(job.seed as i32)?.into();

        let report = RunReport {
            label: job.name.clone(),
            strategy: job.strategy.name().to_string(),
            topology: job.topology.name().to_string(),
            backend: job.backend.clone(),
            n_clients: fleet_size,
            n_workers: worker_names.len(),
            seed: job.seed,
            stopped_early: false,
            rounds: Vec::new(),
        };

        info!(
            "orchestrator",
            "scaffolded job '{}': {} clients, {} workers, {} params, {} topology",
            job.name,
            fleet_size,
            worker_names.len(),
            backend.param_count,
            job.topology.name()
        );

        // Topology-aware fabric: transfers route over the overlay's edges
        // with the job's per-class link models.
        let mut net = NetSim::with_policy(job.network);
        net.attach_overlay(&overlay);
        if virtualized {
            net.set_virtual_star(job.n_clients as u64, worker_names.iter().cloned().collect());
        }

        Ok(JobState {
            job: job.clone(),
            backend,
            overlay,
            clients,
            workers,
            controller,
            kv: KvStore::new(),
            net,
            arena: if job.arena {
                RoundArena::new()
            } else {
                RoundArena::disabled()
            },
            strategy,
            consensus,
            chain,
            eval,
            distributor,
            global,
            clusters: None,
            cluster_models: BTreeMap::new(),
            adversaries,
            fleet: population.map(|population| VirtualFleet {
                population,
                train,
                partition,
            }),
            root_rng,
            report,
            client_virtual_secs: BTreeMap::new(),
            last_phase_secs: 0.0,
        })
    }

    /// The node that physically serves model downloads/uploads in star
    /// flows (deterministic: first worker in overlay order).
    pub fn primary_worker(&self) -> String {
        self.overlay
            .workers()
            .into_iter()
            .next()
            .unwrap_or_else(|| "logic_controller".to_string())
    }

    /// Per-round derived stream (all round-scoped randomness hangs off it).
    pub fn round_rng(&self, round: u64) -> Rng {
        self.root_rng.derive("round", round)
    }

    /// Worker threads the round engine may use (`job.parallelism`, with 0 =
    /// one per core). Purely a wall-clock knob — every result is bitwise
    /// identical at any value.
    pub fn parallelism(&self) -> usize {
        self.job.effective_parallelism()
    }

    /// Aggregation plan: the job's hardware profile plus its parallelism.
    pub fn agg_plan(&self) -> AggPlan {
        AggPlan::new(self.job.hw_profile, self.parallelism())
    }

    /// Whether the strategy's server side is a plain example-weighted mean
    /// (`weighted_mean_plan` over update params in arrival order): FedAvg,
    /// FedProx (prox term is client-side), FedAvgM (momentum lives in
    /// `post_round`). These are the strategies whose aggregate may be
    /// streamed or channel-DP'd without changing a bit.
    fn strategy_is_mean_shaped(&self) -> bool {
        matches!(self.strategy.name(), "fedavg" | "fedprox" | "fedavgm")
    }

    /// Server-side aggregation dispatch: the strategy's own `aggregate`
    /// unless `aggregation: robust:` selects a Byzantine-robust rule
    /// (krum / trimmed-mean / coordinate-median from `aggregate/robust.rs`).
    /// The assumed Byzantine count is the explicit `aggregation.f` when
    /// given (invalid values surface as the robust rule's own error), else
    /// the number of configured adversaries among this round's updates
    /// (min 1), clamped to what the rule can absorb at this cohort size.
    ///
    /// `channel.dp` slots in between: each update's delta is L2-clipped to
    /// `dp.clip` before the strategy aggregate, and the aggregate receives
    /// Gaussian noise from the worker's `"dp_noise"` stream — for `fedavg`
    /// this reproduces the legacy `dpfl` strategy bit for bit (pinned test).
    pub fn aggregate_updates(
        &self,
        updates: &[ClientUpdate],
        plan: AggPlan,
        rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        if self.job.robust_agg.kind == RobustAggKind::None {
            if let Some(dp) = self.job.channel.dp {
                // Virtual fleets fold the clipped deltas online; eager
                // fleets clip-then-aggregate through the strategy. Both
                // land on the same weighted mean bitwise (StreamingMean is
                // golden-tested against weighted_mean_plan), then the same
                // noise stream.
                if self.fleet.is_some() && self.strategy_is_mean_shaped() && !updates.is_empty() {
                    let total: f64 = updates.iter().map(|u| u.weight).sum();
                    let mut stream =
                        StreamingMean::new(updates[0].params.len(), total, plan.order)?;
                    for u in updates {
                        stream.push(&clip_update(&self.global, &u.params, dp.clip), u.weight)?;
                    }
                    let mut agg = stream.finish()?;
                    apply_dp_noise(&mut agg, dp.clip, dp.sigma, updates.len(), rng);
                    return Ok(agg);
                }
                let clipped: Vec<ClientUpdate> = updates
                    .iter()
                    .map(|u| ClientUpdate {
                        params: self
                            .arena
                            .store_vec(clip_update(&self.global, &u.params, dp.clip)),
                        ..u.clone()
                    })
                    .collect();
                let mut agg = self.strategy.aggregate(&clipped, &self.global, plan, rng)?;
                apply_dp_noise(&mut agg, dp.clip, dp.sigma, updates.len(), rng);
                return Ok(agg);
            }
            // Virtual fleets fold mean-shaped strategies online: O(model)
            // accumulator state instead of the collect-then-reduce path.
            // `StreamingMean` is golden-tested bitwise against
            // `weighted_mean_plan` — which is exactly what the
            // fedavg/fedprox/fedavgm aggregates run — for every reduction
            // order, so this gate never changes a result.
            if self.fleet.is_some() && self.strategy_is_mean_shaped() && !updates.is_empty() {
                let total: f64 = updates.iter().map(|u| u.weight).sum();
                let mut stream = StreamingMean::new(updates[0].params.len(), total, plan.order)?;
                for u in updates {
                    stream.push(u.params.as_ref(), u.weight)?;
                }
                return stream.finish();
            }
            return self.strategy.aggregate(updates, &self.global, plan, rng);
        }
        let refs: Vec<&[f32]> = updates.iter().map(|u| u.params.as_ref()).collect();
        let n = refs.len();
        let f_for = |cap: usize| {
            self.job.robust_agg.f.unwrap_or_else(|| {
                updates
                    .iter()
                    .filter(|u| self.adversaries.contains(&u.client))
                    .count()
                    .max(1)
                    .min(cap)
            })
        };
        match self.job.robust_agg.kind {
            RobustAggKind::None => unreachable!("dispatched above"),
            RobustAggKind::Krum => {
                // krum needs n > 2f + 2.
                let f = f_for(n.saturating_sub(3) / 2);
                let idx = krum(&refs, f)?;
                Ok(refs[idx].to_vec())
            }
            RobustAggKind::TrimmedMean => {
                // trimmed_mean needs n > 2·trim.
                let trim = f_for(n.saturating_sub(1) / 2);
                trimmed_mean(&refs, trim)
            }
            RobustAggKind::Median => coordinate_median(&refs),
        }
    }

    /// Sampled client subset for a round (`client_fraction < 1.0`).
    ///
    /// Eager fleets walk the overlay's client roles *borrowed* — only the
    /// chosen cohort is cloned, not the whole fleet name list every round.
    /// Virtual fleets sample over lexicographic ranks and format just the
    /// chosen names; when no downtime is possible this round, the liveness
    /// scan is skipped outright. Both paths feed `choose_indices` the
    /// identical `(alive_len, k)` stream, so the cohorts agree bit for bit.
    pub fn sample_clients(&self, round: u64) -> Vec<String> {
        match &self.fleet {
            Some(fleet) => self.sample_virtual(fleet, round),
            None => self.sample_eager(round),
        }
    }

    fn sample_eager(&self, round: u64) -> Vec<String> {
        let alive: Vec<&str> = self
            .overlay
            .client_names()
            .filter(|n| self.controller.is_alive(n, round))
            .collect();
        self.draw_cohort(alive.len(), round, |i| alive[i].to_string())
    }

    fn sample_virtual(&self, fleet: &VirtualFleet, round: u64) -> Vec<String> {
        use std::fmt::Write;
        let alive_ranks: Option<Vec<usize>> = if self.controller.may_have_downtime(round) {
            let mut ranks = Vec::new();
            let mut scratch = String::new();
            for rank in 0..fleet.population.len() {
                scratch.clear();
                let _ = write!(scratch, "client_{}", fleet.population.id_at_rank(rank));
                if self.controller.is_alive(&scratch, round) {
                    ranks.push(rank);
                }
            }
            Some(ranks)
        } else {
            None // every rank is alive; sample over 0..n directly
        };
        let alive_len = alive_ranks.as_ref().map_or(fleet.population.len(), Vec::len);
        self.draw_cohort(alive_len, round, |i| {
            fleet
                .population
                .name_at_rank(alive_ranks.as_ref().map_or(i, |r| r[i]))
        })
    }

    /// Shared sampling core; `name_at(i)` resolves the i-th alive client.
    fn draw_cohort(
        &self,
        alive_len: usize,
        round: u64,
        name_at: impl Fn(usize) -> String,
    ) -> Vec<String> {
        if alive_len == 0 {
            return Vec::new();
        }
        if self.job.client_fraction >= 1.0 {
            return (0..alive_len).map(name_at).collect();
        }
        let k = ((self.job.client_fraction * alive_len as f64).ceil() as usize).clamp(1, alive_len);
        let mut rng = self.round_rng(round).derive("client_sample", 0);
        let mut out: Vec<String> =
            rng.choose_indices(alive_len, k).into_iter().map(name_at).collect();
        out.sort();
        out
    }

    /// Materialize one sampled virtual client, bitwise-identical to the
    /// node the eager scaffold builds for the same name: same shard (the
    /// lex-rank ↔ partition pairing), same label-flip corruption, same
    /// batching stream (`derive("batching", rank)`) and speed draw
    /// (`derive("speed", id)`). A client already resident (carrying
    /// cross-round strategy state) is left untouched.
    fn materialize_client(&mut self, name: &str) -> Result<()> {
        if self.clients.contains_key(name) {
            return Ok(());
        }
        let fleet = self
            .fleet
            .as_ref()
            .ok_or_else(|| anyhow!("materialize_client on an eager fleet"))?;
        let rank = fleet
            .population
            .rank_of_name(name)
            .ok_or_else(|| anyhow!("unknown virtual client '{name}'"))?;
        let id = fleet.population.id_at_rank(rank);
        let shard = rank % fleet.partition.n_clients();
        let mut chunk = fleet.train.subset(&fleet.partition.assignments[shard]);
        if self.job.adversary.attack == AttackKind::LabelFlip && self.adversaries.contains(name) {
            let k = chunk.num_classes as i32;
            for y in &mut chunk.y {
                *y = (*y + 1) % k;
            }
        }
        let mut batch_rng = self.root_rng.derive("batching", rank as u64);
        let mut node = ClientNode::from_chunk(name, &chunk, &self.backend, &mut batch_rng)?;
        let mut speed_rng = self.root_rng.derive("speed", id);
        node.speed_factor = 1.0 + self.job.heterogeneity * speed_rng.next_f64();
        self.clients.insert(name.to_string(), node);
        Ok(())
    }

    /// Virtual mode: make every sampled client resident and known to the
    /// controller before the round flows drive it (stage updates bail on
    /// unknown nodes, and the virtual clock reads the node's speed factor
    /// in the serial download phase). No-op for eager fleets.
    pub fn ensure_cohort(&mut self, cohort: &[String]) -> Result<()> {
        if self.fleet.is_none() {
            return Ok(());
        }
        for name in cohort {
            self.materialize_client(name)?;
            self.controller.admit(name, NodeStage::ReadyWithDataset);
        }
        Ok(())
    }

    /// Virtual mode: return the fleet to O(sampled cohort) residency after
    /// a round commits. Nodes carrying cross-round strategy state (MOON's
    /// previous params, SCAFFOLD control variates, decentralized local
    /// models) stay resident — exactly the state an eager fleet would have
    /// kept. No-op for eager fleets.
    pub fn evict_cohort(&mut self) {
        if self.fleet.is_none() {
            return;
        }
        let controller = &mut self.controller;
        self.clients.retain(|name, node| {
            let keep = node.state.prev_params.is_some()
                || node.state.c_local.is_some()
                || node.local_model.is_some();
            if !keep {
                controller.forget(name);
            }
            keep
        });
    }

    pub fn verify_chain(&self) -> Result<()> {
        if let Some(chain) = &self.chain {
            chain.verify_integrity()?;
            info!(
                "orchestrator",
                "blockchain integrity verified at height {}",
                chain.height()
            );
        }
        Ok(())
    }

    /// Shared evaluation used by flows: (test_loss, test_accuracy).
    pub fn evaluate(&self, params: &[f32]) -> Result<(f64, f64)> {
        self.eval.evaluate(&self.backend, params)
    }

    /// Dataset setup bytes served to a node (reported in round-1 metrics).
    pub fn setup_bytes(&self) -> u64 {
        self.distributor.total_bytes_served()
    }
}
