//! Virtual client population: client identity as *data*, not objects.
//!
//! The eager scaffold materializes every client up front and keys all
//! per-client state by the **lexicographic position** of the client's name
//! (`client_names` is sorted, data shards pair with names by index, batch
//! RNG streams derive from the enumerate index). To materialize one client
//! lazily — and bitwise-identically — the virtual path therefore needs the
//! bijection between a client's numeric id (`client_{id}`, the
//! `name_index` the RNG/speed/fault draws use) and its lexicographic rank
//! among all `n` names (the shard/batching index).
//!
//! Decimal strings without leading zeros sort lexicographically as a
//! pre-order walk of the digit trie: `0` first (it has no multi-digit
//! descendants among valid ids), then each subtree rooted at `1..=9`, where
//! the children of `x ≥ 1` are `10x ..= 10x+9`. One O(n) DFS builds both
//! rank tables — 4 bytes per client per table, so a 1M-client population
//! costs ~8 MB instead of the eager path's gigabytes of resident nodes.

use anyhow::{bail, Result};

/// Rank tables for a `client_0 .. client_{n-1}` population.
#[derive(Clone, Debug)]
pub struct Population {
    /// `id_at_rank[r]` = numeric id of the r-th name in lexicographic order.
    id_at_rank: Vec<u32>,
    /// `rank_of_id[id]` = lexicographic rank of `client_{id}` (the inverse
    /// permutation of `id_at_rank`).
    rank_of_id: Vec<u32>,
}

impl Population {
    pub fn new(n: usize) -> Result<Population> {
        if n == 0 {
            bail!("virtual population of zero clients");
        }
        if n > u32::MAX as usize {
            bail!("virtual population of {n} clients exceeds the u32 rank table");
        }
        let mut id_at_rank = Vec::with_capacity(n);
        // "0" sorts before every other decimal string ("0" < "1" < "10").
        id_at_rank.push(0u32);
        // Pre-order DFS over the decimal trie, subtrees 1..=9 in order.
        // Children are pushed in reverse so the stack pops them in lex order.
        let mut stack: Vec<u64> = Vec::new();
        for root in (1..10u64).rev() {
            if root < n as u64 {
                stack.push(root);
            }
        }
        while let Some(x) = stack.pop() {
            id_at_rank.push(x as u32);
            let base = 10 * x;
            for child in (base..base + 10).rev() {
                if child < n as u64 {
                    stack.push(child);
                }
            }
        }
        debug_assert_eq!(id_at_rank.len(), n);
        let mut rank_of_id = vec![0u32; n];
        for (rank, &id) in id_at_rank.iter().enumerate() {
            rank_of_id[id as usize] = rank as u32;
        }
        Ok(Population { id_at_rank, rank_of_id })
    }

    /// Number of clients in the population.
    pub fn len(&self) -> usize {
        self.id_at_rank.len()
    }

    pub fn is_empty(&self) -> bool {
        self.id_at_rank.is_empty()
    }

    /// Numeric id of the client at lexicographic rank `rank`.
    pub fn id_at_rank(&self, rank: usize) -> u64 {
        self.id_at_rank[rank] as u64
    }

    /// Lexicographic rank of the client with numeric id `id`.
    pub fn rank_of_id(&self, id: u64) -> usize {
        self.rank_of_id[id as usize] as usize
    }

    /// Name of the client at lexicographic rank `rank`.
    pub fn name_at_rank(&self, rank: usize) -> String {
        format!("client_{}", self.id_at_rank[rank])
    }

    /// Lexicographic rank of a client name, if it belongs to the population.
    pub fn rank_of_name(&self, name: &str) -> Option<usize> {
        let id: usize = name.strip_prefix("client_")?.parse().ok()?;
        if id < self.rank_of_id.len() {
            Some(self.rank_of_id[id] as usize)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_tables_match_sorted_name_lists() {
        for n in [1usize, 2, 5, 9, 10, 11, 13, 101, 1000, 1024] {
            let pop = Population::new(n).unwrap();
            assert_eq!(pop.len(), n);
            let mut names: Vec<String> = (0..n).map(|i| format!("client_{i}")).collect();
            names.sort();
            for (rank, name) in names.iter().enumerate() {
                assert_eq!(&pop.name_at_rank(rank), name, "n={n} rank={rank}");
                assert_eq!(pop.rank_of_name(name), Some(rank), "n={n} rank={rank}");
            }
        }
    }

    #[test]
    fn tables_are_inverse_permutations() {
        let pop = Population::new(12345).unwrap();
        for rank in 0..pop.len() {
            assert_eq!(pop.rank_of_id(pop.id_at_rank(rank)), rank);
        }
    }

    #[test]
    fn foreign_names_have_no_rank() {
        let pop = Population::new(10).unwrap();
        assert_eq!(pop.rank_of_name("client_10"), None);
        assert_eq!(pop.rank_of_name("worker_0"), None);
        assert_eq!(pop.rank_of_name("client_x"), None);
    }

    #[test]
    fn zero_population_rejected() {
        assert!(Population::new(0).is_err());
    }

    #[test]
    fn large_population_builds_quickly_and_compactly() {
        // 1M clients: the whole identity layer is two u32 tables (~8 MB).
        let n = 1_000_000;
        let pop = Population::new(n).unwrap();
        assert_eq!(pop.len(), n);
        // Spot-check the lex order at the tricky boundaries.
        assert_eq!(pop.name_at_rank(0), "client_0");
        assert_eq!(pop.name_at_rank(1), "client_1");
        assert_eq!(pop.name_at_rank(2), "client_10");
        assert_eq!(pop.id_at_rank(n - 1), 999_999);
        assert_eq!(pop.rank_of_id(999_999), n - 1);
    }
}
