//! The four round flows (standard / hierarchical / clustered /
//! decentralized), each implementing the per-round body of Algorithm 1 over
//! the KV store with full traffic metering.
//!
//! ## The parallel round engine
//!
//! Client-local training — the dominant cost of every round — runs on a
//! scoped worker pool sized by `JobConfig::parallelism`. Determinism (RQ6)
//! is preserved *by construction*, not by locking:
//!
//! 1. **Phase A (serial, client order):** starting models are resolved,
//!    downloads are metered and per-client RNG streams are derived — all in
//!    deterministic client order, before any thread is spawned.
//! 2. **Phase B (parallel):** clients train concurrently. Each task touches
//!    only its own node state and pre-derived RNG stream plus
//!    shared-immutable context (backend, strategy, broadcast state); the
//!    reference engine is bitwise-deterministic per call, so scheduling
//!    cannot influence any client's result.
//! 3. **Phase C (serial, client order):** uploads, traffic metering and
//!    controller stage transitions are committed in deterministic client
//!    order, regardless of which worker finished first.
//!
//! Consequently `parallelism: N` produces bitwise-identical model hashes
//! and byte counts to `parallelism: 1` (asserted by
//! `rust/tests/parallel_engine.rs`).
//!
//! ## The virtual clock
//!
//! Every delivery is priced over the overlay route between its real
//! endpoints (`NetSim` + per-edge-class link models), and every client's
//! round is assigned a simulated `download + train + upload` finish time
//! (train time scales with the client's deterministic `speed_factor`).
//! Each flow folds these into the round's **virtual makespan**
//! (`sim_round_secs`): the parallel client phase contributes its maximum
//! finish time, aggregation / gossip hops add on the critical path. The
//! clock is purely observational — results are bitwise-identical with it
//! on or off — unless `round_deadline_secs` is set, in which case clients
//! that overrun the deadline are dropped through the Logic Controller's
//! barrier timeout arm exactly like fault-plan stragglers.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::aggregate::compress::{self, CompressedUpdate};
use crate::chain::block::Tx;
use crate::config::adversary::AttackKind;
use crate::config::channel::CompressKind;
use crate::consensus::Proposal;
use crate::controller::phases::{NodeStage, ProcessPhase};
use crate::kvstore::arena::RoundArena;
use crate::kvstore::store::Payload;
use crate::metrics::report::RoundMetrics;
use crate::metrics::resources;
use crate::node::ClientNode;
use crate::orchestrator::setup::JobState;
use crate::runtime::backend::ModelBackend;
use crate::strategy::ctx::{ClientCtx, ClientUpdate};
use crate::strategy::Strategy;
use crate::util::hash;
use crate::util::json::Json;
use crate::util::rng::Rng;

const LC: &str = "logic_controller";

/// Publish to the KV broker (byte accounting only — a message costs wire
/// *time* when it is delivered, priced over the sender→reader route).
fn publish(state: &mut JobState, topic: &str, sender: &str, round: u64, payload: Payload) {
    state.kv.publish(topic, sender, round, payload);
}

/// Deliver the latest message on `topic` to `reader`, pricing the overlay
/// route from the physical source `src`. Returns (message, virtual secs).
fn deliver_latest(
    state: &mut JobState,
    topic: &str,
    src: &str,
    reader: &str,
) -> Result<(crate::kvstore::store::Message, f64)> {
    let msg = state.kv.fetch_latest(topic, reader)?;
    let secs = state.net.transfer(src, reader, msg.payload.wire_bytes());
    Ok((msg, secs))
}

/// Deliver all of a round's messages on `topic` to `reader`, each priced
/// over the route from its sender. Returns (messages, summed virtual secs).
fn deliver_round(
    state: &mut JobState,
    topic: &str,
    round: u64,
    reader: &str,
) -> (Vec<crate::kvstore::store::Message>, f64) {
    let msgs = state.kv.fetch_round(topic, round, reader);
    let mut secs = 0.0;
    for m in &msgs {
        secs += state.net.transfer(&m.sender, reader, m.payload.wire_bytes());
    }
    (msgs, secs)
}

/// Round-metrics bookkeeping around a flow body.
struct RoundScope {
    t0: Instant,
    res0: resources::ResourceSnapshot,
    bytes0: u64,
    net0: f64,
}

impl RoundScope {
    fn begin(state: &mut JobState) -> RoundScope {
        // The virtual-clock record is per round: drop stale finish times of
        // clients that were not sampled (or whose cluster faulted) earlier.
        state.client_virtual_secs.clear();
        RoundScope {
            t0: Instant::now(),
            res0: resources::snapshot(),
            bytes0: state.kv.total_bytes(),
            net0: state.net.total_secs(),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn finish(
        self,
        state: &JobState,
        round: u64,
        train_loss: f64,
        eval_model: &[f32],
        test_loss: f64,
        test_accuracy: f64,
        sim_round_secs: f64,
    ) -> RoundMetrics {
        let wall = self.t0.elapsed().as_secs_f64();
        let res1 = resources::snapshot();
        // Cumulative privacy spend is a pure function of (config, round):
        // resumed and truncated reports carry the same series as fresh runs.
        let (dp_epsilon, dp_delta) =
            crate::metrics::privacy::cumulative(state.job.channel.dp.as_ref(), round);
        RoundMetrics {
            round,
            test_accuracy,
            test_loss,
            train_loss,
            wall_secs: wall,
            cpu_pct: resources::cpu_util_pct(self.res0, res1, wall),
            rss_mib: res1.rss_mib,
            net_bytes: state.kv.total_bytes() - self.bytes0,
            sim_net_secs: state.net.total_secs() - self.net0,
            sim_round_secs,
            model_hash: hash::short_hash(eval_model),
            dp_epsilon,
            dp_delta,
        }
    }
}

/// One client's unit of parallel work: everything phase B needs, owned or
/// exclusively borrowed, so tasks can move to worker threads.
struct TrainTask<'a> {
    name: &'a str,
    start: Arc<[f32]>,
    rng: Rng,
    node: &'a mut ClientNode,
}

/// Pair every sampled client name with a mutable borrow of its node (the
/// borrows are disjoint — names are unique map keys).
fn collect_tasks<'a>(
    clients: &'a mut BTreeMap<String, ClientNode>,
    names: &'a [String],
    starts: Vec<Arc<[f32]>>,
    rngs: Vec<Rng>,
) -> Result<Vec<TrainTask<'a>>> {
    let index_of: BTreeMap<&str, usize> = names
        .iter()
        .enumerate()
        .map(|(i, n)| (n.as_str(), i))
        .collect();
    let mut nodes: Vec<Option<&'a mut ClientNode>> = Vec::new();
    nodes.resize_with(names.len(), || None);
    for (k, v) in clients.iter_mut() {
        if let Some(&i) = index_of.get(k.as_str()) {
            nodes[i] = Some(v);
        }
    }
    let mut tasks = Vec::with_capacity(names.len());
    for ((name, (start, rng)), node) in names
        .iter()
        .zip(starts.into_iter().zip(rngs))
        .zip(nodes)
    {
        tasks.push(TrainTask {
            name: name.as_str(),
            start,
            rng,
            node: node.ok_or_else(|| anyhow!("unknown client {name}"))?,
        });
    }
    Ok(tasks)
}

/// Phase B: run every task's local training, on a scoped worker pool when
/// `par > 1`. Results come back in task (= client) order; worker scheduling
/// cannot influence any value because each task reads only its own state
/// plus shared-immutable context.
fn train_tasks(
    backend: &ModelBackend,
    strategy: &dyn Strategy,
    arena: &RoundArena,
    extra_state: Option<&[f32]>,
    lr: f32,
    epochs: usize,
    tasks: &mut [TrainTask<'_>],
    par: usize,
) -> Vec<Result<ClientUpdate>> {
    let run_one = |t: &mut TrainTask<'_>| -> Result<ClientUpdate> {
        let mut ctx = ClientCtx {
            client: t.name,
            backend,
            batches: &t.node.batches,
            global: &t.start,
            extra_state,
            lr,
            local_epochs: epochs,
            n_examples: t.node.n_examples,
            state: &mut t.node.state,
            rng: &mut t.rng,
            arena,
        };
        strategy.client_train(&mut ctx)
    };
    let workers = par.min(tasks.len()).max(1);
    if workers <= 1 {
        return tasks.iter_mut().map(run_one).collect();
    }
    let chunk = tasks.len().div_ceil(workers);
    std::thread::scope(|s| {
        let run_one = &run_one;
        let mut handles = Vec::with_capacity(workers);
        for slab in tasks.chunks_mut(chunk) {
            handles.push(s.spawn(move || slab.iter_mut().map(run_one).collect::<Vec<_>>()));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("client training worker panicked"))
            .collect()
    })
}

/// Local training for a set of clients, each starting from `start_of(name)`.
/// Returns updates keyed by client (BTreeMap => deterministic order).
///
/// * `download_of` names the KV topic each client downloads its starting
///   model from and the physical node serving it (None = the model is
///   already resident, e.g. a decentralized peer resuming its own local
///   model — nothing is fetched or metered).
/// * `upload_dst_of` names the node a client's upload travels to (prices
///   the upload leg of its virtual finish time; None = local hand-off).
/// * `upload_topic_of` decides which KV topic each client uploads to
///   (shared topic for star flows; per-cluster for hierarchical; per-peer
///   for gossip).
///
/// Clients whose virtual `download + train + upload` time exceeds
/// `round_deadline_secs` (when set) are marked late: their upload never
/// lands, they are excluded from the returned updates, and the closing
/// barrier resolves through the timeout arm without them.
///
/// When the job configures a `channel:`, its stages apply here at the
/// upload boundary for *every* flow: deltas are compressed (and uploads
/// metered at the compressed wire bytes), secure-agg share traffic is
/// priced, and rounds with fewer surviving updates than the secure-agg
/// threshold abort. The DP stage lives in
/// [`JobState::aggregate_updates`](crate::orchestrator::setup::JobState).
fn train_clients_to(
    state: &mut JobState,
    round: u64,
    names: &[String],
    start_of: impl Fn(&JobState, &str) -> Arc<[f32]>,
    download_of: impl Fn(&JobState, &str) -> Option<(String, String)>,
    upload_dst_of: impl Fn(&JobState, &str) -> Option<String>,
    upload_topic_of: impl Fn(&str) -> String,
) -> Result<BTreeMap<String, ClientUpdate>> {
    state.controller.set_phase(ProcessPhase::LocalLearning);
    state.controller.reset_stages(names, NodeStage::ReadyWithDataset);

    // Broadcast strategy extra state (e.g. SCAFFOLD's c_global) once.
    let extra_state = state.strategy.client_extra_state();
    if let Some(es) = &extra_state {
        let payload = Payload::params(es.clone());
        publish(state, "strategy_state", LC, round, payload);
    }

    let lr = state.job.train.learning_rate;
    let epochs = state.job.train.local_epochs;
    let par = state.parallelism();

    // Phase A (serial, deterministic client order): resolve starting models,
    // meter the phase-4 downloads over their routes, accumulate each
    // client's virtual download + train time, flip stages, derive RNG
    // streams.
    let mut starts = Vec::with_capacity(names.len());
    let mut rngs = Vec::with_capacity(names.len());
    let mut pre_secs = Vec::with_capacity(names.len());
    for name in names {
        let start = start_of(state, name);
        let mut dl_secs = 0.0;
        if let Some((topic, src)) = download_of(state, name) {
            let (_msg, secs) = deliver_latest(state, &topic, &src, name)?;
            dl_secs += secs;
            if extra_state.is_some() {
                let (_msg, secs) = deliver_latest(state, "strategy_state", &src, name)?;
                dl_secs += secs;
            }
        }
        let train_secs = state
            .clients
            .get(name.as_str())
            .map(|n| n.sim_train_secs(epochs))
            .unwrap_or(0.0);
        pre_secs.push(dl_secs + train_secs);
        state.controller.update_stage(name, NodeStage::Busy)?;
        rngs.push(state.round_rng(round).derive("client", name_index(name)));
        starts.push(start);
    }

    // Adversarial / channel context: starting models are consumed by the
    // worker pool below, so keep per-client handles only when phase C
    // actually needs them — attacks rewrite deltas, and the compression
    // stage is defined on the delta vs. the start. The plain path must not
    // clone anything.
    let keep_starts =
        !state.adversaries.is_empty() || state.job.channel.compress.is_active();
    let kept_starts: Option<Vec<Arc<[f32]>>> = keep_starts.then(|| starts.clone());

    // Phase B (parallel): local training on the worker pool.
    let results = {
        let backend = &state.backend;
        let strategy: &dyn Strategy = state.strategy.as_ref();
        let arena = &state.arena;
        let mut tasks = collect_tasks(&mut state.clients, names, starts, rngs)?;
        train_tasks(
            backend,
            strategy,
            arena,
            extra_state.as_deref(),
            lr,
            epochs,
            &mut tasks,
            par,
        )
    };

    // Phase C (serial, deterministic client order): phase-1 uploads, traffic
    // metering, virtual-clock accounting and stage transitions — committed
    // in client order no matter which worker finished first. Publishing a
    // model is an Arc refcount bump; the floats trained in phase B are
    // never copied again.
    let deadline = state.job.round_deadline_secs;
    let mut updates = BTreeMap::new();
    let mut phase_secs = 0f64;
    let mut collusion: Option<Arc<[f32]>> = None;
    for (i, ((name, result), pre)) in names.iter().zip(results).zip(pre_secs).enumerate() {
        let mut update = result?;
        if let Some(starts) = &kept_starts {
            apply_attack(state, round, name, &starts[i], &mut update, &mut collusion);
        }
        // Channel stage: compress the delta at the upload boundary (after
        // any attack — the channel carries whatever the client sends). The
        // update's params are replaced by the decompressed reconstruction,
        // so every downstream consumer — eager aggregation and the virtual
        // StreamingMean fold alike — sees exactly what crossed the wire.
        let compressed = if state.job.channel.compress.is_active() {
            let starts = kept_starts.as_ref().expect("starts kept while compressing");
            Some(Arc::new(compress_for_upload(
                state,
                round,
                name,
                &starts[i],
                &mut update,
            )?))
        } else {
            None
        };
        let upload_dst = upload_dst_of(state, name);
        // Uploads are priced at what actually crosses the wire: the
        // compressed payload when the channel compresses, the dense update
        // (plus any strategy extra) otherwise.
        let extra_wire = update
            .extra
            .as_ref()
            .map(|e| (e.len() * 4) as u64)
            .unwrap_or(0);
        let upload_bytes = match &compressed {
            Some(c) => c.wire_bytes() + extra_wire,
            None => update.wire_bytes(),
        };
        let ul_secs = match &upload_dst {
            Some(dst) => state.net.price(name, dst, upload_bytes),
            None => 0.0,
        };
        let finish = pre + ul_secs;
        state.client_virtual_secs.insert(name.clone(), finish);
        if deadline.map_or(false, |d| finish > d) {
            // Straggler: its upload never lands. The barrier below resolves
            // through the timeout arm without it — Algorithm 1's fault path,
            // emergent from the virtual clock rather than scripted.
            state.controller.mark_late(name, round);
            phase_secs = phase_secs.max(deadline.unwrap_or(0.0));
            continue;
        }
        phase_secs = phase_secs.max(finish);
        let topic = upload_topic_of(name);
        // The KV fabric carries (and meters) the compressed form; readers
        // that re-deliver this message downstream are charged the same
        // compressed bytes.
        let payload = match &compressed {
            Some(c) => Payload::Compressed(c.clone()),
            None => Payload::Params(update.params.clone()),
        };
        publish(state, &topic, name, round, payload);
        if state.job.channel.secure_agg.is_some() {
            // Bonawitz-style masked aggregation, as a cost model: each
            // participant ships one 32-byte pairwise key share per cohort
            // member alongside its (masked) update. Results are unchanged —
            // the simulation prices the protocol, it does not execute it.
            let shares = Payload::Opaque(32 * names.len() as u64);
            let share_secs = match &upload_dst {
                Some(dst) => state.net.transfer(name, dst, shares.wire_bytes()),
                None => 0.0,
            };
            phase_secs = phase_secs.max(finish + share_secs);
            publish(state, "secagg_shares", name, round, shares);
        }
        if let Some(extra) = &update.extra {
            let payload = Payload::Params(extra.clone());
            let extra_bytes = payload.wire_bytes();
            publish(state, "client_extra", name, round, payload);
            // Control-variate uploads ride the same uplink but have no
            // KV reader (the strategy consumes them server-side from the
            // returned updates), so their wire time is metered here.
            if let Some(dst) = &upload_dst {
                state.net.transfer(name, dst, extra_bytes);
            }
        }
        state.controller.update_stage(name, NodeStage::Done)?;
        updates.insert(name.clone(), update);
    }
    if let Some(sa) = state.job.channel.secure_agg {
        if updates.len() < sa.threshold {
            bail!(
                "round {round}: secure aggregation needs {} surviving clients to unmask \
                 the sum, got {} — lower channel.secure_agg.threshold or raise the deadline",
                sa.threshold,
                updates.len()
            );
        }
        let dropped = names.len() - updates.len();
        if dropped > 0 {
            // Share recovery: for every dropped client, `threshold`
            // survivors each re-upload a 96-byte recovery share so the
            // server can unmask the sum without the dropout — the expensive
            // arm of the protocol, priced serially on the critical path.
            let recoverers: Vec<String> =
                updates.keys().take(sa.threshold).cloned().collect();
            let mut recovery_secs = 0.0;
            for _ in 0..dropped {
                for s in &recoverers {
                    if let Some(dst) = upload_dst_of(state, s) {
                        recovery_secs += state.net.transfer(s, &dst, 96);
                    }
                }
            }
            phase_secs += recovery_secs;
        }
    }
    state.last_phase_secs = phase_secs;

    state.controller.emit("Clients are waiting for next round.");
    // With a deadline configured an all-late phase is a legal outcome (the
    // caller decides whether an empty quorum is fatal — a hierarchical flow
    // drops the cluster, a star flow aborts the round); without one, a live
    // client that never reached Done is a real failure.
    let min_quorum = usize::from(deadline.is_none());
    state
        .controller
        .barrier(names, NodeStage::Done, round, min_quorum)?;
    Ok(updates)
}

/// Apply the configured model-poisoning attack to a compromised client's
/// update at the upload boundary (label flipping is a *data* attack and is
/// applied to the client's shard at scaffold time instead, so it needs no
/// hook here). Honest clients pass through untouched. The collusion vector
/// is drawn once per training phase from its own derived stream
/// (`round_rng(round).derive("collude", 0)`) and shared by every colluder,
/// so it is identical regardless of sampling order or parallelism.
fn apply_attack(
    state: &JobState,
    round: u64,
    name: &str,
    start: &Arc<[f32]>,
    update: &mut ClientUpdate,
    collusion: &mut Option<Arc<[f32]>>,
) {
    if !state.adversaries.contains(name) {
        return;
    }
    let scale = state.job.adversary.scale as f32;
    match state.job.adversary.attack {
        AttackKind::LabelFlip => {}
        AttackKind::SignFlip => {
            let flipped: Vec<f32> = update.params.iter().map(|p| -p).collect();
            update.params = state.arena.store_vec(flipped);
        }
        AttackKind::Scale => {
            // Gradient ascent: walk λ× the honest delta away from this
            // client's own starting model.
            let scaled: Vec<f32> = start
                .iter()
                .zip(update.params.iter())
                .map(|(s, p)| s - scale * (p - s))
                .collect();
            update.params = state.arena.store_vec(scaled);
        }
        AttackKind::Collude => {
            let shared = collusion
                .get_or_insert_with(|| {
                    let mut rng = state.round_rng(round).derive("collude", 0);
                    let poison: Vec<f32> = state
                        .global
                        .iter()
                        .map(|g| g - scale * rng.normal_f32())
                        .collect();
                    state.arena.store_vec(poison)
                })
                .clone();
            update.params = shared;
        }
    }
}

/// Apply the channel's compression stage to one client's upload: compress
/// the delta vs. the client's starting model, then replace the update's
/// params with the decompressed reconstruction (the server must aggregate
/// what the wire carried, not the lossless original). Quantization dither
/// draws from `round_rng(round).derive("compress", name_index)` — phase C
/// runs in deterministic client order, so the stream is schedule-invariant.
fn compress_for_upload(
    state: &JobState,
    round: u64,
    name: &str,
    start: &Arc<[f32]>,
    update: &mut ClientUpdate,
) -> Result<CompressedUpdate> {
    let cc = &state.job.channel.compress;
    let delta: Vec<f32> = update
        .params
        .iter()
        .zip(start.iter())
        .map(|(p, s)| p - s)
        .collect();
    let compressed = match cc.kind {
        CompressKind::TopK => compress::top_k(&delta, cc.k),
        CompressKind::Quantize => {
            let mut rng = state.round_rng(round).derive("compress", name_index(name));
            compress::quantize(&delta, cc.bits, &mut rng)?
        }
        CompressKind::None => bail!("compress_for_upload called with an inactive stage"),
    };
    let rec = compressed.decompress();
    let rebuilt: Vec<f32> = start.iter().zip(rec.iter()).map(|(s, d)| s + d).collect();
    update.params = state.arena.store_vec(rebuilt);
    Ok(compressed)
}

/// Flow-level guard for star flows: an empty update set after a training
/// phase means every sampled client overran the round deadline.
fn require_quorum(
    updates: &BTreeMap<String, ClientUpdate>,
    state: &JobState,
    round: u64,
) -> Result<()> {
    if updates.is_empty() {
        bail!(
            "round {round}: every client overran round_deadline_secs={:?} — \
             raise the deadline or lower heterogeneity",
            state.job.round_deadline_secs
        );
    }
    Ok(())
}

/// `train_clients_to` for the star-topology flows: the global model is
/// served by the primary worker, uploads travel back to it, and everyone
/// shares the "client_params" topic.
fn train_clients(
    state: &mut JobState,
    round: u64,
    names: &[String],
    start_of: impl Fn(&JobState, &str) -> Arc<[f32]>,
) -> Result<BTreeMap<String, ClientUpdate>> {
    let primary = state.primary_worker();
    let dl_src = primary.clone();
    train_clients_to(
        state,
        round,
        names,
        start_of,
        move |_, _| Some(("global_model".to_string(), dl_src.clone())),
        move |_, _| Some(primary.clone()),
        |_| "client_params".to_string(),
    )
}

/// Stable per-name stream index: numeric `_N` suffixes map to N (the
/// historical behaviour every seeded run depends on); anything else derives
/// from a SHA-256 of the full name, so distinct names always get distinct
/// RNG streams. (The old byte-sum fallback collided for anagram names —
/// e.g. hierarchical workers `cluster12_worker` vs `cluster21_worker`.)
pub(crate) fn name_index(name: &str) -> u64 {
    name.rsplit('_')
        .next()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or_else(|| {
            let mut h = hash::Sha256::new();
            h.update(name.as_bytes());
            let digest = h.finalize();
            u64::from_be_bytes(digest[..8].try_into().expect("sha256 digest >= 8 bytes"))
        })
}

/// Consensus phases 1+2: every alive worker pulls the round's client
/// updates, aggregates, and publishes a hash vote. Each worker aggregates
/// with its *own* derived stream `round_rng(round).derive("agg", worker)`,
/// so a proposal is invariant to which other workers are alive (a shared
/// mutable RNG would let a dropped worker perturb every later proposal and
/// make the winning model depend on the fault plan).
fn worker_proposals(
    state: &mut JobState,
    round: u64,
    updates: &[ClientUpdate],
) -> Result<Vec<Proposal>> {
    let worker_names = state.overlay.workers();
    let alive = state.controller.alive(&worker_names, round);
    if alive.is_empty() {
        bail!("round {round}: no live workers");
    }
    state.controller.reset_stages(&alive, NodeStage::ReadyWithDataset);
    let plan = state.agg_plan();

    let mut proposals: Vec<Proposal> = Vec::new();
    for wname in &alive {
        state.controller.update_stage(wname, NodeStage::Busy)?;
        // Each worker pulls the full client-parameter set (phase 1 of the
        // consensus pipeline: local parameter sharing to *all* workers).
        // Zero-copy: every message hands back the client's own allocation.
        let (msgs, _secs) = deliver_round(state, "client_params", round, wname);
        if msgs.len() != updates.len() {
            // KV store is the transport; the counts must agree.
            bail!(
                "worker {wname}: saw {} client messages, expected {}",
                msgs.len(),
                updates.len()
            );
        }
        let mut agg_rng = state.round_rng(round).derive("agg", name_index(wname));
        let agg = state.aggregate_updates(updates, plan, &mut agg_rng)?;
        let agg = {
            let worker = state
                .workers
                .get(wname)
                .ok_or_else(|| anyhow!("unknown worker {wname}"))?;
            let mut poison_rng = state.round_rng(round).derive("worker", name_index(wname));
            worker.transform_aggregate(agg, &mut poison_rng)
        };
        // Phase 2: aggregated parameter voting — share the hash.
        let prop = Proposal::new(wname.clone(), agg);
        let payload = Payload::Text(prop.hash.clone());
        publish(state, "agg_votes", wname, round, payload);
        state.controller.update_stage(wname, NodeStage::Done)?;
        proposals.push(prop);
    }
    Ok(proposals)
}

/// Worker-side aggregation + §2.5 consensus pipeline. Returns the winning
/// proposal's parameters and the consensus phase's virtual-clock cost (the
/// slowest worker's vote-exchange time; client uploads were already paid in
/// the training phase).
fn aggregate_and_consensus(
    state: &mut JobState,
    round: u64,
    updates: &[ClientUpdate],
    rng: &mut Rng,
) -> Result<(Vec<f32>, f64)> {
    state.controller.set_phase(ProcessPhase::ModelAggregation);
    let proposals = worker_proposals(state, round, updates)?;
    let alive: Vec<String> = proposals.iter().map(|p| p.worker.clone()).collect();

    state.controller.emit("Workers busy in model aggregation.");
    // Every worker reads every other worker's vote (phase 2 traffic). The
    // workers vote in parallel: the phase costs the slowest exchange.
    let mut vote_secs = 0f64;
    for wname in &alive {
        let (_msgs, secs) = deliver_round(state, "agg_votes", round, wname);
        vote_secs = vote_secs.max(secs);
    }
    state
        .controller
        .barrier(&alive, NodeStage::Done, round, 1)?;
    state.controller.emit("Received aggregated params");

    // Blockchain hooks: record hashes; optionally decide on-chain.
    if let Some(chain) = state.chain.as_mut() {
        for p in &proposals {
            chain.submit_tx(Tx::new(
                &p.worker,
                "param_verify",
                "record",
                Json::obj(vec![
                    ("round", Json::from(round as usize)),
                    ("hash", Json::from(p.hash.as_str())),
                ]),
            ))?;
            if state.job.consensus.on_chain {
                chain.submit_tx(Tx::new(
                    &p.worker,
                    "consensus",
                    "propose",
                    Json::obj(vec![
                        ("round", Json::from(round as usize)),
                        ("hash", Json::from(p.hash.as_str())),
                    ]),
                ))?;
            }
        }
    }

    // Phase 3: final global parameter setting.
    let winner_idx = if state.job.consensus.on_chain {
        let chain = state.chain.as_mut().unwrap();
        let d = chain.query(
            "consensus",
            "decide",
            &Json::obj(vec![("round", Json::from(round as usize))]),
        )?;
        let win_hash = d
            .get("hash")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("on-chain consensus returned no hash"))?;
        proposals
            .iter()
            .position(|p| p.hash == win_hash)
            .ok_or_else(|| anyhow!("winning hash not among proposals"))?
    } else {
        let decision = state.consensus.decide(&proposals, rng)?;
        decision.winner
    };

    // Reputation + provenance on chain.
    if let Some(chain) = state.chain.as_mut() {
        let win_hash = proposals[winner_idx].hash.clone();
        for p in &proposals {
            let method = if p.hash == win_hash { "reward" } else { "penalize" };
            chain.submit_tx(Tx::new(
                LC,
                "reputation",
                method,
                Json::obj(vec![("node", Json::from(p.worker.as_str()))]),
            ))?;
        }
        chain.submit_tx(Tx::new(
            LC,
            "provenance",
            "record",
            Json::obj(vec![
                ("round", Json::from(round as usize)),
                ("hash", Json::from(win_hash.as_str())),
            ]),
        ))?;
        chain.seal_block()?;
    }

    Ok((
        proposals.into_iter().nth(winner_idx).unwrap().params,
        vote_secs,
    ))
}

/// Standard client-server round (Fig 8/9/10): train -> aggregate ->
/// consensus -> distribute.
pub fn standard_round(state: &mut JobState, round: u64) -> Result<RoundMetrics> {
    let scope = RoundScope::begin(state);
    let mut rng = state.round_rng(round);

    // Phase 4 (of the previous round): distribute the current global model
    // (an Arc handle — the broadcast is a refcount bump).
    let payload = Payload::Params(state.global.clone());
    publish(state, "global_model", LC, round, payload);

    let sampled = state.sample_clients(round);
    if sampled.is_empty() {
        bail!("round {round}: no live clients");
    }
    // Virtual fleets materialize exactly this round's cohort (no-op for
    // eager fleets, whose nodes are all resident already).
    state.ensure_cohort(&sampled)?;
    let updates_map = train_clients(state, round, &sampled, |st, _| st.global.clone())?;
    require_quorum(&updates_map, state, round)?;
    let updates: Vec<ClientUpdate> = updates_map.into_values().collect();
    let train_loss = mean_loss(&updates);
    let client_phase = state.last_phase_secs;

    let (winner, agg_secs) = aggregate_and_consensus(state, round, &updates, &mut rng)?;
    let global_before = state.global.clone();
    let next_global = state.strategy.post_round(&updates, &global_before, winner);
    state.global = state.arena.store_vec(next_global);

    let (test_loss, test_accuracy) = state.evaluate(&state.global)?;
    // Server memory stays O(model + sampled cohort): the round's cohort is
    // dropped before the metrics snapshot (eager fleets: no-op).
    state.evict_cohort();
    let global = state.global.clone();
    Ok(scope.finish(
        state,
        round,
        train_loss,
        &global,
        test_loss,
        test_accuracy,
        client_phase + agg_secs,
    ))
}

/// Hierarchical round (Fig 11): leaf-cluster aggregation, then root merge.
pub fn hierarchical_round(state: &mut JobState, round: u64) -> Result<RoundMetrics> {
    let scope = RoundScope::begin(state);

    let payload = Payload::Params(state.global.clone());
    publish(state, "global_model", LC, round, payload);

    // The root aggregator comes from the overlay (don't hardcode its name —
    // off-overlay endpoints silently price on the flat fallback link).
    let root = state
        .overlay
        .root_worker()
        .ok_or_else(|| anyhow!("hierarchical flow: overlay has no root cluster"))?;

    // Leaf clusters (skip the root pseudo-cluster, which has no clients).
    let leaf_clusters: Vec<(String, Vec<String>, String)> = state
        .overlay
        .clusters
        .iter()
        .filter(|c| !c.clients.is_empty())
        .map(|c| (c.name.clone(), c.clients.clone(), c.workers[0].clone()))
        .collect();

    let plan = state.agg_plan();
    let mut cluster_aggs: Vec<ClientUpdate> = Vec::new();
    // Clusters run in parallel: the client phase costs the slowest cluster's
    // critical path (its clients' max finish + its uplink to the root).
    let mut clusters_phase = 0f64;
    for (cname, members, leaf_worker) in &leaf_clusters {
        let alive: Vec<String> = state.controller.alive(members, round);
        if alive.is_empty() {
            continue;
        }
        let cluster_topic = format!("client_params/{cname}");
        let updates_map = train_clients_to(
            state,
            round,
            &alive,
            |st, _| st.global.clone(),
            // The global broadcast physically travels root -> leaf -> client.
            {
                let root = root.clone();
                move |_: &JobState, _: &str| Some(("global_model".to_string(), root.clone()))
            },
            {
                let lw = leaf_worker.clone();
                move |_, _| Some(lw.clone())
            },
            |_| cluster_topic.clone(),
        )?;
        let updates: Vec<ClientUpdate> = updates_map.into_values().collect();
        if updates.is_empty() {
            // Every member overran the deadline: the barrier still waited
            // for them, so the cluster costs the full phase on the clock.
            clusters_phase = clusters_phase.max(state.last_phase_secs);
            continue;
        }
        let closs = mean_loss(&updates);
        // Leaf worker pulls its cluster members' uploads.
        let _ = deliver_round(state, &cluster_topic, round, leaf_worker);

        // Leaf aggregation (per-leaf derived stream — proposals must not
        // couple across clusters through a shared RNG).
        let mut agg_rng = state.round_rng(round).derive("agg", name_index(leaf_worker));
        let agg_vec = state.aggregate_updates(&updates, plan, &mut agg_rng)?;
        let agg: Arc<[f32]> = state.arena.store_vec(agg_vec);
        let weight: f64 = updates.iter().map(|u| u.weight).sum();
        // Leaf worker ships its cluster model upstream (extra hop = the
        // hierarchical bandwidth/CPU overhead of Fig 11); the payload shares
        // the aggregate's allocation.
        let payload = Payload::Params(agg.clone());
        let up_bytes = payload.wire_bytes();
        publish(state, "cluster_agg", leaf_worker, round, payload);
        let up_secs = state.net.price(leaf_worker, &root, up_bytes);
        clusters_phase = clusters_phase.max(state.last_phase_secs + up_secs);
        cluster_aggs.push(ClientUpdate {
            client: cname.clone(),
            params: agg,
            weight,
            extra: None,
            mean_loss: closs as f32,
        });
    }
    if cluster_aggs.is_empty() {
        bail!("round {round}: every cluster was empty");
    }

    // Root merge.
    let _ = deliver_round(state, "cluster_agg", round, &root);
    let refs: Vec<&[f32]> = cluster_aggs.iter().map(|u| u.params.as_ref()).collect();
    let weights: Vec<f64> = cluster_aggs.iter().map(|u| u.weight).collect();
    let merged = crate::aggregate::mean::weighted_mean_plan(&refs, &weights, plan)?;
    let global_before = state.global.clone();
    let next_global = state.strategy.post_round(&cluster_aggs, &global_before, merged);
    state.global = state.arena.store_vec(next_global);

    // Example-weighted over clusters (each cluster's loss is already
    // example-weighted over its members, and carries its total weight).
    let train_loss = mean_loss(&cluster_aggs);
    let (test_loss, test_accuracy) = state.evaluate(&state.global)?;
    let global = state.global.clone();
    Ok(scope.finish(
        state,
        round,
        train_loss,
        &global,
        test_loss,
        test_accuracy,
        clusters_phase,
    ))
}

/// FL+HC round (Briggs et al.): FedAvg until the clustering round, then one
/// model per client cluster.
pub fn clustered_round(state: &mut JobState, round: u64) -> Result<RoundMetrics> {
    let scope = RoundScope::begin(state);
    let mut rng = state.round_rng(round);

    let cluster_round = match &state.job.strategy {
        crate::strategy::StrategyKind::FlHc { cluster_round, .. } => *cluster_round,
        _ => bail!("clustered flow requires the flhc strategy"),
    };

    let plan = state.agg_plan();
    if state.clusters.is_none() {
        // Pre-clustering: behave like FedAvg, but watch for the clustering
        // round.
        let payload = Payload::Params(state.global.clone());
        publish(state, "global_model", LC, round, payload);

        let sampled = state.sample_clients(round);
        let updates_map = train_clients(state, round, &sampled, |st, _| st.global.clone())?;
        require_quorum(&updates_map, state, round)?;
        let updates: Vec<ClientUpdate> = updates_map.into_values().collect();
        let train_loss = mean_loss(&updates);
        let mut sim_round_secs = state.last_phase_secs;

        if round >= cluster_round {
            // Cluster clients by their local models (the paper's
            // "hierarchical clustering of client parameters").
            let kind = state.job.strategy.clone();
            let (n_clusters,) = match kind {
                crate::strategy::StrategyKind::FlHc { n_clusters, .. } => (n_clusters,),
                _ => unreachable!(),
            };
            let vectors: Vec<Vec<f32>> = updates.iter().map(|u| u.params.to_vec()).collect();
            let ids = crate::aggregate::cluster::agglomerative_clusters(
                &vectors,
                n_clusters,
                f64::INFINITY,
                crate::aggregate::cluster::Linkage::Average,
            );
            let mut assignment = BTreeMap::new();
            for (u, &cid) in updates.iter().zip(&ids) {
                assignment.insert(u.client.clone(), cid);
            }
            // Initialize each cluster model from its members.
            let mut models = BTreeMap::new();
            for cid in ids.iter().cloned().collect::<std::collections::BTreeSet<_>>() {
                let members: Vec<&ClientUpdate> = updates
                    .iter()
                    .zip(&ids)
                    .filter(|(_, &c)| c == cid)
                    .map(|(u, _)| u)
                    .collect();
                let refs: Vec<&[f32]> = members.iter().map(|u| u.params.as_ref()).collect();
                let ws: Vec<f64> = members.iter().map(|u| u.weight).collect();
                let model_vec = crate::aggregate::mean::weighted_mean_plan(&refs, &ws, plan)?;
                models.insert(cid, state.arena.store_vec(model_vec));
            }
            state
                .controller
                .emit(&format!("FL+HC: clustered clients into {} clusters", models.len()));
            state.clusters = Some(assignment);
            state.cluster_models = models;
        } else {
            let (winner, agg_secs) =
                aggregate_and_consensus(state, round, &updates, &mut rng)?;
            sim_round_secs += agg_secs;
            let global_before = state.global.clone();
            let next_global = state.strategy.post_round(&updates, &global_before, winner);
            state.global = state.arena.store_vec(next_global);
        }

        let (test_loss, test_accuracy) = clustered_eval(state)?;
        let global = state.global.clone();
        return Ok(scope.finish(
            state,
            round,
            train_loss,
            &global,
            test_loss,
            test_accuracy,
            sim_round_secs,
        ));
    }

    // Post-clustering: per-cluster FedAvg. Each cluster model is published
    // to its own topic and every client downloads the model it actually
    // trains from (metering the global broadcast here would be phantom
    // traffic — no client reads it).
    let primary = state.primary_worker();
    for (cid, model) in state.cluster_models.clone() {
        let payload = Payload::Params(model);
        publish(state, &format!("cluster_model/{cid}"), &primary, round, payload);
    }

    let assignment = state.clusters.clone().unwrap();
    let sampled = state.sample_clients(round);
    let updates_map = train_clients_to(
        state,
        round,
        &sampled,
        |st, name| {
            let cid = st.clusters.as_ref().unwrap().get(name).copied().unwrap_or(0);
            st.cluster_models
                .get(&cid)
                .cloned()
                .unwrap_or_else(|| st.global.clone())
        },
        {
            let primary = primary.clone();
            move |st: &JobState, name: &str| {
                let cid = st.clusters.as_ref().unwrap().get(name).copied().unwrap_or(0);
                st.cluster_models
                    .contains_key(&cid)
                    .then(|| (format!("cluster_model/{cid}"), primary.clone()))
            }
        },
        {
            let primary = primary.clone();
            move |_: &JobState, _: &str| Some(primary.clone())
        },
        |_| "client_params".to_string(),
    )?;
    require_quorum(&updates_map, state, round)?;
    // The primary worker pulls the uploads it re-clusters from (their wire
    // time lands here — there is no consensus pipeline in this branch).
    let _ = deliver_round(state, "client_params", round, &primary);
    let updates: Vec<ClientUpdate> = updates_map.into_values().collect();
    let train_loss = mean_loss(&updates);

    let cluster_ids: std::collections::BTreeSet<usize> =
        assignment.values().cloned().collect();
    for cid in cluster_ids {
        let members: Vec<&ClientUpdate> = updates
            .iter()
            .filter(|u| assignment.get(&u.client) == Some(&cid))
            .collect();
        if members.is_empty() {
            continue;
        }
        let refs: Vec<&[f32]> = members.iter().map(|u| u.params.as_ref()).collect();
        let ws: Vec<f64> = members.iter().map(|u| u.weight).collect();
        let model = crate::aggregate::mean::weighted_mean_plan(&refs, &ws, plan)?;
        let model = state.arena.store_vec(model);
        state.cluster_models.insert(cid, model);
    }

    let (test_loss, test_accuracy) = clustered_eval(state)?;
    let global = state.global.clone();
    let sim_round_secs = state.last_phase_secs;
    Ok(scope.finish(
        state,
        round,
        train_loss,
        &global,
        test_loss,
        test_accuracy,
        sim_round_secs,
    ))
}

/// FL+HC evaluation: example-weighted average over cluster models (falls
/// back to the single global model before clustering happens).
fn clustered_eval(state: &JobState) -> Result<(f64, f64)> {
    if state.cluster_models.is_empty() {
        return state.evaluate(&state.global);
    }
    let assignment = state.clusters.as_ref().unwrap();
    let mut loss = 0f64;
    let mut acc = 0f64;
    let mut total_w = 0f64;
    for (cid, model) in &state.cluster_models {
        let w: f64 = assignment
            .iter()
            .filter(|(_, c)| *c == cid)
            .map(|(name, _)| {
                state
                    .clients
                    .get(name)
                    .map(|n| n.n_examples as f64)
                    .unwrap_or(0.0)
            })
            .sum();
        let (l, a) = state.evaluate(model)?;
        loss += w * l;
        acc += w * a;
        total_w += w;
    }
    if total_w <= 0.0 {
        return state.evaluate(&state.global);
    }
    Ok((loss / total_w, acc / total_w))
}

/// Decentralized (Fedstellar-style) round: peers train locally, gossip,
/// merge. No central aggregator at all — and no global broadcast either:
/// every peer resumes its own local model (round 1 starts from the
/// seed-synchronized init that every node derives identically, so nothing
/// crosses the wire for model distribution).
pub fn decentralized_round(state: &mut JobState, round: u64) -> Result<RoundMetrics> {
    let scope = RoundScope::begin(state);

    let peers = state.sample_clients(round);
    if peers.is_empty() {
        bail!("round {round}: no live peers");
    }
    // Each peer continues from its own local model and uploads to its own
    // per-peer topic (gossip pulls are point-to-point).
    let updates_map = train_clients_to(
        state,
        round,
        &peers,
        |st, name| {
            st.clients
                .get(name)
                .and_then(|n| n.local_model.clone())
                .unwrap_or_else(|| st.global.clone())
        },
        |_, _| None,
        |_, _| None,
        |name| format!("peer_params/{name}"),
    )?;
    require_quorum(&updates_map, state, round)?;
    let train_loss = mean_loss(&updates_map.values().cloned().collect::<Vec<_>>());
    let train_phase = state.last_phase_secs;

    // Gossip: every peer pulls each neighbor's model (n·(n−1) transfers —
    // the decentralized bandwidth signature of Fig 8e/11e).
    let neighbors_k = match &state.job.strategy {
        crate::strategy::StrategyKind::Fedstellar { neighbors } => *neighbors,
        _ => 0,
    };
    let plan = state.agg_plan();
    let plan_gossip = if neighbors_k == 0 {
        crate::topology::gossip::full_exchange(&state.overlay)
    } else {
        let mut grng = state.round_rng(round).derive("gossip", 0);
        crate::topology::gossip::random_k(&state.overlay, neighbors_k, &mut grng)
    };

    // Gossip pulls are point-to-point: each peer fetches exactly the models
    // its plan names (mesh ⇒ n·(n−1) transfers, ring ⇒ 2n — the Fig 11e
    // bandwidth ordering comes straight from the plan), each priced over
    // the peer↔peer route. A pull hands the sender's allocation over — no
    // float copies on the fabric. Peers gossip concurrently, so the phase
    // costs the slowest peer's pull schedule.
    let mut merged_models: BTreeMap<String, Arc<[f32]>> = BTreeMap::new();
    let mut gossip_phase = 0f64;
    for (peer, pulls) in &plan_gossip.pulls {
        let Some(own) = updates_map.get(peer) else {
            continue; // faulted peer this round
        };
        let mut peer_secs = 0f64;
        let mut stack: Vec<&[f32]> = vec![own.params.as_ref()];
        for other in pulls {
            if let Some(u) = updates_map.get(other) {
                let (_msg, secs) =
                    deliver_latest(state, &format!("peer_params/{other}"), other, peer)?;
                peer_secs += secs;
                stack.push(u.params.as_ref());
            }
        }
        gossip_phase = gossip_phase.max(peer_secs);
        let weights = vec![1.0; stack.len()];
        let merged = crate::aggregate::mean::weighted_mean_plan(&stack, &weights, plan)?;
        merged_models.insert(peer.clone(), state.arena.store_vec(merged));
    }
    for (peer, model) in &merged_models {
        if let Some(node) = state.clients.get_mut(peer) {
            node.local_model = Some(model.clone());
        }
    }

    // Report on the uniform mean of peer models (the "virtual global").
    let refs: Vec<&[f32]> = merged_models.values().map(|m| m.as_ref()).collect();
    let weights = vec![1.0; refs.len()];
    let virtual_global = crate::aggregate::mean::weighted_mean_plan(&refs, &weights, plan)?;
    state.global = state.arena.store_vec(virtual_global);

    let (test_loss, test_accuracy) = state.evaluate(&state.global)?;
    let global = state.global.clone();
    Ok(scope.finish(
        state,
        round,
        train_loss,
        &global,
        test_loss,
        test_accuracy,
        train_phase + gossip_phase,
    ))
}

/// Example-weighted mean of the clients' local training losses: a
/// 1000-example client moves the number 1000× more than a 1-example client
/// (the unweighted mean let tiny shards swamp the series).
fn mean_loss(updates: &[ClientUpdate]) -> f64 {
    let total_w: f64 = updates.iter().map(|u| u.weight).sum();
    if updates.is_empty() || total_w <= 0.0 {
        return f64::NAN;
    }
    updates
        .iter()
        .map(|u| u.mean_loss as f64 * u.weight)
        .sum::<f64>()
        / total_w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::job::JobConfig;
    use crate::controller::sync::FaultPlan;
    use crate::runtime::pjrt::Runtime;

    #[test]
    fn name_index_numeric_suffix_is_stable() {
        // The historical fast path every seeded run depends on.
        assert_eq!(name_index("client_7"), 7);
        assert_eq!(name_index("worker_0"), 0);
        assert_eq!(name_index("peer_123"), 123);
    }

    #[test]
    fn name_index_distinct_for_anagram_names() {
        // `rsplit('_')` yields the non-numeric suffix "worker" for both, so
        // the old byte-sum fallback collided on these anagrams.
        let a = name_index("cluster12_worker");
        let b = name_index("cluster21_worker");
        assert_ne!(a, b, "anagram node names must get distinct RNG streams");
        // And distinct streams downstream.
        let root = Rng::seed_from(42);
        let mut ra = root.derive("client", a);
        let mut rb = root.derive("client", b);
        assert_ne!(ra.next_u64(), rb.next_u64());
        // Stable across calls.
        assert_eq!(name_index("cluster12_worker"), a);
    }

    fn upd(client: &str, weight: f64, loss: f32) -> ClientUpdate {
        ClientUpdate {
            client: client.to_string(),
            params: vec![0.0f32; 4].into(),
            weight,
            extra: None,
            mean_loss: loss,
        }
    }

    #[test]
    fn mean_loss_is_example_weighted() {
        // A 1-example straggler with a huge loss must barely move the mean
        // against a 1000-example client.
        let updates = vec![upd("tiny", 1.0, 100.0), upd("big", 1000.0, 1.0)];
        let m = mean_loss(&updates);
        let expect = (100.0 + 1000.0) / 1001.0;
        assert!((m - expect).abs() < 1e-9, "got {m}, want {expect}");
        assert!(m < 1.2, "tiny client dominated the mean: {m}");
        // Degenerate cases stay NaN.
        assert!(mean_loss(&[]).is_nan());
    }

    /// Satellite regression: a worker's aggregation proposal must be
    /// invariant to which *other* workers are alive (independent per-worker
    /// "agg" streams — dpfl consumes RNG in `aggregate`, so it would expose
    /// any coupling).
    #[test]
    fn worker_proposals_invariant_to_dropped_workers() {
        let mk_state = |faults: FaultPlan| {
            let rt = Runtime::shared("artifacts").unwrap();
            let mut job = JobConfig::default_cnn("dpfl");
            job.rounds = 1;
            job.dataset.n = 600;
            job.n_clients = 4;
            job.n_workers = 3;
            JobState::scaffold(rt, &job, faults).unwrap()
        };
        let mut full = mk_state(FaultPlan::none());
        let mut dropped = mk_state(FaultPlan::none().drop_in_round("worker_0", 1));

        let dim = full.backend.param_count;
        let updates: Vec<ClientUpdate> = (0..4)
            .map(|i| upd(&format!("client_{i}"), 100.0, 1.0))
            .map(|mut u| {
                u.params = vec![0.01 * (name_index(&u.client) + 1) as f32; dim].into();
                u
            })
            .collect();
        for st in [&mut full, &mut dropped] {
            for u in &updates {
                st.kv
                    .publish("client_params", &u.client, 1, Payload::Params(u.params.clone()));
            }
        }

        let props_full = worker_proposals(&mut full, 1, &updates).unwrap();
        let props_dropped = worker_proposals(&mut dropped, 1, &updates).unwrap();
        assert_eq!(props_full.len(), 3);
        assert_eq!(props_dropped.len(), 2);
        // worker_1 / worker_2 propose the same model whether or not
        // worker_0 is alive.
        assert_eq!(props_full[1].worker, props_dropped[0].worker);
        assert_eq!(props_full[1].hash, props_dropped[0].hash);
        assert_eq!(props_full[2].hash, props_dropped[1].hash);
        // And dpfl noise is genuinely per-worker (independent streams).
        assert_ne!(props_full[1].hash, props_full[2].hash);
    }
}
