//! The four round flows (standard / hierarchical / clustered /
//! decentralized), each implementing the per-round body of Algorithm 1 over
//! the KV store with full traffic metering.
//!
//! ## The parallel round engine
//!
//! Client-local training — the dominant cost of every round — runs on a
//! scoped worker pool sized by `JobConfig::parallelism`. Determinism (RQ6)
//! is preserved *by construction*, not by locking:
//!
//! 1. **Phase A (serial, client order):** starting models are resolved,
//!    downloads are metered and per-client RNG streams are derived — all in
//!    deterministic client order, before any thread is spawned.
//! 2. **Phase B (parallel):** clients train concurrently. Each task touches
//!    only its own node state and pre-derived RNG stream plus
//!    shared-immutable context (backend, strategy, broadcast state); the
//!    reference engine is bitwise-deterministic per call, so scheduling
//!    cannot influence any client's result.
//! 3. **Phase C (serial, client order):** uploads, traffic metering and
//!    controller stage transitions are committed in deterministic client
//!    order, regardless of which worker finished first.
//!
//! Consequently `parallelism: N` produces bitwise-identical model hashes
//! and byte counts to `parallelism: 1` (asserted by
//! `rust/tests/parallel_engine.rs`).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::chain::block::Tx;
use crate::consensus::Proposal;
use crate::controller::phases::{NodeStage, ProcessPhase};
use crate::kvstore::store::Payload;
use crate::metrics::report::RoundMetrics;
use crate::metrics::resources;
use crate::node::ClientNode;
use crate::orchestrator::setup::JobState;
use crate::runtime::backend::ModelBackend;
use crate::strategy::ctx::{ClientCtx, ClientUpdate};
use crate::strategy::Strategy;
use crate::util::hash;
use crate::util::json::Json;
use crate::util::rng::Rng;

const KV: &str = "kv_store";
const LC: &str = "logic_controller";

/// Publish with NetSim metering (sender -> broker).
fn publish(state: &mut JobState, topic: &str, sender: &str, round: u64, payload: Payload) {
    let bytes = payload.wire_bytes();
    state.kv.publish(topic, sender, round, payload);
    state.net.transfer(sender, KV, bytes);
}

/// Fetch-latest with NetSim metering (broker -> reader).
fn fetch_latest(state: &mut JobState, topic: &str, reader: &str) -> Result<crate::kvstore::store::Message> {
    let msg = state.kv.fetch_latest(topic, reader)?;
    state.net.transfer(KV, reader, msg.payload.wire_bytes());
    Ok(msg)
}

/// Fetch-round with NetSim metering.
fn fetch_round(
    state: &mut JobState,
    topic: &str,
    round: u64,
    reader: &str,
) -> Vec<crate::kvstore::store::Message> {
    let msgs = state.kv.fetch_round(topic, round, reader);
    for m in &msgs {
        state.net.transfer(KV, reader, m.payload.wire_bytes());
    }
    msgs
}

/// Round-metrics bookkeeping around a flow body.
struct RoundScope {
    t0: Instant,
    res0: resources::ResourceSnapshot,
    bytes0: u64,
    net0: f64,
}

impl RoundScope {
    fn begin(state: &JobState) -> RoundScope {
        RoundScope {
            t0: Instant::now(),
            res0: resources::snapshot(),
            bytes0: state.kv.total_bytes(),
            net0: state.net.total_secs(),
        }
    }

    fn finish(
        self,
        state: &JobState,
        round: u64,
        train_loss: f64,
        eval_model: &[f32],
        test_loss: f64,
        test_accuracy: f64,
    ) -> RoundMetrics {
        let wall = self.t0.elapsed().as_secs_f64();
        let res1 = resources::snapshot();
        RoundMetrics {
            round,
            test_accuracy,
            test_loss,
            train_loss,
            wall_secs: wall,
            cpu_pct: resources::cpu_util_pct(self.res0, res1, wall),
            rss_mib: res1.rss_mib,
            net_bytes: state.kv.total_bytes() - self.bytes0,
            sim_net_secs: state.net.total_secs() - self.net0,
            model_hash: hash::short_hash(eval_model),
        }
    }
}

/// One client's unit of parallel work: everything phase B needs, owned or
/// exclusively borrowed, so tasks can move to worker threads.
struct TrainTask<'a> {
    name: &'a str,
    start: Arc<[f32]>,
    rng: Rng,
    node: &'a mut ClientNode,
}

/// Pair every sampled client name with a mutable borrow of its node (the
/// borrows are disjoint — names are unique map keys).
fn collect_tasks<'a>(
    clients: &'a mut BTreeMap<String, ClientNode>,
    names: &'a [String],
    starts: Vec<Arc<[f32]>>,
    rngs: Vec<Rng>,
) -> Result<Vec<TrainTask<'a>>> {
    let index_of: BTreeMap<&str, usize> = names
        .iter()
        .enumerate()
        .map(|(i, n)| (n.as_str(), i))
        .collect();
    let mut nodes: Vec<Option<&'a mut ClientNode>> = Vec::new();
    nodes.resize_with(names.len(), || None);
    for (k, v) in clients.iter_mut() {
        if let Some(&i) = index_of.get(k.as_str()) {
            nodes[i] = Some(v);
        }
    }
    let mut tasks = Vec::with_capacity(names.len());
    for ((name, (start, rng)), node) in names
        .iter()
        .zip(starts.into_iter().zip(rngs))
        .zip(nodes)
    {
        tasks.push(TrainTask {
            name: name.as_str(),
            start,
            rng,
            node: node.ok_or_else(|| anyhow!("unknown client {name}"))?,
        });
    }
    Ok(tasks)
}

/// Phase B: run every task's local training, on a scoped worker pool when
/// `par > 1`. Results come back in task (= client) order; worker scheduling
/// cannot influence any value because each task reads only its own state
/// plus shared-immutable context.
fn train_tasks(
    backend: &ModelBackend,
    strategy: &dyn Strategy,
    extra_state: Option<&[f32]>,
    lr: f32,
    epochs: usize,
    tasks: &mut [TrainTask<'_>],
    par: usize,
) -> Vec<Result<ClientUpdate>> {
    let run_one = |t: &mut TrainTask<'_>| -> Result<ClientUpdate> {
        let mut ctx = ClientCtx {
            client: t.name,
            backend,
            batches: &t.node.batches,
            global: &t.start,
            extra_state,
            lr,
            local_epochs: epochs,
            n_examples: t.node.n_examples,
            state: &mut t.node.state,
            rng: &mut t.rng,
        };
        strategy.client_train(&mut ctx)
    };
    let workers = par.min(tasks.len()).max(1);
    if workers <= 1 {
        return tasks.iter_mut().map(run_one).collect();
    }
    let chunk = tasks.len().div_ceil(workers);
    std::thread::scope(|s| {
        let run_one = &run_one;
        let mut handles = Vec::with_capacity(workers);
        for slab in tasks.chunks_mut(chunk) {
            handles.push(s.spawn(move || slab.iter_mut().map(run_one).collect::<Vec<_>>()));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("client training worker panicked"))
            .collect()
    })
}

/// Local training for a set of clients, each starting from `start_of(name)`.
/// Returns updates keyed by client (BTreeMap => deterministic order).
/// `upload_topic_of` decides which KV topic each client uploads to (shared
/// topic for star flows; per-cluster for hierarchical; per-peer for gossip).
fn train_clients_to(
    state: &mut JobState,
    round: u64,
    names: &[String],
    start_of: impl Fn(&JobState, &str) -> Arc<[f32]>,
    upload_topic_of: impl Fn(&str) -> String,
) -> Result<BTreeMap<String, ClientUpdate>> {
    state.controller.set_phase(ProcessPhase::LocalLearning);
    state.controller.reset_stages(names, NodeStage::ReadyWithDataset);

    // Broadcast strategy extra state (e.g. SCAFFOLD's c_global) once.
    let extra_state = state.strategy.client_extra_state();
    if let Some(es) = &extra_state {
        let payload = Payload::params(es.clone());
        publish(state, "strategy_state", LC, round, payload);
    }

    let lr = state.job.train.learning_rate;
    let epochs = state.job.train.local_epochs;
    let par = state.parallelism();

    // Phase A (serial, deterministic client order): resolve starting models,
    // meter the phase-4 downloads, flip stages, derive RNG streams.
    let mut starts = Vec::with_capacity(names.len());
    let mut rngs = Vec::with_capacity(names.len());
    for name in names {
        let start = start_of(state, name);
        let _ = fetch_latest(state, "global_model", name)?;
        if extra_state.is_some() {
            let _ = fetch_latest(state, "strategy_state", name)?;
        }
        state.controller.update_stage(name, NodeStage::Busy)?;
        rngs.push(state.round_rng(round).derive("client", name_index(name)));
        starts.push(start);
    }

    // Phase B (parallel): local training on the worker pool.
    let results = {
        let backend = &state.backend;
        let strategy: &dyn Strategy = state.strategy.as_ref();
        let mut tasks = collect_tasks(&mut state.clients, names, starts, rngs)?;
        train_tasks(
            backend,
            strategy,
            extra_state.as_deref(),
            lr,
            epochs,
            &mut tasks,
            par,
        )
    };

    // Phase C (serial, deterministic client order): phase-1 uploads, traffic
    // metering and stage transitions — committed in client order no matter
    // which worker finished first. Publishing a model is an Arc refcount
    // bump; the floats trained in phase B are never copied again.
    let mut updates = BTreeMap::new();
    for (name, result) in names.iter().zip(results) {
        let update = result?;
        let topic = upload_topic_of(name);
        let payload = Payload::Params(update.params.clone());
        publish(state, &topic, name, round, payload);
        if let Some(extra) = &update.extra {
            let payload = Payload::Params(extra.clone());
            publish(state, "client_extra", name, round, payload);
        }
        state.controller.update_stage(name, NodeStage::Done)?;
        updates.insert(name.clone(), update);
    }

    state.controller.emit("Clients are waiting for next round.");
    state.controller.barrier(names, NodeStage::Done, round, 1)?;
    Ok(updates)
}

/// `train_clients_to` with the shared "client_params" upload topic (the
/// star-topology flows).
fn train_clients(
    state: &mut JobState,
    round: u64,
    names: &[String],
    start_of: impl Fn(&JobState, &str) -> Arc<[f32]>,
) -> Result<BTreeMap<String, ClientUpdate>> {
    train_clients_to(state, round, names, start_of, |_| "client_params".to_string())
}

fn name_index(name: &str) -> u64 {
    name.rsplit('_')
        .next()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or_else(|| name.bytes().map(|b| b as u64).sum())
}

/// Worker-side aggregation + §2.5 consensus pipeline. Returns the winning
/// proposal's parameters and the per-worker proposals.
fn aggregate_and_consensus(
    state: &mut JobState,
    round: u64,
    updates: &[ClientUpdate],
    rng: &mut Rng,
) -> Result<Vec<f32>> {
    state.controller.set_phase(ProcessPhase::ModelAggregation);
    let worker_names = state.overlay.workers();
    let alive = state.controller.alive(&worker_names, round);
    if alive.is_empty() {
        bail!("round {round}: no live workers");
    }
    state.controller.reset_stages(&alive, NodeStage::ReadyWithDataset);
    let plan = state.agg_plan();

    let mut proposals: Vec<Proposal> = Vec::new();
    for wname in &alive {
        state.controller.update_stage(wname, NodeStage::Busy)?;
        // Each worker pulls the full client-parameter set (phase 1 of the
        // consensus pipeline: local parameter sharing to *all* workers).
        // Zero-copy: every message hands back the client's own allocation.
        let msgs = fetch_round(state, "client_params", round, wname);
        if msgs.len() != updates.len() {
            // KV store is the transport; the counts must agree.
            bail!(
                "worker {wname}: saw {} client messages, expected {}",
                msgs.len(),
                updates.len()
            );
        }
        let agg = state
            .strategy
            .aggregate(updates, &state.global, plan, rng)?;
        let agg = {
            let worker = state
                .workers
                .get(wname)
                .ok_or_else(|| anyhow!("unknown worker {wname}"))?;
            let mut poison_rng = state.round_rng(round).derive("worker", name_index(wname));
            worker.transform_aggregate(agg, &mut poison_rng)
        };
        // Phase 2: aggregated parameter voting — share the hash.
        let prop = Proposal::new(wname.clone(), agg);
        let payload = Payload::Text(prop.hash.clone());
        publish(state, "agg_votes", wname, round, payload);
        state.controller.update_stage(wname, NodeStage::Done)?;
        proposals.push(prop);
    }
    state.controller.emit("Workers busy in model aggregation.");
    // Every worker reads every other worker's vote (phase 2 traffic).
    for wname in &alive {
        let _ = fetch_round(state, "agg_votes", round, wname);
    }
    state
        .controller
        .barrier(&alive, NodeStage::Done, round, 1)?;
    state.controller.emit("Received aggregated params");

    // Blockchain hooks: record hashes; optionally decide on-chain.
    if let Some(chain) = state.chain.as_mut() {
        for p in &proposals {
            chain.submit_tx(Tx::new(
                &p.worker,
                "param_verify",
                "record",
                Json::obj(vec![
                    ("round", Json::from(round as usize)),
                    ("hash", Json::from(p.hash.as_str())),
                ]),
            ))?;
            if state.job.consensus.on_chain {
                chain.submit_tx(Tx::new(
                    &p.worker,
                    "consensus",
                    "propose",
                    Json::obj(vec![
                        ("round", Json::from(round as usize)),
                        ("hash", Json::from(p.hash.as_str())),
                    ]),
                ))?;
            }
        }
    }

    // Phase 3: final global parameter setting.
    let winner_idx = if state.job.consensus.on_chain {
        let chain = state.chain.as_mut().unwrap();
        let d = chain.query(
            "consensus",
            "decide",
            &Json::obj(vec![("round", Json::from(round as usize))]),
        )?;
        let win_hash = d
            .get("hash")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("on-chain consensus returned no hash"))?;
        proposals
            .iter()
            .position(|p| p.hash == win_hash)
            .ok_or_else(|| anyhow!("winning hash not among proposals"))?
    } else {
        let decision = state.consensus.decide(&proposals, rng)?;
        decision.winner
    };

    // Reputation + provenance on chain.
    if let Some(chain) = state.chain.as_mut() {
        let win_hash = proposals[winner_idx].hash.clone();
        for p in &proposals {
            let method = if p.hash == win_hash { "reward" } else { "penalize" };
            chain.submit_tx(Tx::new(
                LC,
                "reputation",
                method,
                Json::obj(vec![("node", Json::from(p.worker.as_str()))]),
            ))?;
        }
        chain.submit_tx(Tx::new(
            LC,
            "provenance",
            "record",
            Json::obj(vec![
                ("round", Json::from(round as usize)),
                ("hash", Json::from(win_hash.as_str())),
            ]),
        ))?;
        chain.seal_block()?;
    }

    Ok(proposals.into_iter().nth(winner_idx).unwrap().params)
}

/// Standard client-server round (Fig 8/9/10): train -> aggregate ->
/// consensus -> distribute.
pub fn standard_round(state: &mut JobState, round: u64) -> Result<RoundMetrics> {
    let scope = RoundScope::begin(state);
    let mut rng = state.round_rng(round);

    // Phase 4 (of the previous round): distribute the current global model
    // (an Arc handle — the broadcast is a refcount bump).
    let payload = Payload::Params(state.global.clone());
    publish(state, "global_model", LC, round, payload);

    let sampled = state.sample_clients(round);
    if sampled.is_empty() {
        bail!("round {round}: no live clients");
    }
    let updates_map = train_clients(state, round, &sampled, |st, _| st.global.clone())?;
    let updates: Vec<ClientUpdate> = updates_map.into_values().collect();
    let train_loss = mean_loss(&updates);

    let winner = aggregate_and_consensus(state, round, &updates, &mut rng)?;
    let global_before = state.global.clone();
    state.global = state
        .strategy
        .post_round(&updates, &global_before, winner)
        .into();

    let (test_loss, test_accuracy) = state.evaluate(&state.global)?;
    let global = state.global.clone();
    Ok(scope.finish(state, round, train_loss, &global, test_loss, test_accuracy))
}

/// Hierarchical round (Fig 11): leaf-cluster aggregation, then root merge.
pub fn hierarchical_round(state: &mut JobState, round: u64) -> Result<RoundMetrics> {
    let scope = RoundScope::begin(state);
    let mut rng = state.round_rng(round);

    let payload = Payload::Params(state.global.clone());
    publish(state, "global_model", LC, round, payload);

    // Leaf clusters (skip the root pseudo-cluster, which has no clients).
    let leaf_clusters: Vec<(String, Vec<String>, String)> = state
        .overlay
        .clusters
        .iter()
        .filter(|c| !c.clients.is_empty())
        .map(|c| (c.name.clone(), c.clients.clone(), c.workers[0].clone()))
        .collect();

    let plan = state.agg_plan();
    let mut cluster_aggs: Vec<ClientUpdate> = Vec::new();
    let mut losses = Vec::new();
    for (cname, members, leaf_worker) in &leaf_clusters {
        let alive: Vec<String> = state.controller.alive(members, round);
        if alive.is_empty() {
            continue;
        }
        let cluster_topic = format!("client_params/{cname}");
        let updates_map = train_clients_to(
            state,
            round,
            &alive,
            |st, _| st.global.clone(),
            |_| cluster_topic.clone(),
        )?;
        let updates: Vec<ClientUpdate> = updates_map.into_values().collect();
        losses.push(mean_loss(&updates));
        // Leaf worker pulls its cluster members' uploads.
        let _ = fetch_round(state, &cluster_topic, round, leaf_worker);

        // Leaf aggregation.
        let agg: Arc<[f32]> = state
            .strategy
            .aggregate(&updates, &state.global, plan, &mut rng)?
            .into();
        let weight: f64 = updates.iter().map(|u| u.weight).sum();
        // Leaf worker ships its cluster model upstream (extra hop = the
        // hierarchical bandwidth/CPU overhead of Fig 11); the payload shares
        // the aggregate's allocation.
        let payload = Payload::Params(agg.clone());
        publish(state, "cluster_agg", leaf_worker, round, payload);
        cluster_aggs.push(ClientUpdate {
            client: cname.clone(),
            params: agg,
            weight,
            extra: None,
            mean_loss: *losses.last().unwrap() as f32,
        });
    }
    if cluster_aggs.is_empty() {
        bail!("round {round}: every cluster was empty");
    }

    // Root merge.
    let root = "root_worker".to_string();
    let _ = fetch_round(state, "cluster_agg", round, &root);
    let refs: Vec<&[f32]> = cluster_aggs.iter().map(|u| u.params.as_ref()).collect();
    let weights: Vec<f64> = cluster_aggs.iter().map(|u| u.weight).collect();
    let merged = crate::aggregate::mean::weighted_mean_plan(&refs, &weights, plan)?;
    let global_before = state.global.clone();
    state.global = state
        .strategy
        .post_round(&cluster_aggs, &global_before, merged)
        .into();

    let train_loss = crate::util::stats::mean(&losses);
    let (test_loss, test_accuracy) = state.evaluate(&state.global)?;
    let global = state.global.clone();
    Ok(scope.finish(state, round, train_loss, &global, test_loss, test_accuracy))
}

/// FL+HC round (Briggs et al.): FedAvg until the clustering round, then one
/// model per client cluster.
pub fn clustered_round(state: &mut JobState, round: u64) -> Result<RoundMetrics> {
    let scope = RoundScope::begin(state);
    let mut rng = state.round_rng(round);

    let cluster_round = match &state.job.strategy {
        crate::strategy::StrategyKind::FlHc { cluster_round, .. } => *cluster_round,
        _ => bail!("clustered flow requires the flhc strategy"),
    };

    let payload = Payload::Params(state.global.clone());
    publish(state, "global_model", LC, round, payload);

    let plan = state.agg_plan();
    if state.clusters.is_none() {
        // Pre-clustering: behave like FedAvg, but watch for the clustering
        // round.
        let sampled = state.sample_clients(round);
        let updates_map = train_clients(state, round, &sampled, |st, _| st.global.clone())?;
        let updates: Vec<ClientUpdate> = updates_map.into_values().collect();
        let train_loss = mean_loss(&updates);

        if round >= cluster_round {
            // Cluster clients by their local models (the paper's
            // "hierarchical clustering of client parameters").
            let kind = state.job.strategy.clone();
            let (n_clusters,) = match kind {
                crate::strategy::StrategyKind::FlHc { n_clusters, .. } => (n_clusters,),
                _ => unreachable!(),
            };
            let vectors: Vec<Vec<f32>> = updates.iter().map(|u| u.params.to_vec()).collect();
            let ids = crate::aggregate::cluster::agglomerative_clusters(
                &vectors,
                n_clusters,
                f64::INFINITY,
                crate::aggregate::cluster::Linkage::Average,
            );
            let mut assignment = BTreeMap::new();
            for (u, &cid) in updates.iter().zip(&ids) {
                assignment.insert(u.client.clone(), cid);
            }
            // Initialize each cluster model from its members.
            let mut models = BTreeMap::new();
            for cid in ids.iter().cloned().collect::<std::collections::BTreeSet<_>>() {
                let members: Vec<&ClientUpdate> = updates
                    .iter()
                    .zip(&ids)
                    .filter(|(_, &c)| c == cid)
                    .map(|(u, _)| u)
                    .collect();
                let refs: Vec<&[f32]> = members.iter().map(|u| u.params.as_ref()).collect();
                let ws: Vec<f64> = members.iter().map(|u| u.weight).collect();
                let model: Arc<[f32]> =
                    crate::aggregate::mean::weighted_mean_plan(&refs, &ws, plan)?.into();
                models.insert(cid, model);
            }
            state
                .controller
                .emit(&format!("FL+HC: clustered clients into {} clusters", models.len()));
            state.clusters = Some(assignment);
            state.cluster_models = models;
        } else {
            let winner = aggregate_and_consensus(state, round, &updates, &mut rng)?;
            let global_before = state.global.clone();
            state.global = state
                .strategy
                .post_round(&updates, &global_before, winner)
                .into();
        }

        let (test_loss, test_accuracy) = clustered_eval(state)?;
        let global = state.global.clone();
        return Ok(scope.finish(state, round, train_loss, &global, test_loss, test_accuracy));
    }

    // Post-clustering: per-cluster FedAvg.
    let assignment = state.clusters.clone().unwrap();
    let sampled = state.sample_clients(round);
    let updates_map = train_clients(state, round, &sampled, |st, name| {
        let cid = st.clusters.as_ref().unwrap().get(name).copied().unwrap_or(0);
        st.cluster_models
            .get(&cid)
            .cloned()
            .unwrap_or_else(|| st.global.clone())
    })?;
    let updates: Vec<ClientUpdate> = updates_map.into_values().collect();
    let train_loss = mean_loss(&updates);

    let cluster_ids: std::collections::BTreeSet<usize> =
        assignment.values().cloned().collect();
    for cid in cluster_ids {
        let members: Vec<&ClientUpdate> = updates
            .iter()
            .filter(|u| assignment.get(&u.client) == Some(&cid))
            .collect();
        if members.is_empty() {
            continue;
        }
        let refs: Vec<&[f32]> = members.iter().map(|u| u.params.as_ref()).collect();
        let ws: Vec<f64> = members.iter().map(|u| u.weight).collect();
        let model = crate::aggregate::mean::weighted_mean_plan(&refs, &ws, plan)?;
        state.cluster_models.insert(cid, model.into());
    }

    let (test_loss, test_accuracy) = clustered_eval(state)?;
    let global = state.global.clone();
    Ok(scope.finish(state, round, train_loss, &global, test_loss, test_accuracy))
}

/// FL+HC evaluation: example-weighted average over cluster models (falls
/// back to the single global model before clustering happens).
fn clustered_eval(state: &JobState) -> Result<(f64, f64)> {
    if state.cluster_models.is_empty() {
        return state.evaluate(&state.global);
    }
    let assignment = state.clusters.as_ref().unwrap();
    let mut loss = 0f64;
    let mut acc = 0f64;
    let mut total_w = 0f64;
    for (cid, model) in &state.cluster_models {
        let w: f64 = assignment
            .iter()
            .filter(|(_, c)| *c == cid)
            .map(|(name, _)| {
                state
                    .clients
                    .get(name)
                    .map(|n| n.n_examples as f64)
                    .unwrap_or(0.0)
            })
            .sum();
        let (l, a) = state.evaluate(model)?;
        loss += w * l;
        acc += w * a;
        total_w += w;
    }
    if total_w <= 0.0 {
        return state.evaluate(&state.global);
    }
    Ok((loss / total_w, acc / total_w))
}

/// Decentralized (Fedstellar-style) round: peers train locally, gossip,
/// merge. No central aggregator at all.
pub fn decentralized_round(state: &mut JobState, round: u64) -> Result<RoundMetrics> {
    let scope = RoundScope::begin(state);

    let payload = Payload::Params(state.global.clone());
    publish(state, "global_model", LC, round, payload);

    let peers = state.sample_clients(round);
    if peers.is_empty() {
        bail!("round {round}: no live peers");
    }
    // Each peer continues from its own local model and uploads to its own
    // per-peer topic (gossip pulls are point-to-point).
    let updates_map = train_clients_to(
        state,
        round,
        &peers,
        |st, name| {
            st.clients
                .get(name)
                .and_then(|n| n.local_model.clone())
                .unwrap_or_else(|| st.global.clone())
        },
        |name| format!("peer_params/{name}"),
    )?;
    let train_loss = mean_loss(&updates_map.values().cloned().collect::<Vec<_>>());

    // Gossip: every peer pulls each neighbor's model (n·(n−1) transfers —
    // the decentralized bandwidth signature of Fig 8e/11e).
    let neighbors_k = match &state.job.strategy {
        crate::strategy::StrategyKind::Fedstellar { neighbors } => *neighbors,
        _ => 0,
    };
    let plan = state.agg_plan();
    let plan_gossip = if neighbors_k == 0 {
        crate::topology::gossip::full_exchange(&state.overlay)
    } else {
        let mut grng = state.round_rng(round).derive("gossip", 0);
        crate::topology::gossip::random_k(&state.overlay, neighbors_k, &mut grng)
    };

    // Gossip pulls are point-to-point: each peer fetches exactly the models
    // its plan names (mesh ⇒ n·(n−1) transfers, ring ⇒ 2n — the Fig 11e
    // bandwidth ordering comes straight from the plan). A pull hands the
    // sender's allocation over — no float copies on the fabric.
    let mut merged_models: BTreeMap<String, Arc<[f32]>> = BTreeMap::new();
    for (peer, pulls) in &plan_gossip.pulls {
        let Some(own) = updates_map.get(peer) else {
            continue; // faulted peer this round
        };
        let mut stack: Vec<&[f32]> = vec![own.params.as_ref()];
        for other in pulls {
            if let Some(u) = updates_map.get(other) {
                let _ = fetch_latest(state, &format!("peer_params/{other}"), peer);
                stack.push(u.params.as_ref());
            }
        }
        let weights = vec![1.0; stack.len()];
        let merged = crate::aggregate::mean::weighted_mean_plan(&stack, &weights, plan)?;
        merged_models.insert(peer.clone(), merged.into());
    }
    for (peer, model) in &merged_models {
        if let Some(node) = state.clients.get_mut(peer) {
            node.local_model = Some(model.clone());
        }
    }

    // Report on the uniform mean of peer models (the "virtual global").
    let refs: Vec<&[f32]> = merged_models.values().map(|m| m.as_ref()).collect();
    let weights = vec![1.0; refs.len()];
    state.global =
        crate::aggregate::mean::weighted_mean_plan(&refs, &weights, plan)?.into();

    let (test_loss, test_accuracy) = state.evaluate(&state.global)?;
    let global = state.global.clone();
    Ok(scope.finish(state, round, train_loss, &global, test_loss, test_accuracy))
}

fn mean_loss(updates: &[ClientUpdate]) -> f64 {
    if updates.is_empty() {
        return f64::NAN;
    }
    updates.iter().map(|u| u.mean_loss as f64).sum::<f64>() / updates.len() as f64
}
