//! Round-buffer arena: recycles the per-round `Arc<[f32]>` parameter
//! allocations (client updates, worker proposals, cluster/peer/global
//! models) instead of re-allocating `n_clients × dim` floats every round.
//!
//! ## Mechanism
//!
//! The arena keeps a bounded pool of `Arc<[f32]>` buffers per dimension and
//! always retains one reference of its own. A buffer is *free* exactly when
//! its strong count is 1 — every downstream holder (KV-store messages,
//! proposals, the previous round's model) has dropped it — at which point
//! [`RoundArena::store`] may overwrite it in place via `Arc::get_mut` and
//! hand out a fresh clone. Because the uniqueness check and the removal
//! from the pool happen under one lock, and the pool holds the only
//! reference at that moment, the overwrite is race-free by construction.
//!
//! `Arc<[f32]>::from(vec)` must copy anyway (the refcount header is inline,
//! so the `Vec` allocation can never be adopted); `store` pays that same
//! copy but skips the allocation — which, for round-sized buffers, is the
//! page-faulting part. In steady state a run allocates each distinct buffer
//! shape once and then recycles it for the rest of the campaign.
//!
//! Determinism: the arena only ever changes *where* bytes land, never what
//! they are — `store` copies the caller's fully-computed values into a
//! buffer with no other observers. Model hashes are pinned unchanged by the
//! parallel-engine and agg-kernel suites.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Buffers retained per distinct dimension. Bounds the arena at
/// `O(shapes × cap × dim)` floats even when downstream holders never
/// release (a full pool of busy buffers degrades to plain allocation).
const POOL_CAP_PER_DIM: usize = 64;

/// Cumulative arena counters (exposed for the `agg_kernel/arena` bench
/// series and the scale diagnostics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// `store` calls satisfied by overwriting a recycled buffer.
    pub reused: u64,
    /// `store` calls that had to allocate (cold pool or all buffers busy).
    pub allocated: u64,
}

/// A shared pool of round-sized parameter buffers. All methods take
/// `&self`; the arena is `Sync` and safe to call from the round engine's
/// worker threads.
pub struct RoundArena {
    /// `None` = pass-through mode (the `arena: false` job knob): every
    /// `store` allocates, nothing is retained.
    pools: Option<Mutex<BTreeMap<usize, Vec<Arc<[f32]>>>>>,
    reused: AtomicU64,
    allocated: AtomicU64,
}

impl Default for RoundArena {
    fn default() -> RoundArena {
        RoundArena::new()
    }
}

impl RoundArena {
    pub fn new() -> RoundArena {
        RoundArena {
            pools: Some(Mutex::new(BTreeMap::new())),
            reused: AtomicU64::new(0),
            allocated: AtomicU64::new(0),
        }
    }

    /// An arena that never recycles — `store` degenerates to
    /// `Arc::from(src)`. The `arena: false` escape hatch.
    pub fn disabled() -> RoundArena {
        RoundArena {
            pools: None,
            reused: AtomicU64::new(0),
            allocated: AtomicU64::new(0),
        }
    }

    /// Copy `src` into a shared buffer, recycling a free pooled allocation
    /// of the same dimension when one exists. Drop-in for
    /// `Arc::<[f32]>::from(src)` (same copy, minus the allocation on a
    /// pool hit).
    pub fn store(&self, src: &[f32]) -> Arc<[f32]> {
        let Some(pools) = &self.pools else {
            self.allocated.fetch_add(1, Ordering::Relaxed);
            return Arc::from(src);
        };
        let dim = src.len();
        let recycled = {
            let mut pools = pools.lock().unwrap();
            let pool = pools.entry(dim).or_default();
            pool.iter()
                .position(|b| Arc::strong_count(b) == 1)
                .map(|i| pool.swap_remove(i))
        };
        match recycled {
            Some(mut buf) => {
                // Unique by the check above; nothing else can clone it —
                // the pool held the only reference and we removed it under
                // the lock.
                Arc::get_mut(&mut buf)
                    .expect("pooled buffer with strong_count 1 is unique")
                    .copy_from_slice(src);
                let out = buf.clone();
                pools.lock().unwrap().entry(dim).or_default().push(buf);
                self.reused.fetch_add(1, Ordering::Relaxed);
                out
            }
            None => {
                let buf: Arc<[f32]> = Arc::from(src);
                let mut pools = pools.lock().unwrap();
                let pool = pools.entry(dim).or_default();
                if dim > 0 && pool.len() < POOL_CAP_PER_DIM {
                    pool.push(buf.clone());
                }
                self.allocated.fetch_add(1, Ordering::Relaxed);
                buf
            }
        }
    }

    /// [`RoundArena::store`] for an owned vector (the common
    /// `Vec<f32> → Arc<[f32]>` conversion sites in the round flows).
    pub fn store_vec(&self, src: Vec<f32>) -> Arc<[f32]> {
        self.store(&src)
    }

    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            reused: self.reused.load(Ordering::Relaxed),
            allocated: self.allocated.load(Ordering::Relaxed),
        }
    }

    /// Buffers currently retained (free + busy), across all dimensions.
    pub fn pooled(&self) -> usize {
        match &self.pools {
            Some(pools) => pools.lock().unwrap().values().map(Vec::len).sum(),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_round_trips_values_bitwise() {
        let arena = RoundArena::new();
        let v: Vec<f32> = (0..1000).map(|i| i as f32 * 0.25 - 7.0).collect();
        let a = arena.store(&v);
        assert_eq!(&a[..], &v[..]);
        let w: Vec<f32> = v.iter().map(|x| x * -3.0).collect();
        let b = arena.store_vec(w.clone());
        assert_eq!(&b[..], &w[..]);
        // Distinct live buffers never alias.
        assert_ne!(&a[..], &b[..]);
    }

    #[test]
    fn buffers_recycle_once_released() {
        let arena = RoundArena::new();
        let v = vec![1.0f32; 256];
        let a = arena.store(&v);
        let first_ptr = a.as_ptr();
        assert_eq!(arena.stats(), ArenaStats { reused: 0, allocated: 1 });

        // Still held: the second store must not clobber it.
        let b = arena.store(&vec![2.0f32; 256]);
        assert_eq!(arena.stats().allocated, 2);
        assert_eq!(a[0], 1.0);

        // Release both; the next store reuses one in place.
        drop(a);
        drop(b);
        let c = arena.store(&vec![3.0f32; 256]);
        assert_eq!(arena.stats().reused, 1);
        assert!(
            c.as_ptr() == first_ptr || arena.pooled() == 2,
            "reuse must come from the pool"
        );
        assert!(c.iter().all(|&x| x == 3.0));
    }

    #[test]
    fn distinct_dims_never_cross_pollinate() {
        let arena = RoundArena::new();
        let a = arena.store(&vec![1.0f32; 8]);
        drop(a);
        let b = arena.store(&vec![2.0f32; 16]);
        assert_eq!(b.len(), 16);
        assert_eq!(arena.stats().reused, 0, "8-dim buffer can't serve 16-dim");
    }

    #[test]
    fn pool_is_bounded_under_leaky_holders() {
        let arena = RoundArena::new();
        // Hold every buffer so none ever frees.
        let held: Vec<_> = (0..POOL_CAP_PER_DIM + 40)
            .map(|i| arena.store(&vec![i as f32; 32]))
            .collect();
        assert_eq!(arena.pooled(), POOL_CAP_PER_DIM, "pool must stay bounded");
        assert_eq!(held.len(), POOL_CAP_PER_DIM + 40);
    }

    #[test]
    fn disabled_arena_is_pass_through() {
        let arena = RoundArena::disabled();
        let a = arena.store(&vec![5.0f32; 64]);
        drop(a);
        let b = arena.store(&vec![6.0f32; 64]);
        assert_eq!(b[0], 6.0);
        assert_eq!(arena.stats().reused, 0);
        assert_eq!(arena.pooled(), 0);
    }

    #[test]
    fn empty_buffers_are_legal_and_unpooled() {
        let arena = RoundArena::new();
        let a = arena.store(&[]);
        assert!(a.is_empty());
        drop(a);
        let b = arena.store(&[]);
        assert!(b.is_empty());
    }

    #[test]
    fn concurrent_stores_keep_buffers_disjoint() {
        let arena = RoundArena::new();
        std::thread::scope(|s| {
            for t in 0..8 {
                let arena = &arena;
                s.spawn(move || {
                    for i in 0..50 {
                        let fill = (t * 1000 + i) as f32;
                        let buf = arena.store(&vec![fill; 512]);
                        // The clone we hold must never be overwritten by a
                        // concurrent store.
                        assert!(buf.iter().all(|&x| x == fill));
                        drop(buf);
                    }
                });
            }
        });
        let s = arena.stats();
        assert_eq!(s.reused + s.allocated, 400);
        assert!(s.reused > 0, "released buffers must recycle");
    }
}
