//! In-process pub-sub key-value store.
//!
//! Nodes publish their state (model parameters, control variates, votes,
//! hashes) under topic keys; subscribers fetch them. The store is the single
//! communication fabric of the simulation — every byte that would cross the
//! network in a real FLsim deployment passes through `publish`/`fetch` and
//! is metered per node, which is what the paper's bandwidth plots report.
//!
//! ## Zero-copy fabric
//!
//! Parameter payloads are `Arc<[f32]>`: publishing, fetching and fanning a
//! model out to every worker are refcount bumps, never float copies. Before
//! this, a 1000-client × 1e5-parameter round cloned ~800 MB of floats
//! through the broker per fetch fan-out; now the broker moves pointers and
//! the *metering* still charges the full logical wire volume (the simulated
//! network cost model is unchanged).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::aggregate::compress::CompressedUpdate;

/// What a node can publish.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// A flat model-parameter vector (or any other f32 state), shared
    /// zero-copy between publisher, broker and all readers.
    Params(Arc<[f32]>),
    /// A channel-compressed model update: the broker moves the handle, the
    /// metering charges the *compressed* wire volume — this is what makes
    /// `net_bytes`/`sim_round_secs` honest under `channel.compress`.
    Compressed(Arc<CompressedUpdate>),
    /// An arbitrary small string (hash votes, signals).
    Text(String),
    /// A scalar (e.g. example counts for weighted aggregation).
    Scalar(f64),
    /// Content-free payload of a given body size: protocol traffic whose
    /// bytes matter but whose contents the simulation never inspects
    /// (secure-aggregation mask shares).
    Opaque(u64),
}

impl Payload {
    /// Parameter payload from anything `Arc<[f32]>`-convertible (an owned
    /// `Vec<f32>` converts without an extra copy beyond the one-time move).
    pub fn params(data: impl Into<Arc<[f32]>>) -> Payload {
        Payload::Params(data.into())
    }

    /// Wire size in bytes (f32 = 4B; text = utf-8 len; scalar = 8B) plus a
    /// fixed 64-byte envelope (topic, sender, round — the REST/JSON framing
    /// the paper's deployment would pay, flat-rated).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Params(p) => 64 + (p.len() * 4) as u64,
            // CompressedUpdate::wire_bytes already includes its own 64-byte
            // envelope — don't charge the framing twice.
            Payload::Compressed(c) => c.wire_bytes(),
            Payload::Text(s) => 64 + s.len() as u64,
            Payload::Scalar(_) => 64 + 8,
            Payload::Opaque(body) => 64 + body,
        }
    }

    pub fn as_params(&self) -> Result<&[f32]> {
        match self {
            Payload::Params(p) => Ok(p),
            _ => Err(anyhow!("payload is not Params")),
        }
    }

    /// Shared handle to a parameter payload (refcount bump, no copy).
    pub fn params_arc(&self) -> Result<Arc<[f32]>> {
        match self {
            Payload::Params(p) => Ok(p.clone()),
            _ => Err(anyhow!("payload is not Params")),
        }
    }

    pub fn as_text(&self) -> Result<&str> {
        match self {
            Payload::Text(t) => Ok(t),
            _ => Err(anyhow!("payload is not Text")),
        }
    }

    pub fn as_scalar(&self) -> Result<f64> {
        match self {
            Payload::Scalar(s) => Ok(*s),
            _ => Err(anyhow!("payload is not Scalar")),
        }
    }

    pub fn as_compressed(&self) -> Result<&CompressedUpdate> {
        match self {
            Payload::Compressed(c) => Ok(c),
            _ => Err(anyhow!("payload is not Compressed")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Message {
    pub topic: String,
    pub sender: String,
    pub round: u64,
    pub payload: Payload,
}

/// Per-node traffic accounting.
#[derive(Clone, Debug, Default)]
pub struct Traffic {
    pub bytes_out: u64,
    pub bytes_in: u64,
    pub msgs_out: u64,
    pub msgs_in: u64,
}

/// FNV-1a — the shard router (cheap, stable, and already the hash the RNG
/// purpose-derivation uses elsewhere in the codebase).
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[derive(Debug, Default)]
struct TopicShard {
    topics: BTreeMap<String, Vec<Message>>,
}

#[derive(Debug, Default)]
struct TrafficShard {
    nodes: BTreeMap<String, Traffic>,
}

/// The broker: topics are partitioned into independently-locked shards
/// (routed by topic-name hash), per-node traffic accounting into its own
/// shard set (routed by node name), and the global byte counter is atomic —
/// so 10k+ concurrent publishes from a worker pool contend only when they
/// hit the same shard, never on one store-wide lock.
///
/// Metering is unchanged from the single-map store: every publish charges
/// the sender's egress, every metered fetch the reader's ingress, and
/// `total_bytes` is their exact sum (u64 adds commute, so totals are
/// schedule-independent — the RQ6 contract holds under any interleaving of
/// commutative meter updates; the orchestrator's serial commit phases keep
/// message *ordering* deterministic on top).
#[derive(Debug)]
pub struct KvStore {
    topic_shards: Vec<Mutex<TopicShard>>,
    traffic_shards: Vec<Mutex<TrafficShard>>,
    total_bytes: AtomicU64,
}

impl Default for KvStore {
    fn default() -> KvStore {
        KvStore::new()
    }
}

impl KvStore {
    /// Default shard count: enough that a worker pool on any reasonable
    /// host rarely collides, cheap enough to scan for the aggregate views.
    const DEFAULT_SHARDS: usize = 64;

    pub fn new() -> KvStore {
        KvStore::with_shards(KvStore::DEFAULT_SHARDS)
    }

    /// A store with an explicit shard count (≥ 1); `new` picks the default.
    pub fn with_shards(n_shards: usize) -> KvStore {
        let n = n_shards.max(1);
        KvStore {
            topic_shards: (0..n).map(|_| Mutex::new(TopicShard::default())).collect(),
            traffic_shards: (0..n).map(|_| Mutex::new(TrafficShard::default())).collect(),
            total_bytes: AtomicU64::new(0),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.topic_shards.len()
    }

    fn topic_shard(&self, topic: &str) -> &Mutex<TopicShard> {
        &self.topic_shards[(fnv1a(topic) % self.topic_shards.len() as u64) as usize]
    }

    fn traffic_shard(&self, node: &str) -> &Mutex<TrafficShard> {
        &self.traffic_shards[(fnv1a(node) % self.traffic_shards.len() as u64) as usize]
    }

    /// Publish a message; charged to the sender's egress. Takes `&self`:
    /// concurrent publishes to different topic shards proceed in parallel.
    pub fn publish(&self, topic: &str, sender: &str, round: u64, payload: Payload) {
        let bytes = payload.wire_bytes();
        {
            let mut shard = self.traffic_shard(sender).lock().expect("traffic shard");
            let t = shard.nodes.entry(sender.to_string()).or_default();
            t.bytes_out += bytes;
            t.msgs_out += 1;
        }
        self.total_bytes.fetch_add(bytes, Ordering::Relaxed);
        let msg = Message {
            topic: topic.to_string(),
            sender: sender.to_string(),
            round,
            payload,
        };
        self.topic_shard(topic)
            .lock()
            .expect("topic shard")
            .topics
            .entry(topic.to_string())
            .or_default()
            .push(msg);
    }

    /// Fetch the latest message on a topic (charged to the reader's ingress).
    /// Cloning the message clones the payload handle, not the floats.
    pub fn fetch_latest(&self, topic: &str, reader: &str) -> Result<Message> {
        let msg = self
            .topic_shard(topic)
            .lock()
            .expect("topic shard")
            .topics
            .get(topic)
            .and_then(|v| v.last())
            .cloned()
            .ok_or_else(|| anyhow!("no message on topic '{topic}'"))?;
        self.charge_read(reader, &msg);
        Ok(msg)
    }

    /// Fetch all messages on a topic for a given round.
    pub fn fetch_round(&self, topic: &str, round: u64, reader: &str) -> Vec<Message> {
        let msgs: Vec<Message> = self
            .topic_shard(topic)
            .lock()
            .expect("topic shard")
            .topics
            .get(topic)
            .map(|v| v.iter().filter(|m| m.round == round).cloned().collect())
            .unwrap_or_default();
        for m in &msgs {
            self.charge_read(reader, m);
        }
        msgs
    }

    /// Peek without traffic accounting (controller-internal bookkeeping).
    pub fn peek_round(&self, topic: &str, round: u64) -> usize {
        self.topic_shard(topic)
            .lock()
            .expect("topic shard")
            .topics
            .get(topic)
            .map(|v| v.iter().filter(|m| m.round == round).count())
            .unwrap_or(0)
    }

    pub fn topic_len(&self, topic: &str) -> usize {
        self.topic_shard(topic)
            .lock()
            .expect("topic shard")
            .topics
            .get(topic)
            .map(Vec::len)
            .unwrap_or(0)
    }

    /// Number of live (non-empty) topics (scans every shard).
    pub fn topic_count(&self) -> usize {
        self.topic_shards
            .iter()
            .map(|s| s.lock().expect("topic shard").topics.len())
            .sum()
    }

    /// Total retained messages across all topics.
    pub fn message_count(&self) -> usize {
        self.topic_shards
            .iter()
            .map(|s| {
                s.lock()
                    .expect("topic shard")
                    .topics
                    .values()
                    .map(Vec::len)
                    .sum::<usize>()
            })
            .sum()
    }

    /// Retained payload volume in bytes (what the broker actually holds —
    /// the memory-boundedness metric for long runs).
    pub fn retained_bytes(&self) -> u64 {
        self.topic_shards
            .iter()
            .map(|s| {
                s.lock()
                    .expect("topic shard")
                    .topics
                    .values()
                    .flat_map(|v| v.iter())
                    .map(|m| m.payload.wire_bytes())
                    .sum::<u64>()
            })
            .sum()
    }

    /// Drop messages older than `keep_from_round` (bounded memory during
    /// long simulations; the paper's §6 "memory management" future work).
    ///
    /// Topics drained to empty are removed outright and surviving buffers
    /// shrink to fit — per-peer/per-cluster topic names (`peer_params/x`)
    /// otherwise accumulate empty `Vec`s (and their capacity) forever.
    pub fn truncate_before(&self, keep_from_round: u64) {
        for shard in &self.topic_shards {
            shard.lock().expect("topic shard").topics.retain(|_, v| {
                v.retain(|m| m.round >= keep_from_round);
                if v.is_empty() {
                    false
                } else {
                    v.shrink_to_fit();
                    true
                }
            });
        }
    }

    fn charge_read(&self, reader: &str, msg: &Message) {
        let bytes = msg.payload.wire_bytes();
        {
            let mut shard = self.traffic_shard(reader).lock().expect("traffic shard");
            let t = shard.nodes.entry(reader.to_string()).or_default();
            t.bytes_in += bytes;
            t.msgs_in += 1;
        }
        self.total_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn traffic(&self, node: &str) -> Traffic {
        self.traffic_shard(node)
            .lock()
            .expect("traffic shard")
            .nodes
            .get(node)
            .cloned()
            .unwrap_or_default()
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes.load(Ordering::Relaxed)
    }

    /// Sum of all node egress+ingress since `mark` (caller keeps the mark).
    pub fn bytes_since(&self, mark: u64) -> u64 {
        self.total_bytes() - mark
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_fetch_roundtrip() {
        let kv = KvStore::new();
        kv.publish("global_model", "worker_0", 1, Payload::params(vec![1.0, 2.0]));
        let m = kv.fetch_latest("global_model", "client_3").unwrap();
        assert_eq!(m.payload.as_params().unwrap(), &[1.0, 2.0]);
        assert_eq!(m.sender, "worker_0");
    }

    #[test]
    fn fetch_round_filters() {
        let kv = KvStore::new();
        kv.publish("local/c0", "c0", 1, Payload::Scalar(1.0));
        kv.publish("local/c0", "c0", 2, Payload::Scalar(2.0));
        kv.publish("local/c0", "c0", 2, Payload::Scalar(3.0));
        assert_eq!(kv.fetch_round("local/c0", 2, "w0").len(), 2);
        assert_eq!(kv.peek_round("local/c0", 1), 1);
    }

    #[test]
    fn traffic_accounting() {
        let kv = KvStore::new();
        kv.publish("t", "alice", 0, Payload::params(vec![0.0; 100]));
        let _ = kv.fetch_latest("t", "bob").unwrap();
        let a = kv.traffic("alice");
        let b = kv.traffic("bob");
        assert_eq!(a.bytes_out, 64 + 400);
        assert_eq!(a.bytes_in, 0);
        assert_eq!(b.bytes_in, 64 + 400);
        assert_eq!(kv.total_bytes(), 2 * (64 + 400));
    }

    #[test]
    fn missing_topic_errors() {
        let kv = KvStore::new();
        assert!(kv.fetch_latest("nope", "x").is_err());
    }

    #[test]
    fn fetch_is_zero_copy() {
        let params: Arc<[f32]> = vec![0.5f32; 1024].into();
        let kv = KvStore::new();
        kv.publish("t", "a", 1, Payload::Params(params.clone()));
        let m1 = kv.fetch_latest("t", "b").unwrap();
        let m2 = kv.fetch_latest("t", "c").unwrap();
        let a1 = m1.payload.params_arc().unwrap();
        let a2 = m2.payload.params_arc().unwrap();
        // Same allocation shared by publisher, broker and both readers.
        assert!(Arc::ptr_eq(&params, &a1));
        assert!(Arc::ptr_eq(&params, &a2));
        // Metering still charges full logical volume per read.
        assert_eq!(kv.traffic("b").bytes_in, 64 + 4096);
        assert_eq!(kv.traffic("c").bytes_in, 64 + 4096);
    }

    #[test]
    fn truncate_bounds_memory() {
        let kv = KvStore::new();
        for r in 0..10 {
            kv.publish("t", "a", r, Payload::Scalar(r as f64));
        }
        kv.truncate_before(8);
        assert_eq!(kv.topic_len("t"), 2);
    }

    #[test]
    fn truncate_removes_dead_topics_and_bounds_long_runs() {
        let kv = KvStore::new();
        // Long simulated run over per-peer topics (the decentralized flows'
        // naming pattern): without topic reclamation this leaks one Vec per
        // peer per round forever.
        let peers = 8;
        for round in 1..=200u64 {
            for p in 0..peers {
                kv.publish(
                    &format!("peer_params/peer_{p}/r{round}"),
                    &format!("peer_{p}"),
                    round,
                    Payload::params(vec![round as f32; 64]),
                );
            }
            kv.truncate_before(round); // keep only the current round
            assert!(
                kv.topic_count() <= peers,
                "round {round}: {} topics retained",
                kv.topic_count()
            );
            assert!(kv.message_count() <= peers);
            assert!(kv.retained_bytes() <= (peers as u64) * (64 + 64 * 4));
        }
        // Draining everything leaves an empty broker (no zombie topics).
        kv.truncate_before(u64::MAX);
        assert_eq!(kv.topic_count(), 0);
        assert_eq!(kv.message_count(), 0);
        assert_eq!(kv.retained_bytes(), 0);
        // Accounting is unaffected by truncation.
        assert!(kv.total_bytes() > 0);
    }

    #[test]
    fn concurrent_publishes_keep_exact_metering_totals() {
        // 10k+ publishes from a thread pool: shard locks allow them to
        // proceed concurrently, and every metering total must still come
        // out exact (commutative u64 adds — no updates lost or doubled).
        let kv = KvStore::new();
        let threads = 8usize;
        let per_thread = 1500usize;
        std::thread::scope(|s| {
            for t in 0..threads {
                let kv = &kv;
                s.spawn(move || {
                    for i in 0..per_thread {
                        let sender = format!("client_{}", t * per_thread + i);
                        let topic = format!("client_params/{sender}");
                        kv.publish(&topic, &sender, 1, Payload::params(vec![t as f32; 16]));
                    }
                });
            }
        });
        let n = (threads * per_thread) as u64;
        let per_msg = 64 + 16 * 4;
        assert_eq!(kv.total_bytes(), n * per_msg);
        assert_eq!(kv.message_count(), n as usize);
        assert_eq!(kv.topic_count(), n as usize);
        let t0 = kv.traffic("client_0");
        assert_eq!(t0.bytes_out, per_msg);
        assert_eq!(t0.msgs_out, 1);
        // Reads across shards still see every message.
        assert_eq!(kv.fetch_round("client_params/client_0", 1, "w0").len(), 1);
        kv.truncate_before(2);
        assert_eq!(kv.message_count(), 0);
        assert_eq!(kv.topic_count(), 0);
    }

    #[test]
    fn interleaved_publishes_to_one_topic_retain_all_messages() {
        // Same-topic publishes serialize on that topic's shard lock; the
        // retained log holds all of them (ordering across threads is the
        // scheduler's — the orchestrator's serial commit phase is what
        // fixes order in real runs).
        let kv = KvStore::with_shards(4);
        std::thread::scope(|s| {
            for t in 0..4 {
                let kv = &kv;
                s.spawn(move || {
                    for i in 0..100u64 {
                        kv.publish("agg_votes", &format!("w{t}"), i, Payload::Scalar(i as f64));
                    }
                });
            }
        });
        assert_eq!(kv.topic_len("agg_votes"), 400);
        assert_eq!(kv.peek_round("agg_votes", 7), 4);
    }

    #[test]
    fn single_shard_store_behaves_identically() {
        let kv = KvStore::with_shards(1);
        kv.publish("a", "x", 1, Payload::Scalar(1.0));
        kv.publish("b", "y", 1, Payload::Text("v".into()));
        assert_eq!(kv.shard_count(), 1);
        assert_eq!(kv.topic_count(), 2);
        assert_eq!(kv.total_bytes(), (64 + 8) + (64 + 1));
    }

    #[test]
    fn payload_accessors() {
        assert!(Payload::Text("x".into()).as_params().is_err());
        assert!(Payload::Scalar(4.0).params_arc().is_err());
        assert_eq!(Payload::Scalar(4.0).as_scalar().unwrap(), 4.0);
        assert_eq!(Payload::Text("hi".into()).wire_bytes(), 66);
        assert!(Payload::Scalar(4.0).as_compressed().is_err());
    }

    #[test]
    fn compressed_and_opaque_payload_metering() {
        // Compressed payloads charge exactly the compressed wire volume:
        // the inner 64-byte envelope, never 64 + 64.
        let c = crate::aggregate::compress::top_k(&[1.0, -3.0, 0.5, 2.0], 2);
        let inner = c.wire_bytes();
        let p = Payload::Compressed(Arc::new(c));
        assert_eq!(p.wire_bytes(), inner);
        assert_eq!(inner, 64 + 2 * 8 + 4);
        let kv = KvStore::new();
        kv.publish("u", "client_0", 1, p);
        assert_eq!(kv.traffic("client_0").bytes_out, inner);
        assert_eq!(kv.total_bytes(), inner);
        // Opaque = envelope + declared body.
        assert_eq!(Payload::Opaque(320).wire_bytes(), 64 + 320);
        let m = kv.fetch_latest("u", "worker_0").unwrap();
        assert_eq!(m.payload.as_compressed().unwrap().decompress().len(), 4);
        assert!(m.payload.as_params().is_err());
    }
}
