//! In-process pub-sub key-value store.
//!
//! Nodes publish their state (model parameters, control variates, votes,
//! hashes) under topic keys; subscribers fetch them. The store is the single
//! communication fabric of the simulation — every byte that would cross the
//! network in a real FLsim deployment passes through `publish`/`fetch` and
//! is metered per node, which is what the paper's bandwidth plots report.
//!
//! ## Zero-copy fabric
//!
//! Parameter payloads are `Arc<[f32]>`: publishing, fetching and fanning a
//! model out to every worker are refcount bumps, never float copies. Before
//! this, a 1000-client × 1e5-parameter round cloned ~800 MB of floats
//! through the broker per fetch fan-out; now the broker moves pointers and
//! the *metering* still charges the full logical wire volume (the simulated
//! network cost model is unchanged).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

/// What a node can publish.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// A flat model-parameter vector (or any other f32 state), shared
    /// zero-copy between publisher, broker and all readers.
    Params(Arc<[f32]>),
    /// An arbitrary small string (hash votes, signals).
    Text(String),
    /// A scalar (e.g. example counts for weighted aggregation).
    Scalar(f64),
}

impl Payload {
    /// Parameter payload from anything `Arc<[f32]>`-convertible (an owned
    /// `Vec<f32>` converts without an extra copy beyond the one-time move).
    pub fn params(data: impl Into<Arc<[f32]>>) -> Payload {
        Payload::Params(data.into())
    }

    /// Wire size in bytes (f32 = 4B; text = utf-8 len; scalar = 8B) plus a
    /// fixed 64-byte envelope (topic, sender, round — the REST/JSON framing
    /// the paper's deployment would pay, flat-rated).
    pub fn wire_bytes(&self) -> u64 {
        64 + match self {
            Payload::Params(p) => (p.len() * 4) as u64,
            Payload::Text(s) => s.len() as u64,
            Payload::Scalar(_) => 8,
        }
    }

    pub fn as_params(&self) -> Result<&[f32]> {
        match self {
            Payload::Params(p) => Ok(p),
            _ => Err(anyhow!("payload is not Params")),
        }
    }

    /// Shared handle to a parameter payload (refcount bump, no copy).
    pub fn params_arc(&self) -> Result<Arc<[f32]>> {
        match self {
            Payload::Params(p) => Ok(p.clone()),
            _ => Err(anyhow!("payload is not Params")),
        }
    }

    pub fn as_text(&self) -> Result<&str> {
        match self {
            Payload::Text(t) => Ok(t),
            _ => Err(anyhow!("payload is not Text")),
        }
    }

    pub fn as_scalar(&self) -> Result<f64> {
        match self {
            Payload::Scalar(s) => Ok(*s),
            _ => Err(anyhow!("payload is not Scalar")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Message {
    pub topic: String,
    pub sender: String,
    pub round: u64,
    pub payload: Payload,
}

/// Per-node traffic accounting.
#[derive(Clone, Debug, Default)]
pub struct Traffic {
    pub bytes_out: u64,
    pub bytes_in: u64,
    pub msgs_out: u64,
    pub msgs_in: u64,
}

/// The broker. Mutation is serialized by the logic controller (publishes and
/// metered fetches are committed in deterministic node order even when
/// training runs on a worker pool), so the store needs no locking (RQ6).
#[derive(Debug, Default)]
pub struct KvStore {
    topics: BTreeMap<String, Vec<Message>>,
    traffic: BTreeMap<String, Traffic>,
    total_bytes: u64,
}

impl KvStore {
    pub fn new() -> KvStore {
        KvStore::default()
    }

    /// Publish a message; charged to the sender's egress.
    pub fn publish(&mut self, topic: &str, sender: &str, round: u64, payload: Payload) {
        let bytes = payload.wire_bytes();
        let t = self.traffic.entry(sender.to_string()).or_default();
        t.bytes_out += bytes;
        t.msgs_out += 1;
        self.total_bytes += bytes;
        self.topics.entry(topic.to_string()).or_default().push(Message {
            topic: topic.to_string(),
            sender: sender.to_string(),
            round,
            payload,
        });
    }

    /// Fetch the latest message on a topic (charged to the reader's ingress).
    /// Cloning the message clones the payload handle, not the floats.
    pub fn fetch_latest(&mut self, topic: &str, reader: &str) -> Result<Message> {
        let msg = self
            .topics
            .get(topic)
            .and_then(|v| v.last())
            .cloned()
            .ok_or_else(|| anyhow!("no message on topic '{topic}'"))?;
        self.charge_read(reader, &msg);
        Ok(msg)
    }

    /// Fetch all messages on a topic for a given round.
    pub fn fetch_round(&mut self, topic: &str, round: u64, reader: &str) -> Vec<Message> {
        let msgs: Vec<Message> = self
            .topics
            .get(topic)
            .map(|v| v.iter().filter(|m| m.round == round).cloned().collect())
            .unwrap_or_default();
        for m in &msgs {
            self.charge_read(reader, m);
        }
        msgs
    }

    /// Peek without traffic accounting (controller-internal bookkeeping).
    pub fn peek_round(&self, topic: &str, round: u64) -> usize {
        self.topics
            .get(topic)
            .map(|v| v.iter().filter(|m| m.round == round).count())
            .unwrap_or(0)
    }

    pub fn topic_len(&self, topic: &str) -> usize {
        self.topics.get(topic).map(Vec::len).unwrap_or(0)
    }

    /// Number of live (non-empty) topics.
    pub fn topic_count(&self) -> usize {
        self.topics.len()
    }

    /// Total retained messages across all topics.
    pub fn message_count(&self) -> usize {
        self.topics.values().map(Vec::len).sum()
    }

    /// Retained payload volume in bytes (what the broker actually holds —
    /// the memory-boundedness metric for long runs).
    pub fn retained_bytes(&self) -> u64 {
        self.topics
            .values()
            .flat_map(|v| v.iter())
            .map(|m| m.payload.wire_bytes())
            .sum()
    }

    /// Drop messages older than `keep_from_round` (bounded memory during
    /// long simulations; the paper's §6 "memory management" future work).
    ///
    /// Topics drained to empty are removed outright and surviving buffers
    /// shrink to fit — per-peer/per-cluster topic names (`peer_params/x`)
    /// otherwise accumulate empty `Vec`s (and their capacity) forever.
    pub fn truncate_before(&mut self, keep_from_round: u64) {
        self.topics.retain(|_, v| {
            v.retain(|m| m.round >= keep_from_round);
            if v.is_empty() {
                false
            } else {
                v.shrink_to_fit();
                true
            }
        });
    }

    fn charge_read(&mut self, reader: &str, msg: &Message) {
        let bytes = msg.payload.wire_bytes();
        let t = self.traffic.entry(reader.to_string()).or_default();
        t.bytes_in += bytes;
        t.msgs_in += 1;
        self.total_bytes += bytes;
    }

    pub fn traffic(&self, node: &str) -> Traffic {
        self.traffic.get(node).cloned().unwrap_or_default()
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    /// Sum of all node egress+ingress since `mark` (caller keeps the mark).
    pub fn bytes_since(&self, mark: u64) -> u64 {
        self.total_bytes - mark
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_fetch_roundtrip() {
        let mut kv = KvStore::new();
        kv.publish("global_model", "worker_0", 1, Payload::params(vec![1.0, 2.0]));
        let m = kv.fetch_latest("global_model", "client_3").unwrap();
        assert_eq!(m.payload.as_params().unwrap(), &[1.0, 2.0]);
        assert_eq!(m.sender, "worker_0");
    }

    #[test]
    fn fetch_round_filters() {
        let mut kv = KvStore::new();
        kv.publish("local/c0", "c0", 1, Payload::Scalar(1.0));
        kv.publish("local/c0", "c0", 2, Payload::Scalar(2.0));
        kv.publish("local/c0", "c0", 2, Payload::Scalar(3.0));
        assert_eq!(kv.fetch_round("local/c0", 2, "w0").len(), 2);
        assert_eq!(kv.peek_round("local/c0", 1), 1);
    }

    #[test]
    fn traffic_accounting() {
        let mut kv = KvStore::new();
        kv.publish("t", "alice", 0, Payload::params(vec![0.0; 100]));
        let _ = kv.fetch_latest("t", "bob").unwrap();
        let a = kv.traffic("alice");
        let b = kv.traffic("bob");
        assert_eq!(a.bytes_out, 64 + 400);
        assert_eq!(a.bytes_in, 0);
        assert_eq!(b.bytes_in, 64 + 400);
        assert_eq!(kv.total_bytes(), 2 * (64 + 400));
    }

    #[test]
    fn missing_topic_errors() {
        let mut kv = KvStore::new();
        assert!(kv.fetch_latest("nope", "x").is_err());
    }

    #[test]
    fn fetch_is_zero_copy() {
        let params: Arc<[f32]> = vec![0.5f32; 1024].into();
        let mut kv = KvStore::new();
        kv.publish("t", "a", 1, Payload::Params(params.clone()));
        let m1 = kv.fetch_latest("t", "b").unwrap();
        let m2 = kv.fetch_latest("t", "c").unwrap();
        let a1 = m1.payload.params_arc().unwrap();
        let a2 = m2.payload.params_arc().unwrap();
        // Same allocation shared by publisher, broker and both readers.
        assert!(Arc::ptr_eq(&params, &a1));
        assert!(Arc::ptr_eq(&params, &a2));
        // Metering still charges full logical volume per read.
        assert_eq!(kv.traffic("b").bytes_in, 64 + 4096);
        assert_eq!(kv.traffic("c").bytes_in, 64 + 4096);
    }

    #[test]
    fn truncate_bounds_memory() {
        let mut kv = KvStore::new();
        for r in 0..10 {
            kv.publish("t", "a", r, Payload::Scalar(r as f64));
        }
        kv.truncate_before(8);
        assert_eq!(kv.topic_len("t"), 2);
    }

    #[test]
    fn truncate_removes_dead_topics_and_bounds_long_runs() {
        let mut kv = KvStore::new();
        // Long simulated run over per-peer topics (the decentralized flows'
        // naming pattern): without topic reclamation this leaks one Vec per
        // peer per round forever.
        let peers = 8;
        for round in 1..=200u64 {
            for p in 0..peers {
                kv.publish(
                    &format!("peer_params/peer_{p}/r{round}"),
                    &format!("peer_{p}"),
                    round,
                    Payload::params(vec![round as f32; 64]),
                );
            }
            kv.truncate_before(round); // keep only the current round
            assert!(
                kv.topic_count() <= peers,
                "round {round}: {} topics retained",
                kv.topic_count()
            );
            assert!(kv.message_count() <= peers);
            assert!(kv.retained_bytes() <= (peers as u64) * (64 + 64 * 4));
        }
        // Draining everything leaves an empty broker (no zombie topics).
        kv.truncate_before(u64::MAX);
        assert_eq!(kv.topic_count(), 0);
        assert_eq!(kv.message_count(), 0);
        assert_eq!(kv.retained_bytes(), 0);
        // Accounting is unaffected by truncation.
        assert!(kv.total_bytes() > 0);
    }

    #[test]
    fn payload_accessors() {
        assert!(Payload::Text("x".into()).as_params().is_err());
        assert!(Payload::Scalar(4.0).params_arc().is_err());
        assert_eq!(Payload::Scalar(4.0).as_scalar().unwrap(), 4.0);
        assert_eq!(Payload::Text("hi".into()).wire_bytes(), 66);
    }
}
