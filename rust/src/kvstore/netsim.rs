//! Topology-aware virtual-clock network fabric.
//!
//! Every simulated transfer is priced over the *actual overlay route*
//! between its two endpoints: a breadth-first shortest path over the
//! [`Overlay`] edges, each hop billed with the [`LinkModel`] of its
//! [`LinkClass`] (client↔worker and peer↔peer hops ride the EDGE uplink,
//! server-tier hops ride LAN — overridable per class via the `network:`
//! config section, or per directed edge via [`NetSim::set_link`]). This is
//! what turns the paper's Fig 11e topology comparison into transfer *time*
//! ordering instead of a message count: fully-connected DFL pays n·(n−1)
//! EDGE crossings per round, hierarchical FL pays an extra LAN tier, the
//! client-server star pays one EDGE hop each way.
//!
//! The clock is **virtual**: prices are accumulated observationally
//! (`sim_net_secs`, per-round makespans) and never influence training
//! results — unless a `round_deadline_secs` is configured, in which case
//! clients whose virtual finish time exceeds the deadline are dropped
//! through the Logic Controller's barrier timeout arm (Algorithm 1's
//! emergent straggler path).

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::topology::graph::{LinkClass, Overlay};

/// Simulated compute seconds one local batch step costs on the *baseline*
/// client device. A client's virtual train time is
/// `steps × SIM_STEP_SECS × speed_factor`, where the per-client speed
/// factor is derived deterministically from the job seed and scaled by the
/// `heterogeneity` knob (0.0 = a homogeneous fleet).
pub const SIM_STEP_SECS: f64 = 0.01;

/// A point-to-point link model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    /// One-way latency in milliseconds.
    pub latency_ms: f64,
    /// Bandwidth in megabytes per second.
    pub bandwidth_mbps: f64,
}

impl LinkModel {
    pub const LAN: LinkModel = LinkModel {
        latency_ms: 0.5,
        bandwidth_mbps: 125.0, // ~1 Gbit/s
    };
    pub const WAN: LinkModel = LinkModel {
        latency_ms: 25.0,
        bandwidth_mbps: 12.5, // ~100 Mbit/s
    };
    pub const EDGE: LinkModel = LinkModel {
        latency_ms: 60.0,
        bandwidth_mbps: 2.5, // ~20 Mbit/s uplink
    };

    /// Seconds to move `bytes` over this link.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        self.latency_ms / 1e3 + bytes as f64 / (self.bandwidth_mbps * 1e6)
    }
}

/// Per-class link models — the `network:` section of a job config.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkPolicy {
    pub edge: LinkModel,
    pub lan: LinkModel,
    pub wan: LinkModel,
}

impl LinkPolicy {
    pub fn model(&self, class: LinkClass) -> LinkModel {
        match class {
            LinkClass::Edge => self.edge,
            LinkClass::Lan => self.lan,
            LinkClass::Wan => self.wan,
        }
    }
}

impl Default for LinkPolicy {
    fn default() -> Self {
        LinkPolicy {
            edge: LinkModel::EDGE,
            lan: LinkModel::LAN,
            wan: LinkModel::WAN,
        }
    }
}

/// Pre-summed cost of a route: `secs(bytes) = latency + bytes · secs_per_byte`
/// (per-hop latencies add; per-hop store-and-forward serialization adds).
#[derive(Clone, Copy, Debug)]
struct RouteCost {
    latency_secs: f64,
    secs_per_byte: f64,
}

impl RouteCost {
    const ZERO: RouteCost = RouteCost {
        latency_secs: 0.0,
        secs_per_byte: 0.0,
    };

    fn from_link(l: LinkModel) -> RouteCost {
        RouteCost {
            latency_secs: l.latency_ms / 1e3,
            secs_per_byte: 1.0 / (l.bandwidth_mbps * 1e6),
        }
    }

    fn secs(&self, bytes: u64) -> f64 {
        self.latency_secs + bytes as f64 * self.secs_per_byte
    }
}

/// Accumulates simulated transfer time per node and globally, routing every
/// transfer over the attached overlay.
#[derive(Clone, Debug)]
pub struct NetSim {
    /// Per-class models for routed hops.
    policy: LinkPolicy,
    /// Single-hop model for endpoints outside the overlay (or when no
    /// overlay is attached — the legacy flat-LAN behaviour).
    default_link: LinkModel,
    /// Undirected adjacency with per-edge classes (from the overlay).
    adj: BTreeMap<String, Vec<(String, LinkClass)>>,
    /// Optional per-directed-edge overrides keyed by "src->dst".
    overrides: BTreeMap<String, LinkModel>,
    /// Route costs memoized as src -> dst -> cost (nested so a cache hit is
    /// two borrowed lookups, no allocation — this sits on the per-delivery
    /// metering hot path).
    route_cache: BTreeMap<String, BTreeMap<String, RouteCost>>,
    /// Cross-device scale fast path: `(n_clients, worker names)` of a
    /// virtual star. Any `client_{i}` (i < n) ↔ worker pair is a single
    /// EDGE hop priced closed-form — exactly what BFS over the eager star's
    /// O(N·workers) edge set would return, without materializing it (a
    /// 1-worker virtual overlay has *zero* edges, so the worker set must be
    /// carried here, not inferred from the adjacency).
    virtual_star: Option<(u64, BTreeSet<String>)>,
    per_node_secs: BTreeMap<String, f64>,
    total_secs: f64,
    total_bytes: u64,
}

impl NetSim {
    pub fn new(default_link: LinkModel) -> NetSim {
        NetSim {
            policy: LinkPolicy::default(),
            default_link,
            adj: BTreeMap::new(),
            overrides: BTreeMap::new(),
            route_cache: BTreeMap::new(),
            virtual_star: None,
            per_node_secs: BTreeMap::new(),
            total_secs: 0.0,
            total_bytes: 0,
        }
    }

    /// Fabric with per-class link models (off-overlay endpoints fall back
    /// to the LAN model).
    pub fn with_policy(policy: LinkPolicy) -> NetSim {
        let mut n = NetSim::new(policy.lan);
        n.policy = policy;
        n
    }

    /// Route future transfers over this overlay's edges. Classes are
    /// derived from the endpoint roles ([`Overlay::link_class`]).
    pub fn attach_overlay(&mut self, overlay: &Overlay) {
        self.adj.clear();
        self.route_cache.clear();
        for (a, b) in &overlay.edges {
            let class = overlay.link_class(a, b);
            self.adj
                .entry(a.clone())
                .or_default()
                .push((b.clone(), class));
            self.adj
                .entry(b.clone())
                .or_default()
                .push((a.clone(), class));
        }
        for ns in self.adj.values_mut() {
            ns.sort();
            ns.dedup();
        }
    }

    /// Arm the virtual-star fast path: price any `client_{i}` (i <
    /// `n_clients`) ↔ worker transfer as one EDGE uplink hop without
    /// consulting the overlay adjacency. Pair with
    /// [`Overlay::client_server_virtual`], whose client tier is not
    /// materialized as edges.
    pub fn set_virtual_star(&mut self, n_clients: u64, workers: BTreeSet<String>) {
        self.virtual_star = Some((n_clients, workers));
        self.route_cache.clear();
    }

    pub fn set_link(&mut self, src: &str, dst: &str, link: LinkModel) {
        self.overrides.insert(format!("{src}->{dst}"), link);
        self.route_cache.clear();
    }

    /// Fewest-hop path src→dst over the overlay (deterministic: neighbor
    /// lists are sorted). Returns the hop classes, or None when either
    /// endpoint is off-overlay or unreachable.
    fn bfs(&self, src: &str, dst: &str) -> Option<Vec<LinkClass>> {
        if !self.adj.contains_key(src) || !self.adj.contains_key(dst) {
            return None;
        }
        let mut prev: BTreeMap<&str, (&str, LinkClass)> = BTreeMap::new();
        let mut queue: VecDeque<&str> = VecDeque::new();
        queue.push_back(src);
        while let Some(node) = queue.pop_front() {
            if node == dst {
                let mut hops = Vec::new();
                let mut cur = dst;
                while cur != src {
                    let (p, class) = prev[cur];
                    hops.push(class);
                    cur = p;
                }
                hops.reverse();
                return Some(hops);
            }
            if let Some(ns) = self.adj.get(node) {
                for (n, class) in ns {
                    if n.as_str() != src && !prev.contains_key(n.as_str()) {
                        prev.insert(n, (node, *class));
                        queue.push_back(n);
                    }
                }
            }
        }
        None
    }

    fn route_cost(&mut self, src: &str, dst: &str) -> RouteCost {
        if src == dst {
            return RouteCost::ZERO;
        }
        if !self.overrides.is_empty() {
            if let Some(l) = self.overrides.get(&format!("{src}->{dst}")) {
                return RouteCost::from_link(*l);
            }
        }
        if let Some(c) = self.virtual_star_cost(src, dst) {
            return c;
        }
        if let Some(c) = self.route_cache.get(src).and_then(|m| m.get(dst)) {
            return *c;
        }
        let cost = match self.bfs(src, dst) {
            Some(hops) => {
                let mut c = RouteCost::ZERO;
                for class in hops {
                    let h = RouteCost::from_link(self.policy.model(class));
                    c.latency_secs += h.latency_secs;
                    c.secs_per_byte += h.secs_per_byte;
                }
                c
            }
            None => RouteCost::from_link(self.default_link),
        };
        self.route_cache
            .entry(src.to_string())
            .or_default()
            .insert(dst.to_string(), cost);
        cost
    }

    /// One-EDGE-hop cost for a virtual-star client↔worker pair (either
    /// direction); `None` for every other pair, which falls through to the
    /// routed/cached path. Off-star endpoints (e.g. `logic_controller`)
    /// keep the same default-LAN fallback as the eager overlay gives them.
    fn virtual_star_cost(&self, src: &str, dst: &str) -> Option<RouteCost> {
        let (n, workers) = self.virtual_star.as_ref()?;
        let is_client = |name: &str| {
            let digits = match name.strip_prefix("client_") {
                Some(d) => d,
                None => return false,
            };
            // Canonical names only: "client_007" is not a fleet member.
            if digits.len() > 1 && digits.starts_with('0') {
                return false;
            }
            digits.parse::<u64>().map(|i| i < *n).unwrap_or(false)
        };
        let hit = (is_client(src) && workers.contains(dst))
            || (is_client(dst) && workers.contains(src));
        hit.then(|| RouteCost::from_link(self.policy.edge))
    }

    /// Price a transfer without recording it (pure: used for critical-path
    /// makespan components that are metered elsewhere).
    pub fn price(&mut self, src: &str, dst: &str, bytes: u64) -> f64 {
        self.route_cost(src, dst).secs(bytes)
    }

    /// Record a transfer; returns simulated seconds it took over the route.
    pub fn transfer(&mut self, src: &str, dst: &str, bytes: u64) -> f64 {
        let secs = self.price(src, dst, bytes);
        *self.per_node_secs.entry(src.to_string()).or_insert(0.0) += secs;
        *self.per_node_secs.entry(dst.to_string()).or_insert(0.0) += secs;
        self.total_secs += secs;
        self.total_bytes += bytes;
        secs
    }

    pub fn node_secs(&self, node: &str) -> f64 {
        self.per_node_secs.get(node).copied().unwrap_or(0.0)
    }

    pub fn total_secs(&self) -> f64 {
        self.total_secs
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }
}

impl Default for NetSim {
    fn default() -> Self {
        NetSim::new(LinkModel::LAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::graph::Overlay;

    #[test]
    fn transfer_time_formula() {
        let l = LinkModel {
            latency_ms: 10.0,
            bandwidth_mbps: 1.0,
        };
        // 10ms + 1MB / 1MBps = 0.01 + 1.0
        assert!((l.transfer_secs(1_000_000) - 1.01).abs() < 1e-9);
    }

    #[test]
    fn accumulates_per_node() {
        let mut net = NetSim::new(LinkModel::LAN);
        let s1 = net.transfer("a", "b", 1_000_000);
        let s2 = net.transfer("a", "c", 2_000_000);
        assert!(net.node_secs("a") > net.node_secs("b"));
        assert!((net.total_secs() - (s1 + s2)).abs() < 1e-12);
        assert_eq!(net.total_bytes(), 3_000_000);
    }

    #[test]
    fn per_edge_override() {
        let mut net = NetSim::new(LinkModel::LAN);
        net.set_link("a", "b", LinkModel::EDGE);
        let slow = net.transfer("a", "b", 1_000_000);
        let fast = net.transfer("b", "a", 1_000_000);
        assert!(slow > fast);
    }

    #[test]
    fn edge_slower_than_lan() {
        assert!(LinkModel::EDGE.transfer_secs(1 << 20) > LinkModel::LAN.transfer_secs(1 << 20));
    }

    #[test]
    fn routes_over_overlay_edge_classes() {
        let mut net = NetSim::with_policy(LinkPolicy::default());
        net.attach_overlay(&Overlay::client_server(4, 1));
        let bytes = 1 << 20;
        let up = net.price("client_0", "worker_0", bytes);
        // Client uplink is an EDGE hop, exactly.
        assert!((up - LinkModel::EDGE.transfer_secs(bytes)).abs() < 1e-12);
        // Self-transfer is free.
        assert_eq!(net.price("worker_0", "worker_0", bytes), 0.0);
    }

    #[test]
    fn multi_hop_route_sums_hops() {
        let mut net = NetSim::with_policy(LinkPolicy::default());
        net.attach_overlay(&Overlay::hierarchical(6, 2));
        let bytes = 1 << 20;
        // root -> client crosses the LAN tier then the EDGE uplink.
        let dl = net.price("root_worker", "client_0", bytes);
        let expect = LinkModel::LAN.transfer_secs(bytes) + LinkModel::EDGE.transfer_secs(bytes);
        assert!((dl - expect).abs() < 1e-12);
        // Direct leaf -> root stays a single LAN hop.
        let up = net.price("cluster0_worker", "root_worker", bytes);
        assert!((up - LinkModel::LAN.transfer_secs(bytes)).abs() < 1e-12);
    }

    #[test]
    fn off_overlay_endpoints_fall_back_to_default() {
        let mut net = NetSim::with_policy(LinkPolicy::default());
        net.attach_overlay(&Overlay::client_server(2, 1));
        let bytes = 1 << 20;
        let secs = net.price("logic_controller", "client_0", bytes);
        assert!((secs - LinkModel::LAN.transfer_secs(bytes)).abs() < 1e-12);
    }

    #[test]
    fn policy_override_changes_class_pricing() {
        let slow_edge = LinkModel {
            latency_ms: 500.0,
            bandwidth_mbps: 0.5,
        };
        let mut net = NetSim::with_policy(LinkPolicy {
            edge: slow_edge,
            ..LinkPolicy::default()
        });
        net.attach_overlay(&Overlay::client_server(2, 1));
        let bytes = 1 << 20;
        let up = net.price("client_0", "worker_0", bytes);
        assert!((up - slow_edge.transfer_secs(bytes)).abs() < 1e-12);
        assert!(up > LinkModel::EDGE.transfer_secs(bytes));
    }

    #[test]
    fn virtual_star_prices_like_eager_star() {
        let bytes = 1u64 << 20;
        // Eager reference: routed over the materialized star.
        let mut eager = NetSim::with_policy(LinkPolicy::default());
        eager.attach_overlay(&Overlay::client_server(4, 2));
        // Virtual: zero client edges, closed-form fast path.
        let mut virt = NetSim::with_policy(LinkPolicy::default());
        let overlay = Overlay::client_server_virtual(4, 2);
        virt.attach_overlay(&overlay);
        virt.set_virtual_star(4, overlay.workers().into_iter().collect());
        for (src, dst) in [
            ("client_0", "worker_0"),
            ("worker_1", "client_3"),
            ("worker_0", "worker_1"),
            ("logic_controller", "client_0"),
            ("client_2", "client_2"),
        ] {
            assert_eq!(
                eager.price(src, dst, bytes),
                virt.price(src, dst, bytes),
                "{src}->{dst}"
            );
        }
        // Out-of-fleet and non-canonical names are not star members.
        assert_eq!(
            virt.price("client_4", "worker_0", bytes),
            LinkModel::LAN.transfer_secs(bytes)
        );
        assert_eq!(
            virt.price("client_01", "worker_0", bytes),
            LinkModel::LAN.transfer_secs(bytes)
        );
    }

    #[test]
    fn route_cache_is_consistent() {
        let mut net = NetSim::with_policy(LinkPolicy::default());
        net.attach_overlay(&Overlay::ring(6));
        let a = net.price("peer_0", "peer_3", 1000);
        let b = net.price("peer_0", "peer_3", 1000);
        assert_eq!(a, b);
        // Ring distance 3 => three EDGE hops.
        let expect = 3.0 * LinkModel::EDGE.transfer_secs(1000);
        assert!((a - expect).abs() < 1e-12);
    }
}
