//! Network simulator: prices transfers with per-link bandwidth/latency so
//! the simulation can report transfer *times* (not only byte volumes) per
//! topology — decentralized P2P pays more link crossings than client-server
//! (paper Fig 11e).

use std::collections::BTreeMap;

/// A point-to-point link model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    /// One-way latency in milliseconds.
    pub latency_ms: f64,
    /// Bandwidth in megabytes per second.
    pub bandwidth_mbps: f64,
}

impl LinkModel {
    pub const LAN: LinkModel = LinkModel {
        latency_ms: 0.5,
        bandwidth_mbps: 125.0, // ~1 Gbit/s
    };
    pub const WAN: LinkModel = LinkModel {
        latency_ms: 25.0,
        bandwidth_mbps: 12.5, // ~100 Mbit/s
    };
    pub const EDGE: LinkModel = LinkModel {
        latency_ms: 60.0,
        bandwidth_mbps: 2.5, // ~20 Mbit/s uplink
    };

    /// Seconds to move `bytes` over this link.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        self.latency_ms / 1e3 + bytes as f64 / (self.bandwidth_mbps * 1e6)
    }
}

/// Accumulates simulated transfer time per node and globally.
#[derive(Clone, Debug)]
pub struct NetSim {
    default_link: LinkModel,
    /// Optional per-edge overrides keyed by "src->dst".
    overrides: BTreeMap<String, LinkModel>,
    per_node_secs: BTreeMap<String, f64>,
    total_secs: f64,
    total_bytes: u64,
}

impl NetSim {
    pub fn new(default_link: LinkModel) -> NetSim {
        NetSim {
            default_link,
            overrides: BTreeMap::new(),
            per_node_secs: BTreeMap::new(),
            total_secs: 0.0,
            total_bytes: 0,
        }
    }

    pub fn set_link(&mut self, src: &str, dst: &str, link: LinkModel) {
        self.overrides.insert(format!("{src}->{dst}"), link);
    }

    fn link(&self, src: &str, dst: &str) -> LinkModel {
        self.overrides
            .get(&format!("{src}->{dst}"))
            .copied()
            .unwrap_or(self.default_link)
    }

    /// Record a transfer; returns simulated seconds it took.
    pub fn transfer(&mut self, src: &str, dst: &str, bytes: u64) -> f64 {
        let secs = self.link(src, dst).transfer_secs(bytes);
        *self.per_node_secs.entry(src.to_string()).or_insert(0.0) += secs;
        *self.per_node_secs.entry(dst.to_string()).or_insert(0.0) += secs;
        self.total_secs += secs;
        self.total_bytes += bytes;
        secs
    }

    pub fn node_secs(&self, node: &str) -> f64 {
        self.per_node_secs.get(node).copied().unwrap_or(0.0)
    }

    pub fn total_secs(&self) -> f64 {
        self.total_secs
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }
}

impl Default for NetSim {
    fn default() -> Self {
        NetSim::new(LinkModel::LAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_formula() {
        let l = LinkModel {
            latency_ms: 10.0,
            bandwidth_mbps: 1.0,
        };
        // 10ms + 1MB / 1MBps = 0.01 + 1.0
        assert!((l.transfer_secs(1_000_000) - 1.01).abs() < 1e-9);
    }

    #[test]
    fn accumulates_per_node() {
        let mut net = NetSim::new(LinkModel::LAN);
        let s1 = net.transfer("a", "b", 1_000_000);
        let s2 = net.transfer("a", "c", 2_000_000);
        assert!(net.node_secs("a") > net.node_secs("b"));
        assert!((net.total_secs() - (s1 + s2)).abs() < 1e-12);
        assert_eq!(net.total_bytes(), 3_000_000);
    }

    #[test]
    fn per_edge_override() {
        let mut net = NetSim::new(LinkModel::LAN);
        net.set_link("a", "b", LinkModel::EDGE);
        let slow = net.transfer("a", "b", 1_000_000);
        let fast = net.transfer("b", "a", 1_000_000);
        assert!(slow > fast);
    }

    #[test]
    fn edge_slower_than_lan() {
        assert!(LinkModel::EDGE.transfer_secs(1 << 20) > LinkModel::LAN.transfer_secs(1 << 20));
    }
}
