//! Key-Value Store (paper §2.1 component 5): a pub-sub broker through which
//! nodes share model parameters and auxiliary state, plus the network
//! simulator that prices every transfer for the bandwidth metrics.

pub mod arena;
pub mod netsim;
pub mod store;

pub use arena::{ArenaStats, RoundArena};
pub use netsim::{LinkModel, LinkPolicy, NetSim, SIM_STEP_SECS};
pub use store::{KvStore, Message, Payload};
