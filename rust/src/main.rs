//! `flsim` — the FLsim launcher CLI.
//!
//! ```text
//! flsim run --config configs/fedavg_cifar.yaml [--artifacts DIR]
//! flsim campaign run|list|report --spec configs/sweep.yaml [--store DIR] [--jobs N]
//! flsim campaign worker <store> <spec> [--owner ID] [--heartbeat-secs S] [--expiry-secs S]
//! flsim experiment fig8|fig9|fig10|fig11|tables|fig12|all
//! flsim preset fedavg|scaffold|... [--rounds N] [--clients N]
//! flsim list
//! flsim info
//! ```
//!
//! (Argument parsing is hand-rolled: the offline image has no clap.)

use anyhow::{anyhow, bail, Result};

use flsim::campaign::{lease, CampaignReport, CampaignSpec, FrontierReport, ResultStore};
use flsim::config::job::JobConfig;
use flsim::experiments;
use flsim::metrics::dashboard;
use flsim::orchestrator::{Orchestrator, RunOptions};
use flsim::runtime::pjrt::Runtime;
use flsim::strategy::StrategyKind;
use flsim::util::logging;

fn main() {
    logging::init_from_env();
    if let Err(e) = run() {
        eprintln!("flsim: error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::BTreeMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), val);
        } else {
            positional.push(a);
        }
    }
    Args { positional, flags }
}

fn run() -> Result<()> {
    let args = parse_args();
    let artifacts = args
        .flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());

    match args.positional.first().map(String::as_str) {
        Some("run") => {
            let config = args
                .flags
                .get("config")
                .ok_or_else(|| anyhow!("run: missing --config <file.yaml>"))?;
            let mut job = JobConfig::from_yaml_file(config)?;
            apply_overrides(&mut job, &args)?;
            let rt = Runtime::shared(&artifacts)?;
            let report = Orchestrator::new(rt).run(&job, RunOptions::default())?;
            println!("{}", dashboard::run_line(&report));
            println!(
                "{}",
                dashboard::round_table(
                    std::slice::from_ref(&report),
                    |r| r.accuracy_series(),
                    "Accuracy"
                )
            );
            experiments::save_report("runs", &report)?;
            Ok(())
        }
        Some("preset") => {
            let name = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("preset: missing strategy name"))?;
            let mut job = JobConfig::default_cnn(name);
            apply_overrides(&mut job, &args)?;
            let rt = Runtime::shared(&artifacts)?;
            let report = Orchestrator::new(rt).run(&job, RunOptions::default())?;
            println!("{}", dashboard::run_line(&report));
            experiments::save_report("runs", &report)?;
            Ok(())
        }
        Some("campaign") => {
            let sub = args.positional.get(1).map(String::as_str).unwrap_or("help");
            campaign_cmd(sub, &args, &artifacts)
        }
        Some("experiment") => {
            let which = args
                .positional
                .get(1)
                .map(String::as_str)
                .unwrap_or("all");
            let rt = Runtime::shared(&artifacts)?;
            experiments::run_by_name(rt, which)
        }
        Some("list") => {
            println!("strategies:");
            for s in [
                "fedavg", "fedavgm", "fedprox", "scaffold", "moon", "dpfl", "flhc",
                "fedstellar",
            ] {
                let k = StrategyKind::parse(s, &flsim::util::yaml::Yaml::Null)?;
                println!(
                    "  {s:<12} mode={:?} artifact={}",
                    k.mode(),
                    k.required_artifact()
                );
            }
            println!("topologies: client_server hierarchical fully_connected ring");
            println!("consensus:  majority_hash score_vote first");
            println!("chains:     ethereum fabric");
            Ok(())
        }
        Some("info") => {
            let rt = Runtime::shared(&artifacts)?;
            println!("artifact dir: {artifacts}");
            println!("engine:       {}", rt.engine_name());
            println!("jax version:  {}", rt.manifest.jax_version);
            println!(
                "batches:      train={} eval={}",
                rt.manifest.train_batch, rt.manifest.eval_batch
            );
            println!("backends:");
            for (name, b) in &rt.manifest.backends {
                println!(
                    "  {name:<8} params={:<8} input={:?} pallas={} artifacts={:?}",
                    b.param_count,
                    b.input_shape,
                    b.use_pallas,
                    b.artifacts.keys().collect::<Vec<_>>()
                );
            }
            Ok(())
        }
        _ => {
            println!(
                "usage: flsim <run|campaign|preset|experiment|list|info> [options]\n\
                 \n\
                 flsim run --config <job.yaml> [--artifacts DIR] [--rounds N] [--parallelism N]\n\
                 flsim campaign run    --spec <sweep.yaml> [--store DIR] [--out DIR] [--jobs N]\n\
                 \x20                     [--scheduler grid|asha] [--eta N] [--min-rounds N]\n\
                 flsim campaign list   --spec <sweep.yaml> [--store DIR]\n\
                 flsim campaign report --spec <sweep.yaml> [--store DIR] [--out DIR]\n\
                 flsim campaign worker <store> <spec.yaml> [--owner ID] [--heartbeat-secs S]\n\
                 \x20                     [--expiry-secs S] [--poll-secs S] [--jobs N]\n\
                 flsim campaign gc     [--spec <sweep.yaml>] [--store DIR]\n\
                 \x20                     [--max-age-days N | --max-age-secs N] [--keep-last N]\n\
                 flsim preset <strategy> [--rounds N] [--clients N] [--seed N] [--parallelism N]\n\
                 flsim experiment <fig8|fig9|fig10|fig11|tables|fig12|all>\n\
                 flsim list\n\
                 flsim info"
            );
            Ok(())
        }
    }
}

/// `flsim campaign run|list|report|gc` — the sweep engine's CLI surface.
///
/// `run` exits non-zero with the failure list when any cell fails, but only
/// after every other cell has executed and persisted to the result store —
/// a rerun resumes the completed cells from cache and retries the failures.
fn campaign_cmd(sub: &str, args: &Args, artifacts: &str) -> Result<()> {
    let store_dir = args
        .flags
        .get("store")
        .cloned()
        .unwrap_or_else(|| "campaigns/cache".to_string());
    let out_dir = args
        .flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "campaigns".to_string());

    // `gc` takes --spec optionally (entries of the named campaign are
    // protected from eviction); everything else requires it.
    if sub == "gc" {
        let store = ResultStore::open(&store_dir)?;
        return campaign_gc(args, &store);
    }
    if sub == "worker" {
        return campaign_worker(args, artifacts);
    }
    if !matches!(sub, "run" | "list" | "report") {
        bail!("unknown campaign subcommand '{sub}' (run|list|report|worker|gc)");
    }
    let spec_path = args
        .flags
        .get("spec")
        .ok_or_else(|| anyhow!("campaign {sub}: missing --spec <sweep.yaml>"))?;
    let mut spec = CampaignSpec::from_yaml_file(spec_path)?;
    if let Some(j) = args.flags.get("jobs") {
        spec.jobs = j.parse().map_err(|_| anyhow!("bad --jobs"))?;
    }
    apply_scheduler_overrides(&mut spec, args)?;
    // Only now — with the subcommand and spec validated — create/open the
    // store (error paths must not leave stray cache directories behind).
    let store = ResultStore::open(&store_dir)?;

    match sub {
        "run" => {
            let rt = Runtime::shared(artifacts)?;
            let outcome = flsim::campaign::run(rt, &spec, &store)?;
            println!();
            for c in &outcome.cells {
                match (&c.report, &c.error) {
                    (Some(r), None) => println!(
                        "  [{}] {}",
                        if c.cached { "cache" } else { " run " },
                        dashboard::run_line(r)
                    ),
                    _ => println!(
                        "  [FAIL ] {:<22} {}",
                        c.cell.name,
                        c.error.as_deref().unwrap_or("unknown error")
                    ),
                }
            }
            println!("{}", outcome.summary());
            let report = CampaignReport::from_outcome(&outcome);
            let (csv, json) = report.save(&out_dir)?;
            println!("wrote {} and {}", csv.display(), json.display());
            if let Some(frontier) = FrontierReport::from_outcome(&outcome) {
                let path = frontier.save(&out_dir)?;
                println!("wrote {}", path.display());
                println!();
                println!("{}", frontier.render());
            }
            let reports = outcome.reports();
            if !reports.is_empty() {
                println!();
                println!(
                    "{}",
                    dashboard::comparison(&format!("campaign {}", outcome.name), &reports)
                );
            }
            let failures = outcome.failure_lines();
            if !failures.is_empty() {
                bail!(
                    "campaign '{}': {} of {} cells failed (completed cells are persisted \
                     under {}; re-running resumes them from cache):\n  {}",
                    outcome.name,
                    failures.len(),
                    outcome.cells.len(),
                    store.dir().display(),
                    failures.join("\n  ")
                );
            }
            Ok(())
        }
        "list" => {
            let cells = flsim::campaign::expand(&spec)?;
            println!(
                "campaign '{}': {} cells (store {})",
                spec.name,
                cells.len(),
                store.dir().display()
            );
            let mut cached = 0usize;
            let mut shared: std::collections::BTreeMap<String, usize> =
                std::collections::BTreeMap::new();
            let lease_expiry = flsim::campaign::LeaseConfig::default().expiry;
            for (i, c) in cells.iter().enumerate() {
                // Complete entry = cached; rung-stopped prefix = partial
                // (a full run would re-execute, but an asha rung can hit).
                let mut status = if store.contains(&c.key) {
                    cached += 1;
                    match store.origin(&c.key) {
                        Some(origin) if origin != spec.name => {
                            *shared.entry(origin.clone()).or_insert(0) += 1;
                            format!("cached (from '{origin}')")
                        }
                        _ => "cached".to_string(),
                    }
                } else if let Some(p) = store.get_at_least(&c.key, 1) {
                    format!("partial({} rounds)", p.rounds_completed())
                } else {
                    "pending".to_string()
                };
                // A live lease means a worker is on the cell right now.
                if let Some(l) = lease::info(store.dir(), &c.key) {
                    if l.age <= lease_expiry {
                        status = format!(
                            "{status}, leased ('{}', {:.0}s)",
                            l.owner,
                            l.age.as_secs_f64()
                        );
                    }
                }
                println!(
                    "  {:>3}  {:<28} {}  {:<10} {:<15} seed {:<6} {}",
                    i + 1,
                    c.name,
                    &c.key[..12],
                    c.job.strategy.name(),
                    c.job.topology.name(),
                    c.job.seed,
                    status
                );
            }
            // Cross-campaign dedup: content addressing means a cell another
            // campaign already computed is a free cache hit here.
            let deduped: usize = shared.values().sum();
            println!(
                "cache: {cached} of {} cells cached, {deduped} first computed by other campaigns",
                cells.len()
            );
            for (origin, n) in &shared {
                println!("  {n} shared with campaign '{origin}'");
            }
            let census = store.census();
            let total: usize = census.values().sum();
            println!(
                "store: {total} entries across {} campaign(s){}",
                census.len(),
                if census.is_empty() { "" } else { ":" }
            );
            for (origin, n) in &census {
                println!("  {n:>5}  {origin}");
            }
            Ok(())
        }
        "report" => {
            if spec.scheduler.kind == flsim::campaign::SchedulerKind::Asha {
                // Which cells are rung-stopped (and at what depth) is the
                // scheduler's decision, not the store's — `campaign run`
                // replays those decisions from cache (zero executions) and
                // writes the same report.
                bail!(
                    "campaign report: the asha scheduler decides per-cell depths — \
                     use `flsim campaign run` (a fully-cached run is free) to regenerate"
                );
            }
            let cells = flsim::campaign::expand(&spec)?;
            let mut missing = Vec::new();
            let mut reports = Vec::new();
            let mut rows_src = Vec::new();
            for c in &cells {
                match store.get(&c.key) {
                    Some(r) => {
                        reports.push(r.clone());
                        rows_src.push((c.clone(), r));
                    }
                    None => missing.push(c.name.clone()),
                }
            }
            if !missing.is_empty() {
                bail!(
                    "campaign '{}': {} of {} cells not in the result store yet \
                     (run `flsim campaign run --spec ...` first): {}",
                    spec.name,
                    missing.len(),
                    cells.len(),
                    missing.join(", ")
                );
            }
            let outcome = flsim::campaign::CampaignOutcome {
                name: spec.name.clone(),
                cells: rows_src
                    .into_iter()
                    .map(|(cell, r)| flsim::campaign::CellRun {
                        cell,
                        cached: true,
                        report: Some(r),
                        error: None,
                    })
                    .collect(),
            };
            let report = CampaignReport::from_outcome(&outcome);
            let (csv, json) = report.save(&out_dir)?;
            println!("wrote {} and {}", csv.display(), json.display());
            if let Some(frontier) = FrontierReport::from_outcome(&outcome) {
                let path = frontier.save(&out_dir)?;
                println!("wrote {}", path.display());
                println!();
                println!("{}", frontier.render());
            }
            println!(
                "{}",
                dashboard::comparison(&format!("campaign {}", spec.name), &reports)
            );
            Ok(())
        }
        _ => bail!("unknown campaign subcommand '{sub}' (run|list|report|gc)"),
    }
}

/// `--scheduler grid|asha [--eta N] [--min-rounds N]` — override the spec's
/// `campaign.scheduler:` section from the command line.
fn apply_scheduler_overrides(spec: &mut CampaignSpec, args: &Args) -> Result<()> {
    use flsim::campaign::SchedulerKind;
    if let Some(k) = args.flags.get("scheduler") {
        spec.scheduler.kind = match k.as_str() {
            "grid" => SchedulerKind::Grid,
            "asha" | "sha" | "successive_halving" => SchedulerKind::Asha,
            other => bail!("bad --scheduler '{other}' (grid|asha)"),
        };
    }
    if let Some(e) = args.flags.get("eta") {
        spec.scheduler.eta = e.parse().map_err(|_| anyhow!("bad --eta"))?;
        if spec.scheduler.eta < 2 {
            bail!("--eta must be >= 2");
        }
    }
    if let Some(m) = args.flags.get("min-rounds") {
        spec.scheduler.min_rounds = m.parse().map_err(|_| anyhow!("bad --min-rounds"))?;
        if spec.scheduler.min_rounds < 1 {
            bail!("--min-rounds must be >= 1");
        }
    }
    Ok(())
}

/// `flsim campaign gc` — result-store lifecycle. Evicts entries older than
/// `--max-age-days` and/or beyond the `--keep-last` newest, sweeps `.tmp`
/// residue, and never touches entries of the campaign named by `--spec`
/// (so a gc'd store still resumes that campaign entirely from cache).
fn campaign_gc(args: &Args, store: &ResultStore) -> Result<()> {
    let max_age = match (args.flags.get("max-age-days"), args.flags.get("max-age-secs")) {
        (Some(_), Some(_)) => bail!("campaign gc: pick one of --max-age-days / --max-age-secs"),
        (Some(d), None) => {
            let days: f64 = d.parse().map_err(|_| anyhow!("bad --max-age-days"))?;
            // `Duration::from_secs_f64` panics on non-finite/overflowing
            // seconds; reject those (and negatives — NaN fails both signs)
            // with a clean error instead.
            if !(days >= 0.0 && days * 86_400.0 <= u64::MAX as f64) {
                bail!("--max-age-days must be a finite number of days >= 0, got {d}");
            }
            Some(std::time::Duration::from_secs_f64(days * 86_400.0))
        }
        (None, Some(s)) => {
            let secs: u64 = s.parse().map_err(|_| anyhow!("bad --max-age-secs"))?;
            Some(std::time::Duration::from_secs(secs))
        }
        (None, None) => None,
    };
    let keep_last = match args.flags.get("keep-last") {
        Some(k) => Some(k.parse::<usize>().map_err(|_| anyhow!("bad --keep-last"))?),
        None => None,
    };
    if max_age.is_none() && keep_last.is_none() {
        bail!(
            "campaign gc: nothing to do — pass --max-age-days/--max-age-secs and/or --keep-last"
        );
    }

    let mut protect = std::collections::BTreeSet::new();
    if let Some(spec_path) = args.flags.get("spec") {
        let spec = CampaignSpec::from_yaml_file(spec_path)?;
        for cell in flsim::campaign::expand(&spec)? {
            protect.insert(cell.key);
        }
        println!("campaign gc: protecting {} cells of campaign '{}'", protect.len(), spec.name);
    }

    let opts = flsim::campaign::GcOptions {
        max_age,
        keep_last,
        // Default: `.tmp` residue younger than an hour is spared (it may
        // be a live writer mid-commit on a shared store).
        tmp_max_age: None,
        // Live-leased cells (workers mid-cell) are always protected; pass
        // the workers' --expiry-secs if it differs from the default.
        lease_expiry: flag_secs(args, "expiry-secs")?,
    };
    let stats = store.gc(&opts, &protect)?;
    println!(
        "campaign gc: {} entries scanned — {} evicted, {} kept, {} tmp files swept, \
         {} checkpoints removed, {} expired leases swept ({})",
        stats.scanned,
        stats.evicted,
        stats.kept,
        stats.tmp_removed,
        stats.ckpt_removed,
        stats.leases_swept,
        store.dir().display()
    );
    Ok(())
}

/// `flsim campaign worker <store> <spec>` — one cooperative drain process.
/// Start N of these on a shared filesystem (distinct `--owner` ids; the
/// pid default suffices on one host) and they divide the campaign's cells
/// via store leases, with no coordinator. Exits once every cell is
/// resolved; non-zero if any cell failed (its marker unblocks the other
/// workers). Writes no campaign report — run `flsim campaign run` against
/// the drained store (all cache hits, zero executions) to generate it.
fn campaign_worker(args: &Args, artifacts: &str) -> Result<()> {
    let store_dir = args
        .positional
        .get(2)
        .cloned()
        .or_else(|| args.flags.get("store").cloned())
        .ok_or_else(|| anyhow!("campaign worker: missing <store> (or --store DIR)"))?;
    let spec_path = args
        .positional
        .get(3)
        .cloned()
        .or_else(|| args.flags.get("spec").cloned())
        .ok_or_else(|| anyhow!("campaign worker: missing <spec.yaml> (or --spec FILE)"))?;
    let mut spec = CampaignSpec::from_yaml_file(&spec_path)?;
    if let Some(j) = args.flags.get("jobs") {
        spec.jobs = j.parse().map_err(|_| anyhow!("bad --jobs"))?;
    }
    apply_scheduler_overrides(&mut spec, args)?;

    let owner = args
        .flags
        .get("owner")
        .cloned()
        .unwrap_or_else(|| format!("worker-{}", std::process::id()));
    let mut opts = flsim::campaign::WorkerOptions::new(&owner);
    if let Some(d) = flag_secs(args, "heartbeat-secs")? {
        opts.lease.heartbeat = d;
    }
    if let Some(d) = flag_secs(args, "expiry-secs")? {
        opts.lease.expiry = d;
    }
    if let Some(d) = flag_secs(args, "poll-secs")? {
        opts.poll = d;
    }
    if opts.lease.expiry <= opts.lease.heartbeat {
        bail!(
            "--expiry-secs ({:.1}) must exceed --heartbeat-secs ({:.1}) — a healthy \
             worker would look dead",
            opts.lease.expiry.as_secs_f64(),
            opts.lease.heartbeat.as_secs_f64()
        );
    }

    let store = ResultStore::open(&store_dir)?;
    let rt = Runtime::shared(artifacts)?;
    println!(
        "worker[{owner}]: draining campaign '{}' against {}",
        spec.name,
        store.dir().display()
    );
    let outcome = flsim::campaign::drain(rt, &spec, &store, &opts)?;
    println!("{}", outcome.summary());
    let failures = outcome.failure_lines();
    if !failures.is_empty() {
        bail!(
            "campaign '{}': {} of {} cells failed:\n  {}",
            outcome.name,
            failures.len(),
            outcome.cells.len(),
            failures.join("\n  ")
        );
    }
    Ok(())
}

/// Parse a `--<name> <seconds>` flag (fractional allowed, must be positive).
fn flag_secs(args: &Args, name: &str) -> Result<Option<std::time::Duration>> {
    match args.flags.get(name) {
        None => Ok(None),
        Some(v) => {
            let secs: f64 = v.parse().map_err(|_| anyhow!("bad --{name}"))?;
            if !(secs > 0.0 && secs.is_finite()) {
                bail!("--{name} must be a positive number of seconds, got {v}");
            }
            Ok(Some(std::time::Duration::from_secs_f64(secs)))
        }
    }
}

fn apply_overrides(job: &mut JobConfig, args: &Args) -> Result<()> {
    if let Some(r) = args.flags.get("rounds") {
        job.rounds = r.parse().map_err(|_| anyhow!("bad --rounds"))?;
    }
    if let Some(c) = args.flags.get("clients") {
        job.n_clients = c.parse().map_err(|_| anyhow!("bad --clients"))?;
    }
    if let Some(w) = args.flags.get("workers") {
        job.n_workers = w.parse().map_err(|_| anyhow!("bad --workers"))?;
    }
    if let Some(s) = args.flags.get("seed") {
        job.seed = s.parse().map_err(|_| anyhow!("bad --seed"))?;
    }
    if let Some(n) = args.flags.get("dataset-n") {
        job.dataset.n = n.parse().map_err(|_| anyhow!("bad --dataset-n"))?;
    }
    if let Some(p) = args.flags.get("parallelism") {
        // 0 = one worker per core; results are bitwise-identical either way.
        job.parallelism = p.parse().map_err(|_| anyhow!("bad --parallelism"))?;
    }
    if args.flags.contains_key("chain") {
        job.chain.enabled = true;
        if let Some(p) = args.flags.get("chain") {
            if p != "true" {
                job.chain.platform = p.clone();
            }
        }
    }
    job.validate()?;
    if job.rounds == 0 {
        bail!("rounds must be positive");
    }
    Ok(())
}
