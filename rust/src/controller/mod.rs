//! Logic Controller (paper §2.1 component 2 + §2.3): the synchronization
//! state machine of Algorithm 1 — ProcessPhase / NodeStage signalling,
//! stage barriers with timeouts, and fault injection.

pub mod cancel;
pub mod phases;
pub mod sync;

pub use cancel::CancelToken;
pub use phases::{NodeStage, ProcessPhase};
pub use sync::{ChurnSpec, FaultPlan, LogicController};
