//! The two signal types of the paper's Algorithm 1.

use std::fmt;

/// What phase the FL *experiment* is in (Algorithm 1's `ProcessPhase`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProcessPhase {
    /// 0 = "System Initializing"
    Initializing,
    /// 1 = "In Local Learning"
    LocalLearning,
    /// 2 = "In Model Aggregation"
    ModelAggregation,
}

impl ProcessPhase {
    pub fn code(&self) -> u8 {
        match self {
            ProcessPhase::Initializing => 0,
            ProcessPhase::LocalLearning => 1,
            ProcessPhase::ModelAggregation => 2,
        }
    }
}

impl fmt::Display for ProcessPhase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ProcessPhase::Initializing => "System Initializing",
            ProcessPhase::LocalLearning => "In Local Learning",
            ProcessPhase::ModelAggregation => "In Model Aggregation",
        };
        write!(f, "{s}")
    }
}

/// What stage a *node* is in (Algorithm 1's `NodeStage`).
///
/// Stage 3/4 read differently for clients and workers (paper §2.3):
/// 3 = "Clients busy in Training" / "Workers busy in Aggregation",
/// 4 = "Clients Waiting for Next Round" / "Aggregation Complete".
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum NodeStage {
    /// 0 = "Nodes not Ready"
    NotReady,
    /// 1 = "Nodes Ready for Job"
    ReadyForJob,
    /// 2 = "Nodes Ready with Dataset"
    ReadyWithDataset,
    /// 3 = busy (training / aggregating)
    Busy,
    /// 4 = done (waiting for next round / aggregation complete)
    Done,
}

impl NodeStage {
    pub fn code(&self) -> u8 {
        match self {
            NodeStage::NotReady => 0,
            NodeStage::ReadyForJob => 1,
            NodeStage::ReadyWithDataset => 2,
            NodeStage::Busy => 3,
            NodeStage::Done => 4,
        }
    }

    pub fn describe(&self, is_client: bool) -> &'static str {
        match (self, is_client) {
            (NodeStage::NotReady, _) => "Nodes not Ready",
            (NodeStage::ReadyForJob, _) => "Nodes Ready for Job",
            (NodeStage::ReadyWithDataset, _) => "Nodes Ready with Dataset",
            (NodeStage::Busy, true) => "Clients busy in Training",
            (NodeStage::Busy, false) => "Workers busy in Aggregation",
            (NodeStage::Done, true) => "Clients Waiting for Next Round",
            (NodeStage::Done, false) => "Aggregation Complete",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_match_paper() {
        assert_eq!(ProcessPhase::Initializing.code(), 0);
        assert_eq!(ProcessPhase::LocalLearning.code(), 1);
        assert_eq!(ProcessPhase::ModelAggregation.code(), 2);
        assert_eq!(NodeStage::NotReady.code(), 0);
        assert_eq!(NodeStage::Done.code(), 4);
    }

    #[test]
    fn role_specific_descriptions() {
        assert_eq!(NodeStage::Busy.describe(true), "Clients busy in Training");
        assert_eq!(
            NodeStage::Busy.describe(false),
            "Workers busy in Aggregation"
        );
        assert_eq!(NodeStage::Done.describe(false), "Aggregation Complete");
    }
}
