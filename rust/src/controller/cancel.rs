//! Cooperative cancellation for the round loop.
//!
//! A [`CancelToken`] is a cheap, clonable flag shared between whoever drives
//! a run (the campaign scheduler, a CLI signal handler, a test) and the
//! orchestrator's per-round loop. Cancellation is *cooperative*: the round
//! loop checks the token at every round boundary and, when it is set, stops
//! **cleanly** — the in-flight round either completes or never starts, so
//! the partial [`crate::metrics::report::RunReport`] is always a valid
//! bitwise prefix of the full run (the determinism contract extends to
//! partial runs, test-enforced by `rust/tests/campaign.rs`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A shared cancellation flag. Cloning yields a handle to the *same* flag.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation. Idempotent; never blocks. The round loop
    /// observes it at the next round boundary.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clones_share_the_flag() {
        let a = CancelToken::new();
        let b = a.clone();
        assert!(!a.is_cancelled() && !b.is_cancelled());
        b.cancel();
        assert!(a.is_cancelled() && b.is_cancelled());
        a.cancel(); // idempotent
        assert!(a.is_cancelled());
    }

    #[test]
    fn independent_tokens_do_not_interfere() {
        let a = CancelToken::new();
        let b = CancelToken::new();
        a.cancel();
        assert!(a.is_cancelled());
        assert!(!b.is_cancelled());
    }

    #[test]
    fn token_crosses_threads() {
        let t = CancelToken::new();
        let t2 = t.clone();
        std::thread::spawn(move || t2.cancel()).join().unwrap();
        assert!(t.is_cancelled());
    }
}
