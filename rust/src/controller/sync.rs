//! Algorithm 1 — FLsim node synchronization.
//!
//! The controller tracks every node's `NodeStage` and the global
//! `ProcessPhase`, enforces the stage barriers (`wait-until
//! all_nodes_in_stage(s) ∨ timeout()`), and emits the paper's progress
//! messages. Fault injection models stragglers/crashes: a faulted node never
//! reaches the awaited stage, and the barrier resolves through the timeout
//! arm with the surviving subset — exactly the fault-tolerance path of
//! Algorithm 1 lines 28/36/43/50.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Result};

use crate::controller::phases::{NodeStage, ProcessPhase};
use crate::info;
use crate::util::rng::Rng;

/// Stochastic churn evaluated *lazily*: the exact per-client draw sequence
/// the eager `materialize_faults` loop commits to a dense [`FaultPlan`],
/// replayed on demand for whichever client is being queried. This is what
/// lets a 1M-client virtual fleet carry churn without 1M × rounds resident
/// drop entries (test-enforced identical to the dense plan).
#[derive(Clone, Debug)]
pub struct ChurnSpec {
    /// The job seed the per-client churn streams derive from.
    pub seed: u64,
    /// Probability a client is up in any churn round.
    pub availability: f64,
    /// First round churn applies to.
    pub from_round: u64,
    /// Last round churn applies to (the job's round count).
    pub rounds: u64,
    /// Fleet size: only canonical `client_{i}` names with `i < n_clients`
    /// draw churn (the eager loop only draws for fleet clients).
    pub n_clients: u64,
}

impl ChurnSpec {
    fn is_down(&self, node: &str, round: u64) -> bool {
        if round < self.from_round || round > self.rounds {
            return false;
        }
        let digits = match node.strip_prefix("client_") {
            Some(d) => d,
            None => return false,
        };
        if digits.len() > 1 && digits.starts_with('0') {
            return false;
        }
        let id = match digits.parse::<u64>() {
            Ok(i) if i < self.n_clients => i,
            _ => return false,
        };
        // Replay the client's stream up to this round: the eager loop draws
        // one f64 per round in from_round..=rounds, in order.
        let mut rng = Rng::seed_from(self.seed).derive("churn", id);
        let mut draw = 0.0;
        for _ in self.from_round..=round {
            draw = rng.next_f64();
        }
        draw >= self.availability
    }
}

/// Which nodes fail (drop out) in which rounds.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Per-node rounds missed (transient drops), keyed by node name so the
    /// per-round barrier poll is a borrowed-key lookup — no allocation.
    drops: BTreeMap<String, BTreeSet<u64>>,
    /// Nodes dead from a given round onward (crash, not a transient drop).
    crashes: BTreeMap<String, u64>,
    /// Churn evaluated lazily per query instead of densely materialized
    /// (cross-device scale; `None` for eager plans).
    churn: Option<ChurnSpec>,
}

impl FaultPlan {
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// `node` misses `round` (transient straggler).
    pub fn drop_in_round(mut self, node: &str, round: u64) -> FaultPlan {
        self.drops.entry(node.to_string()).or_default().insert(round);
        self
    }

    /// `node` is dead from `round` onward. Repeated crashes keep the
    /// earliest round.
    pub fn crash_from(mut self, node: &str, round: u64) -> FaultPlan {
        self.crashes
            .entry(node.to_string())
            .and_modify(|r| *r = (*r).min(round))
            .or_insert(round);
        self
    }

    /// Attach lazily-evaluated churn (replaces any previous spec).
    pub fn with_churn(mut self, spec: ChurnSpec) -> FaultPlan {
        self.churn = Some(spec);
        self
    }

    /// Fold another plan's events into this one.
    pub fn merge(&mut self, other: FaultPlan) {
        for (node, rounds) in other.drops {
            self.drops.entry(node).or_default().extend(rounds);
        }
        for (node, round) in other.crashes {
            self.crashes
                .entry(node)
                .and_modify(|r| *r = (*r).min(round))
                .or_insert(round);
        }
        if other.churn.is_some() {
            self.churn = other.churn;
        }
    }

    pub fn is_down(&self, node: &str, round: u64) -> bool {
        self.drops
            .get(node)
            .map(|rounds| rounds.contains(&round))
            .unwrap_or(false)
            || self
                .crashes
                .get(node)
                .map(|&r| round >= r)
                .unwrap_or(false)
            || self
                .churn
                .as_ref()
                .map(|c| c.is_down(node, round))
                .unwrap_or(false)
    }

    pub fn is_empty(&self) -> bool {
        self.drops.is_empty() && self.crashes.is_empty() && self.churn.is_none()
    }
}

/// The Logic Controller state machine.
pub struct LogicController {
    phase: ProcessPhase,
    stages: BTreeMap<String, NodeStage>,
    pub fault_plan: FaultPlan,
    /// Nodes that missed the current round's virtual-clock deadline
    /// (`round_deadline_secs`): dropped through the same barrier timeout
    /// arm as fault-plan stragglers, but *emergent* — marked by the round
    /// engine when a node's simulated finish time overruns the deadline.
    /// Keyed by node name (value = the round it was marked in) so the
    /// per-barrier poll is allocation-free.
    late: BTreeMap<String, u64>,
    /// Whether barriers may resolve with a partial quorum (Algorithm 1's
    /// `timeout()` arm). When `false`, a faulted node is a hard error.
    pub allow_timeout: bool,
    /// Emitted progress log (the paper's `emit` lines), kept for tests and
    /// the dashboard.
    pub emitted: Vec<String>,
}

impl LogicController {
    pub fn new(nodes: &[String]) -> LogicController {
        LogicController {
            phase: ProcessPhase::Initializing,
            stages: nodes
                .iter()
                .map(|n| (n.clone(), NodeStage::NotReady))
                .collect(),
            fault_plan: FaultPlan::none(),
            late: BTreeMap::new(),
            allow_timeout: true,
            emitted: Vec::new(),
        }
    }

    /// Record that `node` overran the virtual-clock round deadline: it is
    /// treated as down for `round` (barrier timeout arm + alive filter),
    /// exactly like a fault-plan straggler. Entries from earlier rounds are
    /// dead (only the current round is ever queried) and are pruned here so
    /// chronic stragglers don't grow the set across a long run.
    pub fn mark_late(&mut self, node: &str, round: u64) {
        self.late.retain(|_, r| *r >= round);
        self.late.insert(node.to_string(), round);
        self.emit(&format!(
            "straggler: {node} overran the round-{round} virtual deadline"
        ));
    }

    pub fn is_late(&self, node: &str, round: u64) -> bool {
        self.late.get(node).map(|&r| r == round).unwrap_or(false)
    }

    /// Down this round: faulted by the plan, or late past the deadline.
    fn is_down(&self, node: &str, round: u64) -> bool {
        self.fault_plan.is_down(node, round) || self.is_late(node, round)
    }

    /// Up this round — the borrowed-key complement of [`Self::alive`], for
    /// callers that filter a fleet without allocating the name list.
    pub fn is_alive(&self, node: &str, round: u64) -> bool {
        !self.is_down(node, round)
    }

    /// Whether *any* node could be down in `round` (non-empty fault plan or
    /// a deadline straggler marked this round). When `false`, samplers may
    /// skip the per-name liveness scan outright — the fast path that keeps
    /// 1M-client cohort sampling free of per-client name formatting.
    pub fn may_have_downtime(&self, round: u64) -> bool {
        !self.fault_plan.is_empty() || self.late.values().any(|&r| r == round)
    }

    /// Register a node mid-run (virtual-population cohort materialization:
    /// the controller starts with only the resident worker tier, and each
    /// round's sampled clients are admitted before the training barrier).
    pub fn admit(&mut self, node: &str, stage: NodeStage) {
        self.stages.insert(node.to_string(), stage);
    }

    /// Drop a node's stage entry (cohort eviction after a round). The node
    /// can be re-admitted later; fault-plan and lateness state are keyed
    /// separately and survive.
    pub fn forget(&mut self, node: &str) {
        self.stages.remove(node);
    }

    pub fn phase(&self) -> ProcessPhase {
        self.phase
    }

    pub fn set_phase(&mut self, phase: ProcessPhase) {
        self.phase = phase;
        self.emit(&format!("ProcessPhase <- {} ({})", phase.code(), phase));
    }

    pub fn stage_of(&self, node: &str) -> NodeStage {
        self.stages
            .get(node)
            .copied()
            .unwrap_or(NodeStage::NotReady)
    }

    pub fn update_stage(&mut self, node: &str, stage: NodeStage) -> Result<()> {
        let Some(s) = self.stages.get_mut(node) else {
            bail!("unknown node '{node}'");
        };
        *s = stage;
        Ok(())
    }

    /// Reset a node set to a stage (start of each round).
    pub fn reset_stages(&mut self, nodes: &[String], stage: NodeStage) {
        for n in nodes {
            if let Some(s) = self.stages.get_mut(n) {
                *s = stage;
            }
        }
    }

    pub fn all_in_stage(&self, nodes: &[String], stage: NodeStage) -> bool {
        nodes.iter().all(|n| self.stage_of(n) == stage)
    }

    /// Algorithm 1 barrier: wait until every node in `nodes` reaches
    /// `stage`, tolerating faulted nodes via the timeout arm. Returns the
    /// responsive subset (callers require ≥ `min_quorum` survivors —
    /// Algorithm 1 line 50's `AggregatedParams >= 1`).
    pub fn barrier(
        &mut self,
        nodes: &[String],
        stage: NodeStage,
        round: u64,
        min_quorum: usize,
    ) -> Result<Vec<String>> {
        let mut present = Vec::new();
        let mut missing = Vec::new();
        for n in nodes {
            if self.is_down(n, round) {
                missing.push(n.clone());
            } else {
                // In-process nodes are synchronous: a live node has already
                // been driven to the awaited stage by the orchestrator.
                if self.stage_of(n) != stage {
                    missing.push(n.clone());
                } else {
                    present.push(n.clone());
                }
            }
        }
        if !missing.is_empty() {
            if !self.allow_timeout {
                bail!("barrier(stage {stage:?}) deadlocked: missing {missing:?}");
            }
            self.emit(&format!(
                "timeout(): proceeding without {} node(s): {missing:?}",
                missing.len()
            ));
        }
        if present.len() < min_quorum {
            bail!(
                "round {round}: quorum failure ({} < {min_quorum}) at stage {stage:?}",
                present.len()
            );
        }
        Ok(present)
    }

    pub fn emit(&mut self, msg: &str) {
        info!("controller", "{msg}");
        self.emitted.push(msg.to_string());
    }

    /// Which of `nodes` are alive this round (fault-plan + deadline filter).
    pub fn alive<'a>(&self, nodes: &'a [String], round: u64) -> Vec<String> {
        nodes
            .iter()
            .filter(|n| !self.is_down(n, round))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn stage_tracking_and_barrier() {
        let ns = nodes(&["client_0", "client_1"]);
        let mut lc = LogicController::new(&ns);
        assert!(!lc.all_in_stage(&ns, NodeStage::ReadyForJob));
        lc.update_stage("client_0", NodeStage::ReadyForJob).unwrap();
        lc.update_stage("client_1", NodeStage::ReadyForJob).unwrap();
        let present = lc.barrier(&ns, NodeStage::ReadyForJob, 1, 1).unwrap();
        assert_eq!(present.len(), 2);
    }

    #[test]
    fn faulted_node_resolves_via_timeout() {
        let ns = nodes(&["client_0", "client_1", "client_2"]);
        let mut lc = LogicController::new(&ns);
        lc.fault_plan = FaultPlan::none().drop_in_round("client_1", 3);
        for n in &ns {
            lc.update_stage(n, NodeStage::Done).unwrap();
        }
        let present = lc.barrier(&ns, NodeStage::Done, 3, 1).unwrap();
        assert_eq!(present, nodes(&["client_0", "client_2"]));
        assert!(lc.emitted.iter().any(|m| m.contains("timeout()")));
        // Other rounds unaffected.
        let present = lc.barrier(&ns, NodeStage::Done, 4, 1).unwrap();
        assert_eq!(present.len(), 3);
    }

    #[test]
    fn late_node_drops_via_timeout_arm_for_one_round() {
        let ns = nodes(&["client_0", "client_1"]);
        let mut lc = LogicController::new(&ns);
        for n in &ns {
            lc.update_stage(n, NodeStage::Done).unwrap();
        }
        lc.mark_late("client_1", 2);
        assert!(lc.is_late("client_1", 2));
        assert!(!lc.is_late("client_1", 3));
        let present = lc.barrier(&ns, NodeStage::Done, 2, 1).unwrap();
        assert_eq!(present, nodes(&["client_0"]));
        assert!(lc.emitted.iter().any(|m| m.contains("timeout()")));
        assert_eq!(lc.alive(&ns, 2), nodes(&["client_0"]));
        // The drop is round-scoped, exactly like FaultPlan::drop_in_round.
        assert_eq!(lc.barrier(&ns, NodeStage::Done, 3, 1).unwrap().len(), 2);
    }

    #[test]
    fn crash_is_permanent() {
        let plan = FaultPlan::none().crash_from("w", 5);
        assert!(!plan.is_down("w", 4));
        assert!(plan.is_down("w", 5));
        assert!(plan.is_down("w", 50));
    }

    #[test]
    fn fault_plan_merge_unions_events() {
        let mut a = FaultPlan::none()
            .drop_in_round("client_0", 2)
            .crash_from("client_1", 6);
        let b = FaultPlan::none()
            .drop_in_round("client_0", 4)
            .drop_in_round("client_2", 3)
            .crash_from("client_1", 4);
        a.merge(b);
        assert!(a.is_down("client_0", 2) && a.is_down("client_0", 4));
        assert!(!a.is_down("client_0", 3));
        assert!(a.is_down("client_2", 3));
        // Merged crashes keep the earliest round.
        assert!(a.is_down("client_1", 4) && a.is_down("client_1", 10));
        assert!(!a.is_down("client_1", 3));
        assert!(!a.is_empty());
    }

    #[test]
    fn lazy_churn_is_windowed_and_client_scoped() {
        let plan = FaultPlan::none().with_churn(ChurnSpec {
            seed: 7,
            availability: 0.0, // every draw is a drop inside the window
            from_round: 3,
            rounds: 6,
            n_clients: 4,
        });
        assert!(!plan.is_empty());
        assert!(!plan.is_down("client_0", 2), "before the window");
        assert!(plan.is_down("client_0", 3));
        assert!(plan.is_down("client_3", 6));
        assert!(!plan.is_down("client_0", 7), "after the window");
        // Non-fleet names never draw churn.
        assert!(!plan.is_down("worker_0", 4));
        assert!(!plan.is_down("client_4", 4));
        assert!(!plan.is_down("client_01", 4));
        // merge carries the spec across.
        let mut merged = FaultPlan::none().drop_in_round("client_1", 1);
        merged.merge(plan);
        assert!(merged.is_down("client_0", 4) && merged.is_down("client_1", 1));
    }

    #[test]
    fn admit_and_forget_cycle_cohorts() {
        let mut lc = LogicController::new(&nodes(&["worker_0"]));
        assert!(lc.update_stage("client_5", NodeStage::Busy).is_err());
        lc.admit("client_5", NodeStage::ReadyWithDataset);
        assert_eq!(lc.stage_of("client_5"), NodeStage::ReadyWithDataset);
        lc.update_stage("client_5", NodeStage::Busy).unwrap();
        lc.forget("client_5");
        assert!(lc.update_stage("client_5", NodeStage::Done).is_err());
        // Liveness is independent of admission.
        lc.fault_plan = FaultPlan::none().drop_in_round("client_5", 2);
        assert!(!lc.is_alive("client_5", 2));
        assert!(lc.is_alive("client_5", 3));
    }

    #[test]
    fn quorum_failure_errors() {
        let ns = nodes(&["worker_0"]);
        let mut lc = LogicController::new(&ns);
        lc.fault_plan = FaultPlan::none().drop_in_round("worker_0", 1);
        assert!(lc.barrier(&ns, NodeStage::Done, 1, 1).is_err());
    }

    #[test]
    fn no_timeout_mode_deadlocks_loudly() {
        let ns = nodes(&["client_0"]);
        let mut lc = LogicController::new(&ns);
        lc.allow_timeout = false;
        // Node never reaches the stage.
        assert!(lc.barrier(&ns, NodeStage::Done, 1, 0).is_err());
    }

    #[test]
    fn unknown_node_rejected() {
        let mut lc = LogicController::new(&nodes(&["a"]));
        assert!(lc.update_stage("ghost", NodeStage::Busy).is_err());
    }

    #[test]
    fn phase_transitions_emit() {
        let mut lc = LogicController::new(&nodes(&["a"]));
        lc.set_phase(ProcessPhase::LocalLearning);
        lc.set_phase(ProcessPhase::ModelAggregation);
        assert_eq!(lc.phase(), ProcessPhase::ModelAggregation);
        assert!(lc.emitted[0].contains("In Local Learning"));
    }
}
