//! Tables 1-2 (RQ6): reproducibility across hardware configurations.
//!
//! Three trials on each of four simulated hardware profiles (floating-point
//! reduction orders — DESIGN.md §3). Expected shape, exactly as the paper's
//! tables: trials on the same profile are **bitwise identical**; different
//! profiles drift by well under 1% absolute accuracy by round 10.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::aggregate::mean::ReductionOrder;
use crate::config::job::JobConfig;
use crate::experiments::{dataset_n_override, rounds_override, save_report};
use crate::metrics::dashboard;
use crate::metrics::report::RunReport;
use crate::orchestrator::{Orchestrator, RunOptions};
use crate::runtime::pjrt::Runtime;

pub const TRIALS: usize = 3;

pub fn job_for(profile: ReductionOrder) -> JobConfig {
    let mut j = JobConfig::default_cnn("fedavg");
    j.name = profile.profile_name().replace(' ', "_");
    j.hw_profile = profile;
    j.rounds = rounds_override(10);
    j.dataset.n = dataset_n_override(5000);
    j
}

pub fn run(rt: Arc<Runtime>) -> Result<Vec<RunReport>> {
    let orch = Orchestrator::new(rt);
    let mut all: Vec<RunReport> = Vec::new();

    for trial in 1..=TRIALS {
        for profile in ReductionOrder::ALL {
            let job = job_for(profile);
            let label = format!("{} (trial {trial})", profile.profile_name());
            let (report, _secs) = crate::bench::time_once(&label, || orch.run(&job, RunOptions::default()));
            let mut report = report?;
            report.label = label;
            save_report("tables12", &report)?;
            all.push(report);
        }
    }

    println!(
        "{}",
        dashboard::round_table(&all, |r| r.accuracy_series(), "Table 1: Accuracy")
    );
    println!(
        "{}",
        dashboard::round_table(&all, |r| r.loss_series(), "Table 2: Loss")
    );

    verify_reproducibility(&all)?;
    Ok(all)
}

/// The tables' two claims, enforced: (1) same profile ⇒ identical trials;
/// (2) cross-profile drift small but (generally) nonzero.
pub fn verify_reproducibility(all: &[RunReport]) -> Result<()> {
    let per_trial = ReductionOrder::ALL.len();
    if all.len() < 2 * per_trial {
        bail!("need at least two trials to verify reproducibility");
    }
    for (i, profile) in ReductionOrder::ALL.iter().enumerate() {
        let first = &all[i];
        for t in 1..(all.len() / per_trial) {
            let other = &all[t * per_trial + i];
            for (a, b) in first.rounds.iter().zip(&other.rounds) {
                if a.test_accuracy != b.test_accuracy || a.test_loss != b.test_loss {
                    bail!(
                        "{}: trial results differ at round {} ({} vs {})",
                        profile.profile_name(),
                        a.round,
                        a.test_accuracy,
                        b.test_accuracy
                    );
                }
                if a.model_hash != b.model_hash {
                    bail!(
                        "{}: model hash differs at round {}",
                        profile.profile_name(),
                        a.round
                    );
                }
            }
        }
    }
    // Cross-profile drift bounded (paper: ≤ ~0.6% at round 10).
    let base = all[0].final_accuracy();
    for r in &all[1..per_trial] {
        let drift = (r.final_accuracy() - base).abs();
        if drift > 0.05 {
            bail!(
                "profile {} drifted {drift:.4} from {} — too large",
                r.label,
                all[0].label
            );
        }
    }
    println!("reproducibility verified: identical trials per profile; cross-profile drift bounded");
    Ok(())
}
