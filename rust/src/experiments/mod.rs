//! One module per paper table/figure (DESIGN.md §6). Shared by the CLI,
//! the examples and the bench targets.

pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig8;
pub mod fig9;
pub mod tables12;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::campaign::{CampaignSpec, ResultStore};
use crate::metrics::dashboard;
use crate::metrics::report::RunReport;
use crate::runtime::pjrt::Runtime;

/// Rounds override for quick runs: `FLSIM_ROUNDS=N` (full paper setting
/// otherwise).
pub fn rounds_override(default: u64) -> u64 {
    std::env::var("FLSIM_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Dataset-size override for quick runs: `FLSIM_DATASET_N=N`.
pub fn dataset_n_override(default: usize) -> usize {
    std::env::var("FLSIM_DATASET_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Persist a run report under `results/<experiment>/<label>.{csv,json}`.
pub fn save_report(experiment: &str, report: &crate::metrics::report::RunReport) -> Result<()> {
    let dir = std::path::PathBuf::from("results").join(experiment);
    std::fs::create_dir_all(&dir)?;
    report.save_csv(dir.join(format!("{}.csv", report.label)))?;
    report.save_json(dir.join(format!("{}.json", report.label)))?;
    Ok(())
}

/// Execute a figure's campaign spec over the shared per-experiment result
/// store (`results/<experiment>/cache` — a second run of the same figure
/// resumes from cache), keep the per-cell golden outputs
/// (`results/<experiment>/<label>.{csv,json}`), and return the reports in
/// spec order.
///
/// `FLSIM_REFRESH=1` forces every cell to re-execute and overwrite its
/// store entry — the figure *bench* binaries set it so wall-clock/CPU
/// columns are measured fresh instead of served from a stale first run.
pub fn run_figure_campaign(
    rt: Arc<Runtime>,
    experiment: &str,
    spec: &CampaignSpec,
) -> Result<Vec<RunReport>> {
    let store = ResultStore::open(
        std::path::PathBuf::from("results").join(experiment).join("cache"),
    )?;
    let refresh = std::env::var("FLSIM_REFRESH").map(|v| v == "1").unwrap_or(false);
    let outcome = crate::campaign::run_with_options(rt, spec, &store, refresh)?;
    let mut reports = Vec::new();
    for c in outcome.completed() {
        let r = c.report.as_ref().expect("completed cells carry a report");
        println!(
            "{}{}",
            if c.cached { "[cache] " } else { "" },
            dashboard::run_line(r)
        );
        save_report(experiment, r)?;
        reports.push(r.clone());
    }
    println!("{}", outcome.summary());
    let failures = outcome.failure_lines();
    if !failures.is_empty() {
        bail!(
            "experiment {experiment}: {} cells failed (completed cells persisted):\n  {}",
            failures.len(),
            failures.join("\n  ")
        );
    }
    Ok(reports)
}

/// Run an experiment by figure/table id.
pub fn run_by_name(rt: Arc<Runtime>, which: &str) -> Result<()> {
    match which {
        "fig8" => fig8::run(rt).map(|_| ()),
        "fig9" => fig9::run(rt).map(|_| ()),
        "fig10" => fig10::run(rt).map(|_| ()),
        "fig11" => fig11::run(rt).map(|_| ()),
        "tab1" | "tab2" | "tables" => tables12::run(rt).map(|_| ()),
        "fig12" => fig12::run(rt).map(|_| ()),
        "all" => {
            fig8::run(rt.clone())?;
            fig9::run(rt.clone())?;
            fig10::run(rt.clone())?;
            fig11::run(rt.clone())?;
            tables12::run(rt.clone())?;
            fig12::run(rt)?;
            Ok(())
        }
        _ => anyhow::bail!("unknown experiment '{which}' (fig8|fig9|fig10|fig11|tables|fig12|all)"),
    }
}
