//! Fig 8 (RQ1): comparison among seven state-of-the-art FL techniques on
//! the paper's standard setting (CIFAR-10-like, Dirichlet α=0.5, 10
//! clients, batch 64, 30 rounds): accuracy, loss, wall time, CPU/memory,
//! network bandwidth.
//!
//! Ported to a thin campaign spec: one `strategy` axis over the base
//! preset, executed through the campaign engine (re-running resumes from
//! `results/fig8/cache`). Golden outputs — the
//! `results/fig8/<strategy>.{csv,json}` files and the printed tables — are
//! unchanged.

use std::sync::Arc;

use anyhow::Result;

use crate::campaign::CampaignSpec;
use crate::config::job::JobConfig;
use crate::experiments::{dataset_n_override, rounds_override, run_figure_campaign};
use crate::metrics::dashboard;
use crate::metrics::report::RunReport;
use crate::runtime::pjrt::Runtime;

pub const STRATEGIES: [&str; 7] = [
    "fedavg",
    "fedavgm",
    "scaffold",
    "moon",
    "dpfl",
    "flhc",
    "fedstellar",
];

pub fn spec() -> CampaignSpec {
    let mut base = JobConfig::default_cnn("fedavg");
    base.rounds = rounds_override(30);
    base.dataset.n = dataset_n_override(5000);
    CampaignSpec::builder("fig8", base)
        .axis_strs("strategy", &STRATEGIES)
        .build()
}

/// The expanded per-cell job list (kept as the historical public surface;
/// `run()` goes through the campaign engine directly). Infallible for the
/// static spec above.
pub fn jobs() -> Vec<JobConfig> {
    crate::campaign::expand(&spec())
        .expect("fig8 grid expands")
        .into_iter()
        .map(|c| c.job)
        .collect()
}

pub fn run(rt: Arc<Runtime>) -> Result<Vec<RunReport>> {
    let reports = run_figure_campaign(rt, "fig8", &spec())?;
    println!();
    println!("{}", dashboard::comparison("Fig 8: FL techniques", &reports));
    println!(
        "{}",
        dashboard::round_table(&reports, |r| r.accuracy_series(), "Fig 8a: Accuracy")
    );
    println!(
        "{}",
        dashboard::round_table(&reports, |r| r.loss_series(), "Fig 8b: Loss")
    );
    Ok(reports)
}
