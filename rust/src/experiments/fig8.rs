//! Fig 8 (RQ1): comparison among seven state-of-the-art FL techniques on
//! the paper's standard setting (CIFAR-10-like, Dirichlet α=0.5, 10
//! clients, batch 64, 30 rounds): accuracy, loss, wall time, CPU/memory,
//! network bandwidth.

use std::sync::Arc;

use anyhow::Result;

use crate::config::job::JobConfig;
use crate::experiments::{dataset_n_override, rounds_override, save_report};
use crate::metrics::dashboard;
use crate::metrics::report::RunReport;
use crate::orchestrator::Orchestrator;
use crate::runtime::pjrt::Runtime;

pub const STRATEGIES: [&str; 7] = [
    "fedavg",
    "fedavgm",
    "scaffold",
    "moon",
    "dpfl",
    "flhc",
    "fedstellar",
];

pub fn jobs() -> Vec<JobConfig> {
    STRATEGIES
        .iter()
        .map(|s| {
            let mut j = JobConfig::default_cnn(s);
            j.rounds = rounds_override(30);
            j.dataset.n = dataset_n_override(5000);
            j.name = s.to_string();
            j
        })
        .collect()
}

pub fn run(rt: Arc<Runtime>) -> Result<Vec<RunReport>> {
    let orch = Orchestrator::new(rt);
    let mut reports = Vec::new();
    for job in jobs() {
        let (report, _secs) =
            crate::bench::time_once(&format!("fig8/{}", job.name), || orch.run(&job));
        let report = report?;
        println!("{}", dashboard::run_line(&report));
        save_report("fig8", &report)?;
        reports.push(report);
    }
    println!();
    println!("{}", dashboard::comparison("Fig 8: FL techniques", &reports));
    println!(
        "{}",
        dashboard::round_table(&reports, |r| r.accuracy_series(), "Fig 8a: Accuracy")
    );
    println!(
        "{}",
        dashboard::round_table(&reports, |r| r.loss_series(), "Fig 8b: Loss")
    );
    Ok(reports)
}
