//! Fig 10 (RQ3): multi-worker aggregation under model poisoning, with the
//! majority-hash consensus of Chowdhury et al. [13]. Scenarios: 1M-0H,
//! 1M-1H, 1M-2H, 1M-3H (M = malicious worker, H = honest worker).
//!
//! Expected shape: honest > 50% ⇒ poisoning nullified; 1M-1H ⇒ the coin-flip
//! tie makes the trajectory fluctuate; 1M-0H ⇒ training destroyed.
//!
//! Ported to a thin campaign spec: four explicit cells sweeping the
//! `workers` axis over the malicious-worker base preset, executed through
//! the campaign engine (re-running resumes from `results/fig10/cache`).
//! Golden outputs — `results/fig10/<label>.{csv,json}` and the printed
//! tables — are unchanged.

use std::sync::Arc;

use anyhow::Result;

use crate::campaign::CampaignSpec;
use crate::config::job::JobConfig;
use crate::experiments::{dataset_n_override, rounds_override, run_figure_campaign};
use crate::metrics::dashboard;
use crate::metrics::report::RunReport;
use crate::runtime::pjrt::Runtime;
use crate::util::yaml::Yaml;

/// (label, total workers) — worker_0 is always the malicious one.
pub const SCENARIOS: [(&str, usize); 4] =
    [("1M-0H", 1), ("1M-1H", 2), ("1M-2H", 3), ("1M-3H", 4)];

pub fn spec() -> CampaignSpec {
    let mut base = JobConfig::default_cnn("fedavg");
    base.rounds = rounds_override(30);
    base.dataset.n = dataset_n_override(5000);
    base.consensus.runnable = "majority_hash".into();
    base.consensus.malicious_workers = vec!["worker_0".into()];
    let mut b = CampaignSpec::builder("fig10", base);
    for (label, n_workers) in SCENARIOS {
        b = b.cell(label, vec![("workers", Yaml::Int(n_workers as i64))]);
    }
    b.build()
}

/// The expanded per-cell job list (kept as the historical public surface;
/// `run()` goes through the campaign engine directly). Infallible for the
/// static spec above.
pub fn jobs() -> Vec<JobConfig> {
    crate::campaign::expand(&spec())
        .expect("fig10 grid expands")
        .into_iter()
        .map(|c| c.job)
        .collect()
}

pub fn run(rt: Arc<Runtime>) -> Result<Vec<RunReport>> {
    let reports = run_figure_campaign(rt, "fig10", &spec())?;
    println!();
    println!(
        "{}",
        dashboard::comparison("Fig 10: malicious-worker scenarios", &reports)
    );
    println!(
        "{}",
        dashboard::round_table(&reports, |r| r.accuracy_series(), "Fig 10: Accuracy")
    );
    Ok(reports)
}
