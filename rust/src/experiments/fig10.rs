//! Fig 10 (RQ3): multi-worker aggregation under model poisoning, with the
//! majority-hash consensus of Chowdhury et al. [13]. Scenarios: 1M-0H,
//! 1M-1H, 1M-2H, 1M-3H (M = malicious worker, H = honest worker).
//!
//! Expected shape: honest > 50% ⇒ poisoning nullified; 1M-1H ⇒ the coin-flip
//! tie makes the trajectory fluctuate; 1M-0H ⇒ training destroyed.

use std::sync::Arc;

use anyhow::Result;

use crate::config::job::JobConfig;
use crate::experiments::{dataset_n_override, rounds_override, save_report};
use crate::metrics::dashboard;
use crate::metrics::report::RunReport;
use crate::orchestrator::Orchestrator;
use crate::runtime::pjrt::Runtime;

/// (label, total workers) — worker_0 is always the malicious one.
pub const SCENARIOS: [(&str, usize); 4] =
    [("1M-0H", 1), ("1M-1H", 2), ("1M-2H", 3), ("1M-3H", 4)];

pub fn jobs() -> Vec<JobConfig> {
    SCENARIOS
        .iter()
        .map(|(label, n_workers)| {
            let mut j = JobConfig::default_cnn("fedavg");
            j.name = label.to_string();
            j.n_workers = *n_workers;
            j.rounds = rounds_override(30);
            j.dataset.n = dataset_n_override(5000);
            j.consensus.runnable = "majority_hash".into();
            j.consensus.malicious_workers = vec!["worker_0".into()];
            j
        })
        .collect()
}

pub fn run(rt: Arc<Runtime>) -> Result<Vec<RunReport>> {
    let orch = Orchestrator::new(rt);
    let mut reports = Vec::new();
    for job in jobs() {
        let (report, _secs) =
            crate::bench::time_once(&format!("fig10/{}", job.name), || orch.run(&job));
        let report = report?;
        println!("{}", dashboard::run_line(&report));
        save_report("fig10", &report)?;
        reports.push(report);
    }
    println!();
    println!(
        "{}",
        dashboard::comparison("Fig 10: malicious-worker scenarios", &reports)
    );
    println!(
        "{}",
        dashboard::round_table(&reports, |r| r.accuracy_series(), "Fig 10: Accuracy")
    );
    Ok(reports)
}
