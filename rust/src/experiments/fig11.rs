//! Fig 11 (RQ5): client-server vs hierarchical vs decentralized topologies.
//! Expected shape: similar accuracy everywhere; hierarchical slightly higher
//! loss; hierarchical/decentralized higher CPU+memory; decentralized the
//! most network bandwidth.
//!
//! Ported to a thin campaign spec: three explicit cells (the sweep is
//! *paired* — the decentralized point swaps both strategy and topology —
//! so it is not a pure axis grid). Golden `results/fig11/<label>.{csv,json}`
//! outputs are unchanged, and re-running resumes from the result cache.

use std::sync::Arc;

use anyhow::Result;

use crate::campaign::CampaignSpec;
use crate::config::job::JobConfig;
use crate::experiments::{dataset_n_override, rounds_override, run_figure_campaign};
use crate::metrics::dashboard;
use crate::metrics::report::RunReport;
use crate::runtime::pjrt::Runtime;
use crate::util::yaml::Yaml;

pub fn spec() -> CampaignSpec {
    let mut base = JobConfig::default_cnn("fedavg");
    base.rounds = rounds_override(30);
    base.dataset.n = dataset_n_override(5000);
    CampaignSpec::builder("fig11", base)
        // (1) client-server: FedAvg [1] — the base job as-is.
        .cell("client_server", vec![])
        // (2) hierarchical: leaf-cluster aggregation + root merge ([26]'s
        //     topology; 3 clusters over 10 clients).
        .cell(
            "hierarchical",
            vec![("topology", "hierarchical".into()), ("workers", Yaml::Int(3))],
        )
        // (3) decentralized: Fedstellar [24] on a full mesh.
        .cell("decentralized", vec![("strategy", "fedstellar".into())])
        .build()
}

/// The expanded per-cell job list (kept as the historical public surface;
/// `run()` goes through the campaign engine directly). Infallible for the
/// static spec above.
pub fn jobs() -> Vec<JobConfig> {
    crate::campaign::expand(&spec())
        .expect("fig11 cells expand")
        .into_iter()
        .map(|c| c.job)
        .collect()
}

pub fn run(rt: Arc<Runtime>) -> Result<Vec<RunReport>> {
    let reports = run_figure_campaign(rt, "fig11", &spec())?;
    println!();
    println!("{}", dashboard::comparison("Fig 11: topologies", &reports));
    Ok(reports)
}
