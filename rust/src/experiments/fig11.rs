//! Fig 11 (RQ5): client-server vs hierarchical vs decentralized topologies.
//! Expected shape: similar accuracy everywhere; hierarchical slightly higher
//! loss; hierarchical/decentralized higher CPU+memory; decentralized the
//! most network bandwidth.

use std::sync::Arc;

use anyhow::Result;

use crate::config::job::JobConfig;
use crate::experiments::{dataset_n_override, rounds_override, save_report};
use crate::metrics::dashboard;
use crate::metrics::report::RunReport;
use crate::orchestrator::Orchestrator;
use crate::runtime::pjrt::Runtime;
use crate::topology::TopologyKind;

pub fn jobs() -> Vec<JobConfig> {
    let mut out = Vec::new();

    // (1) client-server: FedAvg [1].
    let mut cs = JobConfig::default_cnn("fedavg");
    cs.name = "client_server".into();
    out.push(cs);

    // (2) hierarchical: leaf-cluster aggregation + root merge ([26]'s
    //     topology; 3 clusters over 10 clients).
    let mut h = JobConfig::default_cnn("fedavg");
    h.name = "hierarchical".into();
    h.topology = TopologyKind::Hierarchical;
    h.n_workers = 3;
    out.push(h);

    // (3) decentralized: Fedstellar [24] on a full mesh.
    let mut d = JobConfig::default_cnn("fedstellar");
    d.name = "decentralized".into();
    out.push(d);

    for j in &mut out {
        j.rounds = rounds_override(30);
        j.dataset.n = dataset_n_override(5000);
    }
    out
}

pub fn run(rt: Arc<Runtime>) -> Result<Vec<RunReport>> {
    let orch = Orchestrator::new(rt);
    let mut reports = Vec::new();
    for job in jobs() {
        let (report, _secs) =
            crate::bench::time_once(&format!("fig11/{}", job.name), || orch.run(&job));
        let report = report?;
        println!("{}", dashboard::run_line(&report));
        save_report("fig11", &report)?;
        reports.push(report);
    }
    println!();
    println!("{}", dashboard::comparison("Fig 11: topologies", &reports));
    Ok(reports)
}
