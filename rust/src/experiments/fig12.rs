//! Fig 12 (RQ7): large-scale experiments — MNIST logistic regression with
//! 100 / 250 / 500 / 1000 clients. Expected shape: accuracy identical
//! across client counts; network bandwidth and total time grow with the
//! number of clients.

use std::sync::Arc;

use anyhow::Result;

use crate::config::job::JobConfig;
use crate::experiments::{rounds_override, save_report};
use crate::metrics::dashboard;
use crate::metrics::report::RunReport;
use crate::orchestrator::{Orchestrator, RunOptions};
use crate::runtime::pjrt::Runtime;

pub const CLIENT_COUNTS: [usize; 4] = [100, 250, 500, 1000];

pub fn jobs() -> Vec<JobConfig> {
    CLIENT_COUNTS
        .iter()
        .map(|&n| {
            let mut j = JobConfig::scale_logreg(n);
            j.rounds = rounds_override(10);
            // Own knob (not FLSIM_DATASET_N): the scale run must keep a
            // realistic per-client shard even in quick passes.
            j.dataset.n = std::env::var("FLSIM_SCALE_DATASET_N")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(60_000);
            j
        })
        .collect()
}

pub fn run(rt: Arc<Runtime>) -> Result<Vec<RunReport>> {
    let orch = Orchestrator::new(rt);
    let mut reports = Vec::new();
    for job in jobs() {
        let (report, _secs) =
            crate::bench::time_once(&format!("fig12/{}", job.name), || orch.run(&job, RunOptions::default()));
        let report = report?;
        println!("{}", dashboard::run_line(&report));
        save_report("fig12", &report)?;
        reports.push(report);
    }
    println!();
    println!("{}", dashboard::comparison("Fig 12: scalability", &reports));
    Ok(reports)
}
