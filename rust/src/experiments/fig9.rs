//! Fig 9 (RQ2): ML-library agnosticism. The paper runs PyTorch /
//! TensorFlow / Scikit-Learn implementations unchanged; here the analogous
//! property is backend-agnosticism — the same FedAvg job over the `cnn`
//! ("torch"), `cnn_v2` ("tensorflow") and `mlp` ("sklearn") manifest
//! backends (DESIGN.md §2).

use std::sync::Arc;

use anyhow::Result;

use crate::config::job::JobConfig;
use crate::experiments::{dataset_n_override, rounds_override, save_report};
use crate::metrics::dashboard;
use crate::metrics::report::RunReport;
use crate::orchestrator::Orchestrator;
use crate::runtime::pjrt::Runtime;

pub const BACKENDS: [(&str, &str); 3] = [
    ("cnn", "pytorch-analog"),
    ("cnn_v2", "tensorflow-analog"),
    ("mlp", "sklearn-analog"),
];

pub fn jobs() -> Vec<JobConfig> {
    BACKENDS
        .iter()
        .map(|(backend, label)| {
            let mut j = JobConfig::default_cnn("fedavg");
            j.backend = backend.to_string();
            j.rounds = rounds_override(30);
            j.dataset.n = dataset_n_override(5000);
            j.name = label.to_string();
            j
        })
        .collect()
}

pub fn run(rt: Arc<Runtime>) -> Result<Vec<RunReport>> {
    let orch = Orchestrator::new(rt);
    let mut reports = Vec::new();
    for job in jobs() {
        let (report, _secs) =
            crate::bench::time_once(&format!("fig9/{}", job.name), || orch.run(&job));
        let report = report?;
        println!("{}", dashboard::run_line(&report));
        save_report("fig9", &report)?;
        reports.push(report);
    }
    println!();
    println!("{}", dashboard::comparison("Fig 9: ML library backends", &reports));
    Ok(reports)
}
