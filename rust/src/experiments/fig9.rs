//! Fig 9 (RQ2): ML-library agnosticism. The paper runs PyTorch /
//! TensorFlow / Scikit-Learn implementations unchanged; here the analogous
//! property is backend-agnosticism — the same FedAvg job over the `cnn`
//! ("torch"), `cnn_v2` ("tensorflow") and `mlp` ("sklearn") manifest
//! backends (DESIGN.md §2).
//!
//! Ported to a thin campaign spec: three explicit named cells sweeping the
//! `backend` axis (the cells carry the paper's library labels, keeping the
//! golden `results/fig9/<label>.{csv,json}` outputs).

use std::sync::Arc;

use anyhow::Result;

use crate::campaign::CampaignSpec;
use crate::config::job::JobConfig;
use crate::experiments::{dataset_n_override, rounds_override, run_figure_campaign};
use crate::metrics::dashboard;
use crate::metrics::report::RunReport;
use crate::runtime::pjrt::Runtime;

pub const BACKENDS: [(&str, &str); 3] = [
    ("cnn", "pytorch-analog"),
    ("cnn_v2", "tensorflow-analog"),
    ("mlp", "sklearn-analog"),
];

pub fn spec() -> CampaignSpec {
    let mut base = JobConfig::default_cnn("fedavg");
    base.rounds = rounds_override(30);
    base.dataset.n = dataset_n_override(5000);
    let mut b = CampaignSpec::builder("fig9", base);
    for (backend, label) in BACKENDS {
        b = b.cell(label, vec![("backend", backend.into())]);
    }
    b.build()
}

/// The expanded per-cell job list (kept as the historical public surface;
/// `run()` goes through the campaign engine directly). Infallible for the
/// static spec above.
pub fn jobs() -> Vec<JobConfig> {
    crate::campaign::expand(&spec())
        .expect("fig9 cells expand")
        .into_iter()
        .map(|c| c.job)
        .collect()
}

pub fn run(rt: Arc<Runtime>) -> Result<Vec<RunReport>> {
    let reports = run_figure_campaign(rt, "fig9", &spec())?;
    println!();
    println!("{}", dashboard::comparison("Fig 9: ML library backends", &reports));
    Ok(reports)
}
