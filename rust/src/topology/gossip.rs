//! Gossip schedules for decentralized topologies: which peer exchanges with
//! which in a round (used by the Fedstellar-style DFL strategy).

use crate::topology::graph::Overlay;
use crate::util::rng::Rng;

/// A round's exchange plan: for each peer, the peers it pulls models from.
#[derive(Clone, Debug)]
pub struct GossipPlan {
    pub pulls: Vec<(String, Vec<String>)>,
}

/// Full gossip: every peer pulls from all of its overlay neighbors
/// (fully-connected DFL — highest bandwidth, matches Fig 11e).
pub fn full_exchange(overlay: &Overlay) -> GossipPlan {
    let mut pulls = Vec::new();
    let mut peers = overlay.clients();
    peers.sort();
    for p in peers {
        let mut ns = overlay.neighbors(&p);
        ns.sort();
        pulls.push((p, ns));
    }
    GossipPlan { pulls }
}

/// Random-k gossip: each peer pulls from k random neighbors (deterministic
/// under the round-derived rng).
pub fn random_k(overlay: &Overlay, k: usize, rng: &mut Rng) -> GossipPlan {
    let mut pulls = Vec::new();
    let mut peers = overlay.clients();
    peers.sort();
    for p in peers {
        let mut ns = overlay.neighbors(&p);
        ns.sort();
        if ns.len() > k {
            let idx = rng.choose_indices(ns.len(), k);
            let mut chosen: Vec<String> = idx.into_iter().map(|i| ns[i].clone()).collect();
            chosen.sort();
            pulls.push((p, chosen));
        } else {
            pulls.push((p, ns));
        }
    }
    GossipPlan { pulls }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_exchange_covers_all_neighbors() {
        let o = Overlay::fully_connected(4);
        let plan = full_exchange(&o);
        assert_eq!(plan.pulls.len(), 4);
        for (_, ns) in &plan.pulls {
            assert_eq!(ns.len(), 3);
        }
    }

    #[test]
    fn random_k_bounded_and_deterministic() {
        let o = Overlay::fully_connected(6);
        let a = random_k(&o, 2, &mut Rng::seed_from(1));
        let b = random_k(&o, 2, &mut Rng::seed_from(1));
        for ((pa, na), (pb, nb)) in a.pulls.iter().zip(&b.pulls) {
            assert_eq!(pa, pb);
            assert_eq!(na, nb);
            assert_eq!(na.len(), 2);
        }
    }

    #[test]
    fn ring_gossip_uses_ring_neighbors() {
        let o = Overlay::ring(5);
        let plan = full_exchange(&o);
        for (_, ns) in &plan.pulls {
            assert_eq!(ns.len(), 2);
        }
    }
}
