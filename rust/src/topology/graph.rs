//! Overlay graph: the node-role assignment plus directed communication
//! edges an FL job runs over (paper Fig 2c "cluster config" / Fig 4).

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeRole {
    Client,
    Worker,
    /// Acts as both (decentralized FL: every peer trains and aggregates).
    Hybrid,
}

/// Communication class of an overlay edge — what kind of physical link a
/// hop over this edge rides on. The network fabric prices each class with
/// its own [`crate::kvstore::netsim::LinkModel`] (the `network:` config
/// section), which is how topology choice turns into transfer *time*
/// (paper Fig 11e) instead of just message counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LinkClass {
    /// Last-mile client uplink (client↔worker, peer↔peer).
    Edge,
    /// Server-tier datacenter link (worker↔worker, leaf↔root).
    Lan,
    /// Inter-site link (only reachable via explicit overrides).
    Wan,
}

impl LinkClass {
    pub fn name(&self) -> &'static str {
        match self {
            LinkClass::Edge => "edge",
            LinkClass::Lan => "lan",
            LinkClass::Wan => "wan",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// Classic FedAvg star: clients <-> workers.
    ClientServer,
    /// Two-level tree: leaf clusters aggregate locally, then an upstream
    /// root cluster merges cluster models (paper's hierarchical FL).
    Hierarchical,
    /// Fully-connected peer-to-peer (Fedstellar's DFL baseline).
    FullyConnected,
    /// Ring gossip.
    Ring,
}

impl TopologyKind {
    pub fn parse(s: &str) -> Result<TopologyKind> {
        Ok(match s {
            "client_server" | "client-server" | "star" => TopologyKind::ClientServer,
            "hierarchical" | "hfl" => TopologyKind::Hierarchical,
            "fully_connected" | "p2p" | "dfl" => TopologyKind::FullyConnected,
            "ring" => TopologyKind::Ring,
            _ => return Err(anyhow!("unknown topology '{s}'")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::ClientServer => "client_server",
            TopologyKind::Hierarchical => "hierarchical",
            TopologyKind::FullyConnected => "fully_connected",
            TopologyKind::Ring => "ring",
        }
    }
}

/// A cluster: a set of client nodes served by a set of worker nodes.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub name: String,
    pub clients: Vec<String>,
    pub workers: Vec<String>,
    /// Name of the upstream cluster (hierarchical topologies), if any.
    pub upstream: Option<String>,
}

/// The overlay: nodes with roles, directed edges, cluster structure.
#[derive(Clone, Debug, Default)]
pub struct Overlay {
    pub roles: BTreeMap<String, NodeRole>,
    pub edges: BTreeSet<(String, String)>,
    pub clusters: Vec<Cluster>,
    /// Cross-device scale: number of *virtual* clients (`client_0 ..
    /// client_{n-1}`) that are part of the job but not materialized as
    /// overlay nodes — a 1M-client star would otherwise carry O(N·workers)
    /// resident edges. `0` = fully materialized overlay (the default).
    /// Virtual client↔worker links are priced by the network fabric's
    /// star fast path instead of edge lookups.
    pub virtual_clients: usize,
}

impl Overlay {
    /// Star topology: `n_clients` clients all connected to `n_workers`
    /// workers (multi-worker => the consensus path of §2.5).
    pub fn client_server(n_clients: usize, n_workers: usize) -> Overlay {
        let clients: Vec<String> = (0..n_clients).map(|i| format!("client_{i}")).collect();
        let workers: Vec<String> = (0..n_workers).map(|i| format!("worker_{i}")).collect();
        let mut o = Overlay::default();
        for c in &clients {
            o.roles.insert(c.clone(), NodeRole::Client);
        }
        for w in &workers {
            o.roles.insert(w.clone(), NodeRole::Worker);
        }
        for c in &clients {
            for w in &workers {
                o.edges.insert((c.clone(), w.clone()));
                o.edges.insert((w.clone(), c.clone()));
            }
        }
        // The server tier is fully connected (consensus vote exchange rides
        // LAN links, never a client uplink).
        for a in &workers {
            for b in &workers {
                if a != b {
                    o.edges.insert((a.clone(), b.clone()));
                }
            }
        }
        o.clusters.push(Cluster {
            name: "cluster_0".into(),
            clients,
            workers,
            upstream: None,
        });
        o
    }

    /// Star topology with a *virtual* client tier: only the worker mesh is
    /// materialized; the `n_clients` clients exist as indices (`client_0 ..`)
    /// resolved on demand. Structurally the same job as
    /// [`Overlay::client_server`] — the per-round cohort sees identical
    /// names, link classes, and transfer prices — without the O(N·workers)
    /// edge set.
    pub fn client_server_virtual(n_clients: usize, n_workers: usize) -> Overlay {
        let workers: Vec<String> = (0..n_workers).map(|i| format!("worker_{i}")).collect();
        let mut o = Overlay::default();
        for w in &workers {
            o.roles.insert(w.clone(), NodeRole::Worker);
        }
        for a in &workers {
            for b in &workers {
                if a != b {
                    o.edges.insert((a.clone(), b.clone()));
                }
            }
        }
        o.clusters.push(Cluster {
            name: "cluster_0".into(),
            clients: Vec::new(),
            workers,
            upstream: None,
        });
        o.virtual_clients = n_clients;
        o
    }

    /// Hierarchical: `n_clusters` leaf clusters of clients, each with one
    /// worker, all reporting to a root worker.
    pub fn hierarchical(n_clients: usize, n_clusters: usize) -> Overlay {
        assert!(n_clusters > 0);
        let mut o = Overlay::default();
        let root = "root_worker".to_string();
        o.roles.insert(root.clone(), NodeRole::Worker);
        for k in 0..n_clusters {
            let w = format!("cluster{k}_worker");
            o.roles.insert(w.clone(), NodeRole::Worker);
            o.edges.insert((w.clone(), root.clone()));
            o.edges.insert((root.clone(), w.clone()));
            let mut clients = Vec::new();
            for i in 0..n_clients {
                if i % n_clusters == k {
                    let c = format!("client_{i}");
                    o.roles.insert(c.clone(), NodeRole::Client);
                    o.edges.insert((c.clone(), w.clone()));
                    o.edges.insert((w.clone(), c.clone()));
                    clients.push(c);
                }
            }
            o.clusters.push(Cluster {
                name: format!("cluster_{k}"),
                clients,
                workers: vec![w],
                upstream: Some("root".into()),
            });
        }
        o.clusters.push(Cluster {
            name: "root".into(),
            clients: Vec::new(),
            workers: vec![root],
            upstream: None,
        });
        o
    }

    /// Fully-connected DFL: every node is a hybrid peer linked to all others.
    pub fn fully_connected(n: usize) -> Overlay {
        let peers: Vec<String> = (0..n).map(|i| format!("peer_{i}")).collect();
        let mut o = Overlay::default();
        for p in &peers {
            o.roles.insert(p.clone(), NodeRole::Hybrid);
        }
        for a in &peers {
            for b in &peers {
                if a != b {
                    o.edges.insert((a.clone(), b.clone()));
                }
            }
        }
        o.clusters.push(Cluster {
            name: "mesh".into(),
            clients: peers.clone(),
            workers: peers,
            upstream: None,
        });
        o
    }

    /// Ring gossip: peer i <-> peers i±1 (mod n).
    pub fn ring(n: usize) -> Overlay {
        let peers: Vec<String> = (0..n).map(|i| format!("peer_{i}")).collect();
        let mut o = Overlay::default();
        for p in &peers {
            o.roles.insert(p.clone(), NodeRole::Hybrid);
        }
        for i in 0..n {
            let j = (i + 1) % n;
            o.edges.insert((peers[i].clone(), peers[j].clone()));
            o.edges.insert((peers[j].clone(), peers[i].clone()));
        }
        o.clusters.push(Cluster {
            name: "ring".into(),
            clients: peers.clone(),
            workers: peers,
            upstream: None,
        });
        o
    }

    pub fn build(kind: TopologyKind, n_clients: usize, n_workers: usize) -> Overlay {
        match kind {
            TopologyKind::ClientServer => Overlay::client_server(n_clients, n_workers),
            TopologyKind::Hierarchical => Overlay::hierarchical(n_clients, n_workers.max(1)),
            TopologyKind::FullyConnected => Overlay::fully_connected(n_clients),
            TopologyKind::Ring => Overlay::ring(n_clients),
        }
    }

    pub fn clients(&self) -> Vec<String> {
        self.by_role(NodeRole::Client, true)
    }

    /// Borrowed iteration over the materialized client names (hybrids
    /// included, same membership as [`Overlay::clients`]) — the round
    /// sampler walks the whole fleet every round and must not clone it.
    pub fn client_names(&self) -> impl Iterator<Item = &str> {
        self.roles
            .iter()
            .filter(|(_, &r)| matches!(r, NodeRole::Client | NodeRole::Hybrid))
            .map(|(n, _)| n.as_str())
    }

    pub fn workers(&self) -> Vec<String> {
        self.by_role(NodeRole::Worker, false)
    }

    fn by_role(&self, role: NodeRole, include_hybrid_as: bool) -> Vec<String> {
        self.roles
            .iter()
            .filter(|(_, &r)| {
                r == role
                    || (r == NodeRole::Hybrid && (include_hybrid_as || role == NodeRole::Worker))
            })
            .map(|(n, _)| n.clone())
            .collect()
    }

    pub fn neighbors(&self, node: &str) -> Vec<String> {
        self.edges
            .iter()
            .filter(|(a, _)| a == node)
            .map(|(_, b)| b.clone())
            .collect()
    }

    pub fn has_edge(&self, a: &str, b: &str) -> bool {
        self.edges.contains(&(a.to_string(), b.to_string()))
    }

    /// The hierarchical root aggregator: the worker of the upstream-less,
    /// client-less cluster every leaf reports to (None for flat overlays).
    pub fn root_worker(&self) -> Option<String> {
        self.clusters
            .iter()
            .find(|c| c.upstream.is_none() && c.clients.is_empty())
            .and_then(|c| c.workers.first().cloned())
    }

    /// Link class of the (a, b) edge, derived from the endpoint roles:
    /// any client endpoint — and a pair of hybrid peers, which are edge
    /// devices in DFL — rides the EDGE uplink; everything else (worker ↔
    /// worker, including the hierarchical root) is server-tier LAN.
    pub fn link_class(&self, a: &str, b: &str) -> LinkClass {
        match (self.roles.get(a), self.roles.get(b)) {
            (Some(NodeRole::Client), _) | (_, Some(NodeRole::Client)) => LinkClass::Edge,
            (Some(NodeRole::Hybrid), Some(NodeRole::Hybrid)) => LinkClass::Edge,
            _ => LinkClass::Lan,
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.roles.len()
    }

    /// Validate structural invariants the controller depends on.
    pub fn validate(&self) -> Result<()> {
        if self.clients().is_empty() && self.virtual_clients == 0 {
            return Err(anyhow!("overlay has no clients"));
        }
        if self.workers().is_empty() {
            return Err(anyhow!("overlay has no workers/aggregators"));
        }
        for (a, b) in &self.edges {
            if !self.roles.contains_key(a) || !self.roles.contains_key(b) {
                return Err(anyhow!("edge ({a},{b}) references unknown node"));
            }
            if a == b {
                return Err(anyhow!("self-loop on {a}"));
            }
        }
        for cl in &self.clusters {
            for n in cl.clients.iter().chain(&cl.workers) {
                if !self.roles.contains_key(n) {
                    return Err(anyhow!("cluster {} references unknown node {n}", cl.name));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_server_shape() {
        let o = Overlay::client_server(10, 2);
        assert_eq!(o.clients().len(), 10);
        assert_eq!(o.workers().len(), 2);
        assert_eq!(o.n_nodes(), 12);
        assert!(o.has_edge("client_0", "worker_1"));
        assert!(o.has_edge("worker_0", "client_9"));
        assert!(!o.has_edge("client_0", "client_1"));
        // Server tier is meshed: vote exchange never routes via a client.
        assert!(o.has_edge("worker_0", "worker_1"));
        assert!(o.has_edge("worker_1", "worker_0"));
        // Star overlays have no hierarchical root.
        assert_eq!(o.root_worker(), None);
        o.validate().unwrap();
    }

    #[test]
    fn virtual_client_server_shape() {
        let o = Overlay::client_server_virtual(1_000_000, 2);
        // Only the worker tier is resident.
        assert_eq!(o.n_nodes(), 2);
        assert_eq!(o.virtual_clients, 1_000_000);
        assert!(o.clients().is_empty());
        assert_eq!(o.workers().len(), 2);
        // The server mesh matches the eager star's.
        assert!(o.has_edge("worker_0", "worker_1"));
        assert!(o.has_edge("worker_1", "worker_0"));
        assert!(!o.has_edge("client_0", "worker_0"));
        // A clientless overlay is only valid because the clients are virtual.
        o.validate().unwrap();
        let mut bare = o.clone();
        bare.virtual_clients = 0;
        assert!(bare.validate().is_err());
    }

    #[test]
    fn client_names_matches_clients() {
        for o in [Overlay::client_server(7, 2), Overlay::fully_connected(4)] {
            let borrowed: Vec<String> =
                o.client_names().map(str::to_string).collect();
            assert_eq!(borrowed, o.clients());
        }
    }

    #[test]
    fn hierarchical_shape() {
        let o = Overlay::hierarchical(10, 3);
        // 10 clients + 3 cluster workers + root.
        assert_eq!(o.n_nodes(), 14);
        assert_eq!(o.clusters.len(), 4);
        assert!(o.has_edge("cluster0_worker", "root_worker"));
        assert!(!o.has_edge("client_0", "root_worker"));
        assert_eq!(o.root_worker().as_deref(), Some("root_worker"));
        o.validate().unwrap();
        // Every client belongs to exactly one leaf cluster.
        let mut seen = BTreeSet::new();
        for cl in &o.clusters {
            for c in &cl.clients {
                assert!(seen.insert(c.clone()), "{c} in two clusters");
            }
        }
        assert_eq!(seen.len(), 10);
    }

    #[test]
    fn fully_connected_shape() {
        let o = Overlay::fully_connected(5);
        assert_eq!(o.n_nodes(), 5);
        assert_eq!(o.edges.len(), 5 * 4);
        // Hybrids double as clients and workers.
        assert_eq!(o.clients().len(), 5);
        assert_eq!(o.workers().len(), 5);
        o.validate().unwrap();
    }

    #[test]
    fn ring_shape() {
        let o = Overlay::ring(6);
        assert_eq!(o.edges.len(), 12);
        assert_eq!(o.neighbors("peer_0").len(), 2);
        o.validate().unwrap();
    }

    #[test]
    fn link_classes_by_role() {
        let o = Overlay::client_server(4, 2);
        assert_eq!(o.link_class("client_0", "worker_0"), LinkClass::Edge);
        assert_eq!(o.link_class("worker_0", "client_3"), LinkClass::Edge);
        assert_eq!(o.link_class("worker_0", "worker_1"), LinkClass::Lan);

        let h = Overlay::hierarchical(6, 2);
        assert_eq!(h.link_class("client_0", "cluster0_worker"), LinkClass::Edge);
        assert_eq!(h.link_class("cluster0_worker", "root_worker"), LinkClass::Lan);

        let p = Overlay::fully_connected(3);
        assert_eq!(p.link_class("peer_0", "peer_1"), LinkClass::Edge);
    }

    #[test]
    fn kind_parse() {
        assert_eq!(TopologyKind::parse("p2p").unwrap(), TopologyKind::FullyConnected);
        assert_eq!(
            TopologyKind::parse("client-server").unwrap(),
            TopologyKind::ClientServer
        );
        assert!(TopologyKind::parse("torus").is_err());
    }

    #[test]
    fn validate_catches_missing_roles() {
        let mut o = Overlay::client_server(2, 1);
        o.edges.insert(("ghost".into(), "worker_0".into()));
        assert!(o.validate().is_err());
    }
}
