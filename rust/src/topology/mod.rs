//! Network topologies (paper Fig 4 / RQ5): client-server, hierarchical
//! cluster trees, decentralized fully-connected P2P and rings, represented
//! as an overlay graph the orchestrator wires nodes into.

pub mod gossip;
pub mod graph;

pub use graph::{LinkClass, NodeRole, Overlay, TopologyKind};
