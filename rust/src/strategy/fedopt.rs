//! FedOpt (Reddi et al. [6]): FedAvg clients + an adaptive server optimizer
//! (FedAdagrad / FedAdam / FedYogi) applied to the averaged pseudo-gradient
//! after consensus. An extension strategy beyond the paper's Fig 8 set,
//! from the direction its introduction cites as "server-side optimization".

use std::cell::RefCell;

use anyhow::Result;

use crate::aggregate::mean::{weighted_mean, ReductionOrder};
use crate::aggregate::server_opt::{ServerOpt, ServerOptKind};
use crate::strategy::{ClientCtx, ClientUpdate, Strategy};
use crate::util::rng::Rng;

pub struct FedOpt {
    opt: RefCell<ServerOpt>,
}

impl FedOpt {
    pub fn new(kind: ServerOptKind, server_lr: f32) -> FedOpt {
        FedOpt {
            opt: RefCell::new(ServerOpt::new(kind, server_lr)),
        }
    }
}

impl Strategy for FedOpt {
    fn name(&self) -> &'static str {
        "fedopt"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let lr = ctx.lr;
        let start = ctx.global.to_vec();
        let (params, mean_loss) =
            ctx.run_epochs(&start, |b, p, x, y| b.sgd(p, x, y, lr))?;
        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params,
            weight: ctx.n_examples as f64,
            extra: None,
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        _global: &[f32],
        order: ReductionOrder,
        _round_rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let params: Vec<&[f32]> = updates.iter().map(|u| u.params.as_slice()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.weight).collect();
        weighted_mean(&params, &weights, order)
    }

    fn post_round(
        &mut self,
        _updates: &[ClientUpdate],
        global_before: &[f32],
        consensus_params: Vec<f32>,
    ) -> Vec<f32> {
        self.opt.borrow_mut().apply(global_before, &consensus_params)
    }
}
