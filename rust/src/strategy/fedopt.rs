//! FedOpt (Reddi et al. [6]): FedAvg clients + an adaptive server optimizer
//! (FedAdagrad / FedAdam / FedYogi) applied to the averaged pseudo-gradient
//! after consensus. An extension strategy beyond the paper's Fig 8 set,
//! from the direction its introduction cites as "server-side optimization".

use anyhow::Result;

use crate::aggregate::mean::{weighted_mean_plan, AggPlan};
use crate::aggregate::server_opt::{ServerOpt, ServerOptKind};
use crate::strategy::{ClientCtx, ClientUpdate, Strategy};
use crate::util::rng::Rng;

pub struct FedOpt {
    // Held directly (not RefCell-wrapped): mutation only happens in the
    // serially-invoked `post_round(&mut self)`, and `Strategy: Send + Sync`
    // forbids interior mutability reachable from the worker pool.
    opt: ServerOpt,
}

impl FedOpt {
    pub fn new(kind: ServerOptKind, server_lr: f32) -> FedOpt {
        FedOpt {
            opt: ServerOpt::new(kind, server_lr),
        }
    }
}

impl Strategy for FedOpt {
    fn name(&self) -> &'static str {
        "fedopt"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let lr = ctx.lr;
        let start = ctx.global.to_vec();
        let (params, mean_loss) =
            ctx.run_epochs(&start, |b, p, x, y| b.sgd(p, x, y, lr))?;
        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params: ctx.share(params),
            weight: ctx.n_examples as f64,
            extra: None,
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        _global: &[f32],
        plan: AggPlan,
        _round_rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let params: Vec<&[f32]> = updates.iter().map(|u| u.params.as_ref()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.weight).collect();
        weighted_mean_plan(&params, &weights, plan)
    }

    fn post_round(
        &mut self,
        _updates: &[ClientUpdate],
        global_before: &[f32],
        consensus_params: Vec<f32>,
    ) -> Vec<f32> {
        self.opt.apply(global_before, &consensus_params)
    }
}
