//! FedAvg (McMahan et al. [1]): local SGD epochs + example-weighted
//! parameter averaging. The baseline every other strategy builds on.

use anyhow::Result;

use crate::aggregate::mean::{weighted_mean_plan, AggPlan};
use crate::strategy::{ClientCtx, ClientUpdate, Strategy};
use crate::util::rng::Rng;

pub struct FedAvg;

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let lr = ctx.lr;
        let start = ctx.global.to_vec();
        let (params, mean_loss) =
            ctx.run_epochs(&start, |b, p, x, y| b.sgd(p, x, y, lr))?;
        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params: ctx.share(params),
            weight: ctx.n_examples as f64,
            extra: None,
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        _global: &[f32],
        plan: AggPlan,
        _round_rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let params: Vec<&[f32]> = updates.iter().map(|u| u.params.as_ref()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.weight).collect();
        weighted_mean_plan(&params, &weights, plan)
    }
}
