//! FedProx (Li et al. [3]): FedAvg with a client-side proximal term
//! `(mu/2)||w - w_global||²` handled inside the backend's `prox` train step.

use anyhow::Result;

use crate::aggregate::mean::{weighted_mean_plan, AggPlan};
use crate::strategy::{ClientCtx, ClientUpdate, Strategy};
use crate::util::rng::Rng;

pub struct FedProx {
    pub mu: f32,
}

impl Strategy for FedProx {
    fn name(&self) -> &'static str {
        "fedprox"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let lr = ctx.lr;
        let mu = self.mu;
        let start = ctx.global.to_vec();
        let global_lit = ctx.backend.params_lit(ctx.global)?;
        let (params, mean_loss) = ctx.run_epochs(&start, |b, p, x, y| {
            b.prox(p, &global_lit, x, y, lr, mu)
        })?;
        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params: ctx.share(params),
            weight: ctx.n_examples as f64,
            extra: None,
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        _global: &[f32],
        plan: AggPlan,
        _round_rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let params: Vec<&[f32]> = updates.iter().map(|u| u.params.as_ref()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.weight).collect();
        weighted_mean_plan(&params, &weights, plan)
    }
}
