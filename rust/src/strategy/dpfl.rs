//! Client-level differentially-private FL (Geyer et al. [7]): the server
//! clips every client update to a norm budget and perturbs the aggregate
//! with Gaussian noise scaled to the clip bound.
//!
//! Noise is drawn from the *round-derived deterministic stream*, so DP runs
//! stay bit-reproducible under a fixed seed (RQ6) while still shifting the
//! accuracy curve slightly below plain FedAvg (paper Fig 8a).

use anyhow::Result;

use crate::aggregate::mean::{apply_dp_noise, clip_update, weighted_mean_plan, AggPlan};
use crate::strategy::{ClientCtx, ClientUpdate, Strategy};
use crate::util::rng::Rng;

pub struct DpFl {
    /// L2 clip bound on each client's update.
    pub clip: f64,
    /// Noise multiplier; per-coordinate stddev = sigma * clip / n_clients.
    pub sigma: f64,
}

impl Strategy for DpFl {
    fn name(&self) -> &'static str {
        "dpfl"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let lr = ctx.lr;
        let start = ctx.global.to_vec();
        let (params, mean_loss) =
            ctx.run_epochs(&start, |b, p, x, y| b.sgd(p, x, y, lr))?;
        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params: ctx.share(params),
            weight: ctx.n_examples as f64,
            extra: None,
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        global: &[f32],
        plan: AggPlan,
        round_rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        // Clip every client's delta to the budget, then average.
        let clipped: Vec<Vec<f32>> = updates
            .iter()
            .map(|u| clip_update(global, &u.params, self.clip))
            .collect();
        let refs: Vec<&[f32]> = clipped.iter().map(|c| c.as_slice()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.weight).collect();
        let mut agg = weighted_mean_plan(&refs, &weights, plan)?;
        // Gaussian mechanism on the aggregate (shared with channel.dp —
        // the composable re-expression this strategy is pinned against).
        apply_dp_noise(&mut agg, self.clip, self.sigma, updates.len(), round_rng);
        Ok(agg)
    }
}
