//! SCAFFOLD (Karimireddy et al. [5]): stochastic controlled averaging with
//! client/server control variates.
//!
//! The batch step runs in the backend's `scaffold` artifact
//! (`w <- w - lr (g - c_i + c)`); the option-II control-variate update is
//! element-wise and runs here: `c_i' = c_i - c + (w_0 - w_K)/(K lr)`.
//! Clients upload `(w_K, dc_i)`; the server folds `mean(dc_i)` into the
//! global control variate after consensus — the "extra states (control
//! variates)" communication the paper calls out in requirement (5).

use anyhow::Result;

use crate::aggregate::mean::{scaffold_cv_update, weighted_mean_plan, AggPlan};
use crate::strategy::{ClientCtx, ClientUpdate, Strategy};
use crate::util::rng::Rng;

#[derive(Default)]
pub struct Scaffold {
    /// Server control variate c (lazily sized on first round).
    c_global: Vec<f32>,
}

impl Strategy for Scaffold {
    fn name(&self) -> &'static str {
        "scaffold"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let dim = ctx.global.len();
        let lr = ctx.lr;
        let c_global: Vec<f32> = ctx
            .extra_state
            .map(|s| s.to_vec())
            .unwrap_or_else(|| vec![0.0; dim]);
        let c_local = ctx
            .state
            .c_local
            .clone()
            .unwrap_or_else(|| vec![0.0; dim]);

        let start = ctx.global.to_vec();
        let c_lit = ctx.backend.params_lit(&c_global)?;
        let ci_lit = ctx.backend.params_lit(&c_local)?;
        let (params, mean_loss) = ctx.run_epochs(&start, |b, p, x, y| {
            b.scaffold(p, &c_lit, &ci_lit, x, y, lr)
        })?;

        let k_steps = ctx.steps_per_round();
        let ci_new = scaffold_cv_update(&c_local, &c_global, &start, &params, k_steps, lr);
        let dci: Vec<f32> = ci_new
            .iter()
            .zip(&c_local)
            .map(|(&n, &o)| n - o)
            .collect();
        ctx.state.c_local = Some(ci_new);

        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params: ctx.share(params),
            weight: ctx.n_examples as f64,
            extra: Some(ctx.share(dci)),
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        _global: &[f32],
        plan: AggPlan,
        _round_rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let params: Vec<&[f32]> = updates.iter().map(|u| u.params.as_ref()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.weight).collect();
        weighted_mean_plan(&params, &weights, plan)
    }

    fn post_round(
        &mut self,
        updates: &[ClientUpdate],
        global_before: &[f32],
        consensus_params: Vec<f32>,
    ) -> Vec<f32> {
        // c <- c + mean_i(dc_i)  (full participation; |S| = N).
        let dim = global_before.len();
        if self.c_global.len() != dim {
            self.c_global = vec![0.0; dim];
        }
        let mut n = 0usize;
        let mut sum = vec![0f64; dim];
        for u in updates {
            if let Some(dci) = &u.extra {
                n += 1;
                for (s, &d) in sum.iter_mut().zip(dci.iter()) {
                    *s += d as f64;
                }
            }
        }
        if n > 0 {
            for (c, s) in self.c_global.iter_mut().zip(&sum) {
                *c += (*s / n as f64) as f32;
            }
        }
        consensus_params
    }

    fn client_extra_state(&self) -> Option<Vec<f32>> {
        if self.c_global.is_empty() {
            None
        } else {
            Some(self.c_global.clone())
        }
    }
}
