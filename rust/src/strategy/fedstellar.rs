//! Fedstellar-style decentralized FL (Beltrán et al. [24]): no central
//! aggregator — every peer trains locally, gossips its model to its overlay
//! neighbors, and averages what it received with its own model.
//!
//! The P2P exchange is why the paper's Fig 8e/11e show the decentralized
//! runs with the highest network bandwidth: n·(n−1) model transfers per
//! round instead of 2n.

use anyhow::Result;

use crate::aggregate::mean::{weighted_mean_plan, AggPlan};
use crate::strategy::{ClientCtx, ClientUpdate, Strategy};
use crate::util::rng::Rng;

pub struct Fedstellar {
    /// Gossip fan-in per round (0 = all overlay neighbors).
    pub neighbors: usize,
}

impl Fedstellar {
    /// Peer-local aggregation: average own update with pulled neighbor
    /// models (uniform weights — Fedstellar's default).
    pub fn peer_merge(
        &self,
        own: &ClientUpdate,
        pulled: &[&ClientUpdate],
        plan: AggPlan,
    ) -> Result<Vec<f32>> {
        let mut params: Vec<&[f32]> = vec![own.params.as_ref()];
        params.extend(pulled.iter().map(|u| u.params.as_ref()));
        let weights = vec![1.0; params.len()];
        weighted_mean_plan(&params, &weights, plan)
    }
}

impl Strategy for Fedstellar {
    fn name(&self) -> &'static str {
        "fedstellar"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let lr = ctx.lr;
        // Peers continue from their own previous model, not a global one —
        // the orchestrator passes each peer's model as `global`.
        let start = ctx.global.to_vec();
        let (params, mean_loss) =
            ctx.run_epochs(&start, |b, p, x, y| b.sgd(p, x, y, lr))?;
        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params: ctx.share(params),
            weight: ctx.n_examples as f64,
            extra: None,
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        _global: &[f32],
        plan: AggPlan,
        _round_rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        // Used for reporting: the uniform mean over peer models ("virtual
        // global model" the evaluation tracks).
        let params: Vec<&[f32]> = updates.iter().map(|u| u.params.as_ref()).collect();
        let weights = vec![1.0; params.len()];
        weighted_mean_plan(&params, &weights, plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregate::mean::ReductionOrder;

    #[test]
    fn peer_merge_uniform_average() {
        let strat = Fedstellar { neighbors: 0 };
        let mk = |v: f32| ClientUpdate {
            client: "p".into(),
            params: vec![v; 4].into(),
            weight: 1.0,
            extra: None,
            mean_loss: 0.0,
        };
        let own = mk(0.0);
        let n1 = mk(3.0);
        let n2 = mk(6.0);
        let merged = strat
            .peer_merge(&own, &[&n1, &n2], AggPlan::sequential(ReductionOrder::Sequential))
            .unwrap();
        assert!((merged[0] - 3.0).abs() < 1e-6);
    }
}
