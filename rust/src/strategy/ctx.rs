//! Client-side training context and helpers shared by all strategies.

use std::sync::Arc;

use anyhow::Result;

use crate::kvstore::arena::RoundArena;
use crate::runtime::backend::ModelBackend;
use crate::runtime::tensor::Literal;
use crate::util::rng::Rng;

/// Persistent per-client strategy state (lives in the client node across
/// rounds; the "additional states" of paper requirement 5).
#[derive(Clone, Debug, Default)]
pub struct ClientState {
    /// Previous round's local model (MOON's contrastive anchor).
    pub prev_params: Option<Vec<f32>>,
    /// SCAFFOLD local control variate.
    pub c_local: Option<Vec<f32>>,
}

/// Everything a strategy needs to run one client's local epochs.
///
/// Contexts are built per client and handed to worker threads by the
/// parallel round engine; every field is either shared-immutable or owned by
/// exactly one client, so concurrent client training is data-race-free by
/// construction.
pub struct ClientCtx<'a> {
    pub client: &'a str,
    pub backend: &'a ModelBackend,
    /// Pre-uploaded training batches (x, y literals), one entry per batch.
    pub batches: &'a [(Literal, Literal)],
    /// Current global model.
    pub global: &'a [f32],
    /// Strategy broadcast state (SCAFFOLD's c_global), if any.
    pub extra_state: Option<&'a [f32]>,
    pub lr: f32,
    pub local_epochs: usize,
    /// Number of local training examples (aggregation weight).
    pub n_examples: usize,
    /// Mutable per-client strategy state.
    pub state: &'a mut ClientState,
    /// Client-round-derived deterministic stream.
    pub rng: &'a mut Rng,
    /// Round-buffer arena the upload `Arc<[f32]>`s are shared through
    /// (recycled allocations — see [`RoundArena`]). Thread-safe: client
    /// tasks on the worker pool all point at the job's one arena.
    pub arena: &'a RoundArena,
}

/// What a client uploads after local training (paper consensus phase 1,
/// "Local Parameter Sharing").
///
/// Parameters are `Arc<[f32]>`: the same allocation flows through the KV
/// store, every worker's aggregation pull and the strategy's post-round hook
/// with refcount bumps only.
#[derive(Clone, Debug)]
pub struct ClientUpdate {
    pub client: String,
    pub params: Arc<[f32]>,
    /// Aggregation weight (= local example count).
    pub weight: f64,
    /// Strategy-specific extra upload (SCAFFOLD's delta control variate).
    pub extra: Option<Arc<[f32]>>,
    /// Mean training loss over the local epochs.
    pub mean_loss: f32,
}

impl ClientUpdate {
    /// Bytes this update costs on the wire.
    pub fn wire_bytes(&self) -> u64 {
        64 + (self.params.len() * 4) as u64
            + self.extra.as_ref().map(|e| (e.len() * 4) as u64).unwrap_or(0)
    }
}

impl<'a> ClientCtx<'a> {
    /// Run `local_epochs` over the client's batches, applying `step` to
    /// each batch. `step(params_lit, x, y) -> (new_params_lit, loss)`.
    ///
    /// Parameters stay literal-resident across the whole local loop — the
    /// only materializations are the initial upload and the final download
    /// (hot-path optimization, EXPERIMENTS.md §Perf).
    pub fn run_epochs<F>(&mut self, start: &[f32], mut step: F) -> Result<(Vec<f32>, f32)>
    where
        F: FnMut(&ModelBackend, &Literal, &Literal, &Literal) -> Result<(Literal, f32)>,
    {
        let mut params = self.backend.params_lit(start)?;
        let mut loss_sum = 0f64;
        let mut n_steps = 0usize;
        for _ in 0..self.local_epochs {
            for (x, y) in self.batches {
                let (next, loss) = step(self.backend, &params, x, y)?;
                params = next;
                loss_sum += loss as f64;
                n_steps += 1;
            }
        }
        let final_params = self.backend.to_params(&params)?;
        let mean_loss = if n_steps > 0 {
            (loss_sum / n_steps as f64) as f32
        } else {
            f32::NAN
        };
        Ok((final_params, mean_loss))
    }

    /// Total batch steps one round performs (local_epochs × batches).
    pub fn steps_per_round(&self) -> usize {
        self.local_epochs * self.batches.len()
    }

    /// Share an owned parameter vector as the upload `Arc<[f32]>`, through
    /// the round arena (recycles a released round buffer when one is free;
    /// bit-for-bit the same values as `v.into()`).
    pub fn share(&self, v: Vec<f32>) -> Arc<[f32]> {
        self.arena.store_vec(v)
    }
}
