//! MOON (Li et al. [4]): model-contrastive federated learning. The client's
//! loss adds a contrastive term pulling its representation toward the global
//! model's and away from its own previous round's — computed inside the
//! backend's `moon` artifact.

use anyhow::Result;

use crate::aggregate::mean::{weighted_mean_plan, AggPlan};
use crate::strategy::{ClientCtx, ClientUpdate, Strategy};
use crate::util::rng::Rng;

pub struct Moon {
    pub mu: f32,
    pub tau: f32,
}

impl Strategy for Moon {
    fn name(&self) -> &'static str {
        "moon"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let lr = ctx.lr;
        let (mu, tau) = (self.mu, self.tau);
        let start = ctx.global.to_vec();
        // First round: previous-local anchor defaults to the global model.
        let prev = ctx
            .state
            .prev_params
            .clone()
            .unwrap_or_else(|| start.clone());
        let global_lit = ctx.backend.params_lit(ctx.global)?;
        let prev_lit = ctx.backend.params_lit(&prev)?;
        let (params, mean_loss) = ctx.run_epochs(&start, |b, p, x, y| {
            b.moon(p, &global_lit, &prev_lit, x, y, lr, mu, tau)
        })?;
        ctx.state.prev_params = Some(params.clone());
        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params: ctx.share(params),
            weight: ctx.n_examples as f64,
            extra: None,
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        _global: &[f32],
        plan: AggPlan,
        _round_rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let params: Vec<&[f32]> = updates.iter().map(|u| u.params.as_ref()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.weight).collect();
        weighted_mean_plan(&params, &weights, plan)
    }
}
