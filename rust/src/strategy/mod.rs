//! FL strategies (paper §2.2 "FLsim FL-Strategy" / Fig 8): the pluggable
//! train + aggregate logic. Seven strategies from the paper's RQ1 evaluation
//! plus FedProx as an extension:
//!
//! | strategy    | reference                         | mode          |
//! |-------------|-----------------------------------|---------------|
//! | `fedavg`    | McMahan et al. [1]                | global        |
//! | `fedavgm`   | Hsu et al. [2] (server momentum)  | global        |
//! | `fedprox`   | Li et al. [3]                     | global        |
//! | `scaffold`  | Karimireddy et al. [5]            | global        |
//! | `moon`      | Li et al. [4] (model-contrastive) | global        |
//! | `dpfl`      | Geyer et al. [7] (client DP)      | global        |
//! | `flhc`      | Briggs et al. [26] (clustering)   | clustered     |
//! | `fedstellar`| Beltrán et al. [24]               | decentralized |

pub mod ctx;
pub mod dpfl;
pub mod fedavg;
pub mod fedavgm;
pub mod fedopt;
pub mod fedprox;
pub mod fedstellar;
pub mod flhc;
pub mod moon;
pub mod scaffold;

use anyhow::{bail, Result};

use crate::aggregate::mean::AggPlan;
use crate::util::rng::Rng;
use crate::util::yaml::Yaml;

pub use ctx::{ClientCtx, ClientUpdate};

/// How the orchestrator runs a strategy's round loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyMode {
    /// Single global model via workers (+ optional consensus).
    Global,
    /// FL+HC: one model per client cluster after the clustering round.
    Clustered,
    /// Peer-to-peer: every node trains and aggregates locally.
    Decentralized,
}

/// Parsed strategy selection with hyper-parameters (Fig 2d `extra_params`).
#[derive(Clone, Debug, PartialEq)]
pub enum StrategyKind {
    FedAvg,
    FedAvgM { server_momentum: f32 },
    FedProx { mu: f32 },
    Scaffold,
    Moon { mu: f32, tau: f32 },
    DpFl { clip: f64, sigma: f64 },
    FedOpt { kind: crate::aggregate::server_opt::ServerOptKind, server_lr: f32 },
    FlHc { cluster_round: u64, n_clusters: usize },
    Fedstellar { neighbors: usize },
}

impl StrategyKind {
    pub fn parse(name: &str, extra: &Yaml) -> Result<StrategyKind> {
        let f = |k: &str, d: f64| extra.get(k).and_then(Yaml::as_f64).unwrap_or(d);
        let i = |k: &str, d: i64| extra.get(k).and_then(Yaml::as_i64).unwrap_or(d);
        Ok(match name {
            "fedavg" => StrategyKind::FedAvg,
            "fedavgm" => StrategyKind::FedAvgM {
                server_momentum: f("server_momentum", 0.9) as f32,
            },
            "fedprox" => StrategyKind::FedProx {
                mu: f("mu", 0.01) as f32,
            },
            "scaffold" => StrategyKind::Scaffold,
            "moon" => StrategyKind::Moon {
                mu: f("mu", 1.0) as f32,
                tau: f("tau", 0.5) as f32,
            },
            "dpfl" => StrategyKind::DpFl {
                clip: f("clip", 10.0),
                sigma: f("sigma", 0.005),
            },
            "fedopt" | "fedadam" | "fedyogi" | "fedadagrad" => StrategyKind::FedOpt {
                kind: crate::aggregate::server_opt::ServerOptKind::parse(
                    extra
                        .get("server_opt")
                        .and_then(Yaml::as_str)
                        .unwrap_or(if name == "fedopt" { "adam" } else { &name[3..] }),
                )?,
                server_lr: f("server_lr", 0.1) as f32,
            },
            "flhc" => StrategyKind::FlHc {
                cluster_round: i("cluster_round", 5) as u64,
                n_clusters: i("n_clusters", 3) as usize,
            },
            "fedstellar" => StrategyKind::Fedstellar {
                neighbors: i("neighbors", 0) as usize, // 0 = all
            },
            _ => bail!("unknown strategy '{name}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::FedAvg => "fedavg",
            StrategyKind::FedAvgM { .. } => "fedavgm",
            StrategyKind::FedProx { .. } => "fedprox",
            StrategyKind::Scaffold => "scaffold",
            StrategyKind::Moon { .. } => "moon",
            StrategyKind::DpFl { .. } => "dpfl",
            StrategyKind::FedOpt { .. } => "fedopt",
            StrategyKind::FlHc { .. } => "flhc",
            StrategyKind::Fedstellar { .. } => "fedstellar",
        }
    }

    pub fn mode(&self) -> StrategyMode {
        match self {
            StrategyKind::FlHc { .. } => StrategyMode::Clustered,
            StrategyKind::Fedstellar { .. } => StrategyMode::Decentralized,
            _ => StrategyMode::Global,
        }
    }

    /// Which train-step artifact the backend must provide.
    pub fn required_artifact(&self) -> &'static str {
        match self {
            StrategyKind::FedProx { .. } => "prox",
            StrategyKind::Scaffold => "scaffold",
            StrategyKind::Moon { .. } => "moon",
            _ => "sgd",
        }
    }

    /// Instantiate the strategy implementation.
    pub fn build(&self) -> Box<dyn Strategy> {
        match self.clone() {
            StrategyKind::FedAvg => Box::new(fedavg::FedAvg),
            StrategyKind::FedAvgM { server_momentum } => {
                Box::new(fedavgm::FedAvgM::new(server_momentum))
            }
            StrategyKind::FedProx { mu } => Box::new(fedprox::FedProx { mu }),
            StrategyKind::Scaffold => Box::new(scaffold::Scaffold::default()),
            StrategyKind::Moon { mu, tau } => Box::new(moon::Moon { mu, tau }),
            StrategyKind::DpFl { clip, sigma } => Box::new(dpfl::DpFl { clip, sigma }),
            StrategyKind::FedOpt { kind, server_lr } => {
                Box::new(fedopt::FedOpt::new(kind, server_lr))
            }
            StrategyKind::FlHc {
                cluster_round,
                n_clusters,
            } => Box::new(flhc::FlHc {
                cluster_round,
                n_clusters,
            }),
            StrategyKind::Fedstellar { neighbors } => {
                Box::new(fedstellar::Fedstellar { neighbors })
            }
        }
    }
}

/// The pluggable strategy interface — the Rust analogue of the paper's
/// `LearnStrategyBase` (train / aggregate; test lives in the orchestrator's
/// evaluation loop, identical for all strategies).
///
/// `Send + Sync` is part of the contract: the parallel round engine calls
/// `client_train` concurrently from a worker pool through a shared `&dyn
/// Strategy`, so implementations must keep round-scoped mutability inside
/// `ClientCtx` (per-client) and strategy-global mutation inside the
/// serially-invoked `post_round`.
pub trait Strategy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Run one client's local training for the round; returns its update.
    /// May be called concurrently for different clients.
    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate>;

    /// Worker-side aggregation of the round's client updates into a
    /// proposal for the next global model. Pure w.r.t. strategy state
    /// (multiple workers must produce identical honest proposals). The
    /// plan's parallelism is a wall-clock hint only — results are
    /// bitwise-identical at any worker count.
    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        global: &[f32],
        plan: AggPlan,
        round_rng: &mut Rng,
    ) -> Result<Vec<f32>>;

    /// Post-consensus global state update (server momentum, control
    /// variates, ...). Receives the consensus winner; returns the final
    /// global parameters for the next round.
    fn post_round(
        &mut self,
        _updates: &[ClientUpdate],
        _global_before: &[f32],
        consensus_params: Vec<f32>,
    ) -> Vec<f32> {
        consensus_params
    }

    /// Extra per-client state the client must download before training
    /// (e.g. SCAFFOLD's c_global) — `None` for most strategies.
    fn client_extra_state(&self) -> Option<Vec<f32>> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_kinds() {
        for (n, mode) in [
            ("fedavg", StrategyMode::Global),
            ("fedavgm", StrategyMode::Global),
            ("fedprox", StrategyMode::Global),
            ("scaffold", StrategyMode::Global),
            ("moon", StrategyMode::Global),
            ("dpfl", StrategyMode::Global),
            ("flhc", StrategyMode::Clustered),
            ("fedstellar", StrategyMode::Decentralized),
        ] {
            let k = StrategyKind::parse(n, &Yaml::Null).unwrap();
            assert_eq!(k.name(), n);
            assert_eq!(k.mode(), mode);
            let _ = k.build();
        }
        assert!(StrategyKind::parse("fancy", &Yaml::Null).is_err());
    }

    #[test]
    fn extra_params_respected() {
        let y = Yaml::parse("mu: 5.0\ntau: 0.1\n").unwrap();
        match StrategyKind::parse("moon", &y).unwrap() {
            StrategyKind::Moon { mu, tau } => {
                assert_eq!(mu, 5.0);
                assert_eq!(tau, 0.1);
            }
            _ => panic!(),
        }
        let y = Yaml::parse("cluster_round: 9\nn_clusters: 4\n").unwrap();
        match StrategyKind::parse("flhc", &y).unwrap() {
            StrategyKind::FlHc {
                cluster_round,
                n_clusters,
            } => {
                assert_eq!(cluster_round, 9);
                assert_eq!(n_clusters, 4);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn required_artifacts() {
        assert_eq!(StrategyKind::FedAvg.required_artifact(), "sgd");
        assert_eq!(StrategyKind::Scaffold.required_artifact(), "scaffold");
        assert_eq!(
            StrategyKind::Moon { mu: 1.0, tau: 0.5 }.required_artifact(),
            "moon"
        );
        assert_eq!(
            StrategyKind::FedProx { mu: 0.1 }.required_artifact(),
            "prox"
        );
    }
}
