//! FL+HC (Briggs et al. [26]): federated learning with hierarchical
//! clustering of client updates.
//!
//! Runs as plain FedAvg until `cluster_round`; at that round the
//! orchestrator clusters clients by the L2 geometry of their local models
//! (agglomerative, average linkage) and from then on maintains one model
//! per cluster. Reported metrics are the example-weighted average over
//! cluster models — which is why the paper's Fig 8 shows FL+HC with the
//! lowest aggregate accuracy and the highest wall time (extra clustering +
//! per-cluster aggregation/eval work).

use anyhow::Result;

use crate::aggregate::cluster::{agglomerative_clusters, Linkage};
use crate::aggregate::mean::{weighted_mean_plan, AggPlan};
use crate::strategy::{ClientCtx, ClientUpdate, Strategy};
use crate::util::rng::Rng;

pub struct FlHc {
    pub cluster_round: u64,
    pub n_clusters: usize,
}

impl FlHc {
    /// Cluster clients by their uploaded parameters (called by the
    /// orchestrator exactly at `cluster_round`).
    pub fn cluster_clients(&self, updates: &[ClientUpdate]) -> Vec<usize> {
        let vectors: Vec<Vec<f32>> = updates.iter().map(|u| u.params.to_vec()).collect();
        agglomerative_clusters(&vectors, self.n_clusters, f64::INFINITY, Linkage::Average)
    }
}

impl Strategy for FlHc {
    fn name(&self) -> &'static str {
        "flhc"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let lr = ctx.lr;
        let start = ctx.global.to_vec();
        let (params, mean_loss) =
            ctx.run_epochs(&start, |b, p, x, y| b.sgd(p, x, y, lr))?;
        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params: ctx.share(params),
            weight: ctx.n_examples as f64,
            extra: None,
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        _global: &[f32],
        plan: AggPlan,
        _round_rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let params: Vec<&[f32]> = updates.iter().map(|u| u.params.as_ref()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.weight).collect();
        weighted_mean_plan(&params, &weights, plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clusters_split_divergent_clients() {
        let strat = FlHc {
            cluster_round: 1,
            n_clusters: 2,
        };
        let mk = |v: f32| ClientUpdate {
            client: format!("c{v}"),
            params: vec![v; 16].into(),
            weight: 1.0,
            extra: None,
            mean_loss: 0.0,
        };
        let updates = vec![mk(0.0), mk(0.1), mk(5.0), mk(5.1)];
        let ids = strat.cluster_clients(&updates);
        assert_eq!(ids[0], ids[1]);
        assert_eq!(ids[2], ids[3]);
        assert_ne!(ids[0], ids[2]);
    }
}
