//! FedAvgM (Hsu et al. [2]): FedAvg clients + server-side momentum over the
//! average update direction.

use anyhow::Result;

use crate::aggregate::mean::{apply_server_momentum, weighted_mean_plan, AggPlan};
use crate::strategy::{ClientCtx, ClientUpdate, Strategy};
use crate::util::rng::Rng;

pub struct FedAvgM {
    beta: f32,
    velocity: Vec<f32>,
}

impl FedAvgM {
    pub fn new(beta: f32) -> FedAvgM {
        FedAvgM {
            beta,
            velocity: Vec::new(),
        }
    }
}

impl Strategy for FedAvgM {
    fn name(&self) -> &'static str {
        "fedavgm"
    }

    fn client_train(&self, ctx: &mut ClientCtx) -> Result<ClientUpdate> {
        let lr = ctx.lr;
        let start = ctx.global.to_vec();
        let (params, mean_loss) =
            ctx.run_epochs(&start, |b, p, x, y| b.sgd(p, x, y, lr))?;
        Ok(ClientUpdate {
            client: ctx.client.to_string(),
            params: ctx.share(params),
            weight: ctx.n_examples as f64,
            extra: None,
            mean_loss,
        })
    }

    fn aggregate(
        &self,
        updates: &[ClientUpdate],
        _global: &[f32],
        plan: AggPlan,
        _round_rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let params: Vec<&[f32]> = updates.iter().map(|u| u.params.as_ref()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.weight).collect();
        weighted_mean_plan(&params, &weights, plan)
    }

    fn post_round(
        &mut self,
        _updates: &[ClientUpdate],
        global_before: &[f32],
        consensus_params: Vec<f32>,
    ) -> Vec<f32> {
        // v <- beta v + (w - w_avg); w <- w - v   (momentum on the server).
        apply_server_momentum(global_before, &consensus_params, &mut self.velocity, self.beta)
    }
}
