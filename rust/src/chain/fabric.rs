//! FabricSim: a Hyperledger-Fabric-flavoured simulated chain — the
//! endorse → order → validate transaction flow with an endorsement policy,
//! channels, and no gas (permissioned network).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::chain::block::{Block, Tx, TxReceipt};
use crate::chain::contract::{Contract, TxCtx};
use crate::chain::contracts::fl_contract_suite;
use crate::chain::Blockchain;
use crate::util::hash;
use crate::util::json::Json;

/// Endorsement policy: k of the n peers must endorse a tx.
#[derive(Clone, Copy, Debug)]
pub struct EndorsementPolicy {
    pub n_peers: usize,
    pub required: usize,
}

impl Default for EndorsementPolicy {
    fn default() -> Self {
        EndorsementPolicy {
            n_peers: 4,
            required: 3,
        }
    }
}

pub struct FabricSim {
    channel: String,
    blocks: Vec<Block>,
    pending: Vec<String>,
    contracts: BTreeMap<String, Box<dyn Contract>>,
    policy: EndorsementPolicy,
    /// Endorsements granted per tx hash (all-honest peers endorse
    /// deterministically; a test can shrink the policy to force failures).
    endorse_log: BTreeMap<String, usize>,
    total_txs: u64,
}

impl FabricSim {
    pub fn new(contracts: Vec<Box<dyn Contract>>, policy: EndorsementPolicy) -> FabricSim {
        let mut map = BTreeMap::new();
        for c in contracts {
            map.insert(c.name().to_string(), c);
        }
        FabricSim {
            channel: "flsim-channel".into(),
            blocks: vec![Block::seal(0, "0x0", Vec::new(), "genesis", "orderer")],
            pending: Vec::new(),
            contracts: map,
            policy,
            endorse_log: BTreeMap::new(),
            total_txs: 0,
        }
    }

    pub fn with_fl_contracts() -> FabricSim {
        FabricSim::new(fl_contract_suite(), EndorsementPolicy::default())
    }

    pub fn channel(&self) -> &str {
        &self.channel
    }

    pub fn total_txs(&self) -> u64 {
        self.total_txs
    }

    /// Phase 1 — endorsement: simulate each peer executing the chaincode
    /// read-set; honest peers all endorse identical results.
    fn endorse(&mut self, tx: &Tx) -> Result<usize> {
        let endorsements = self.policy.n_peers; // all peers honest here
        self.endorse_log.insert(tx.hash(), endorsements);
        if endorsements < self.policy.required {
            bail!(
                "endorsement policy unmet: {endorsements}/{} (need {})",
                self.policy.n_peers,
                self.policy.required
            );
        }
        Ok(endorsements)
    }

    fn state_root(&self) -> String {
        let mut s = String::new();
        for (name, c) in &self.contracts {
            s.push_str(name);
            s.push_str(&c.state_digest());
        }
        hash::sha256_hex(s.as_bytes())
    }
}

impl Blockchain for FabricSim {
    fn platform(&self) -> &'static str {
        "fabric"
    }

    fn submit_tx(&mut self, tx: Tx) -> Result<TxReceipt> {
        // endorse -> order (append to pending) -> validate+commit (invoke).
        self.endorse(&tx)?;
        let contract = self
            .contracts
            .get_mut(&tx.contract)
            .ok_or_else(|| anyhow!("no chaincode '{}' installed", tx.contract))?;
        let ctx = TxCtx {
            sender: tx.sender.clone(),
            height: self.blocks.len() as u64,
        };
        let result = contract.invoke(&tx.method, &tx.args, &ctx)?;
        let tx_hash = tx.hash();
        self.pending.push(tx_hash.clone());
        self.total_txs += 1;
        Ok(TxReceipt {
            tx_hash,
            result,
            gas_used: 0, // permissioned: no gas
        })
    }

    fn seal_block(&mut self) -> Result<&Block> {
        let height = self.blocks.len() as u64;
        let prev_hash = self.blocks.last().unwrap().hash.clone();
        let txs = std::mem::take(&mut self.pending);
        let root = self.state_root();
        self.blocks
            .push(Block::seal(height, &prev_hash, txs, &root, "orderer"));
        Ok(self.blocks.last().unwrap())
    }

    fn query(&self, contract: &str, method: &str, args: &Json) -> Result<Json> {
        self.contracts
            .get(contract)
            .ok_or_else(|| anyhow!("no chaincode '{contract}' installed"))?
            .query(method, args)
    }

    fn height(&self) -> u64 {
        self.blocks.len() as u64 - 1
    }

    fn verify_integrity(&self) -> Result<()> {
        for (i, b) in self.blocks.iter().enumerate() {
            if !b.verify() {
                bail!("block {i} fails hash verification");
            }
            if i > 0 && b.prev_hash != self.blocks[i - 1].hash {
                bail!("block {i} prev-hash link broken");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reward_tx(node: &str) -> Tx {
        Tx::new(
            "lc",
            "reputation",
            "reward",
            Json::obj(vec![("node", Json::from(node))]),
        )
    }

    #[test]
    fn endorse_order_validate_flow() {
        let mut fab = FabricSim::with_fl_contracts();
        let r = fab.submit_tx(reward_tx("w0")).unwrap();
        assert_eq!(r.gas_used, 0);
        fab.seal_block().unwrap();
        fab.verify_integrity().unwrap();
        let score = fab
            .query("reputation", "score", &Json::obj(vec![("node", Json::from("w0"))]))
            .unwrap();
        assert_eq!(score, Json::Num(1.0));
    }

    #[test]
    fn endorsement_policy_enforced() {
        let mut fab = FabricSim::new(
            fl_contract_suite(),
            EndorsementPolicy {
                n_peers: 2,
                required: 3,
            },
        );
        assert!(fab.submit_tx(reward_tx("w0")).is_err());
    }

    #[test]
    fn same_contracts_as_ethereum() {
        // The suite deploys identically on both platforms (pluggability).
        let fab = FabricSim::with_fl_contracts();
        for c in ["param_verify", "provenance", "reputation", "consensus"] {
            assert!(
                fab.contracts.contains_key(c),
                "fabric missing contract {c}"
            );
        }
    }
}
