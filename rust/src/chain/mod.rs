//! Pluggable blockchain integration (paper §2.4 / RQ4).
//!
//! One `Blockchain` API, two simulated platforms — an Ethereum-like
//! account/gas/PoA chain and a Hyperledger-Fabric-like
//! endorse→order→validate chain — hosting the same smart-contract set:
//! parameter verification, global-model provenance, node reputation, and
//! on-chain aggregation consensus. (The paper plugs real Ethereum/Fabric
//! stacks; the pluggable-API property and the BCFL workflows are what the
//! evaluation exercises — DESIGN.md §3.)

pub mod block;
pub mod contract;
pub mod contracts;
pub mod eth;
pub mod fabric;

use anyhow::Result;

pub use block::{Block, Tx, TxReceipt};
pub use contract::{Contract, TxCtx};

use crate::util::json::Json;

/// The FLsim Blockchain API every platform wrapper implements (the paper's
/// "wrapper on the FLsim Blockchain API" step for adding a new platform).
// `Send` is part of the contract: campaign schedulers park a paused
// `JobState` (which owns the chain) between rungs and may resume it on a
// different job-pool worker thread.
pub trait Blockchain: Send {
    fn platform(&self) -> &'static str;

    /// Submit a contract-call transaction; it lands in the pending pool.
    fn submit_tx(&mut self, tx: Tx) -> Result<TxReceipt>;

    /// Seal all pending transactions into a block (applies state).
    fn seal_block(&mut self) -> Result<&Block>;

    /// Read-only contract query (no tx, no state change).
    fn query(&self, contract: &str, method: &str, args: &Json) -> Result<Json>;

    fn height(&self) -> u64;

    /// Verify hash links + per-block tx integrity of the whole chain.
    fn verify_integrity(&self) -> Result<()>;
}

/// Instantiate a platform by config name, pre-deploying the FL contracts.
pub fn by_platform(name: &str) -> Result<Box<dyn Blockchain>> {
    match name {
        "ethereum" | "eth" => Ok(Box::new(eth::EthereumSim::with_fl_contracts())),
        "fabric" | "hyperledger" => Ok(Box::new(fabric::FabricSim::with_fl_contracts())),
        _ => anyhow::bail!("unknown blockchain platform '{name}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn platform_registry() {
        assert_eq!(by_platform("ethereum").unwrap().platform(), "ethereum");
        assert_eq!(by_platform("fabric").unwrap().platform(), "fabric");
        assert!(by_platform("solana").is_err());
    }
}
