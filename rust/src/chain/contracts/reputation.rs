//! Node reputation contract: workers gain reputation when their proposal
//! wins consensus and lose it when their proposal is voted down — the
//! paper's "node reputation score maintenance" benefit.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::chain::contract::{Contract, TxCtx};
use crate::chain::contracts::param_verify::arg_str;
use crate::util::hash;
use crate::util::json::Json;

#[derive(Default)]
pub struct Reputation {
    scores: BTreeMap<String, i64>,
}

impl Contract for Reputation {
    fn name(&self) -> &'static str {
        "reputation"
    }

    fn invoke(&mut self, method: &str, args: &Json, _ctx: &TxCtx) -> Result<Json> {
        match method {
            // reward(node) / penalize(node)
            "reward" => {
                let n = arg_str(args, "node")?;
                *self.scores.entry(n).or_insert(0) += 1;
                Ok(Json::Bool(true))
            }
            "penalize" => {
                let n = arg_str(args, "node")?;
                *self.scores.entry(n).or_insert(0) -= 1;
                Ok(Json::Bool(true))
            }
            _ => bail!("reputation: unknown method '{method}'"),
        }
    }

    fn query(&self, method: &str, args: &Json) -> Result<Json> {
        match method {
            "score" => {
                let n = arg_str(args, "node")?;
                Ok(Json::Num(self.scores.get(&n).copied().unwrap_or(0) as f64))
            }
            "all" => Ok(Json::Obj(
                self.scores
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                    .collect(),
            )),
            _ => bail!("reputation: unknown query '{method}'"),
        }
    }

    fn state_digest(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.scores {
            s.push_str(&format!("{k}={v};"));
        }
        hash::sha256_hex(s.as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> TxCtx {
        TxCtx {
            sender: "lc".into(),
            height: 0,
        }
    }

    fn node_arg(n: &str) -> Json {
        Json::obj(vec![("node", Json::from(n))])
    }

    #[test]
    fn reward_and_penalize() {
        let mut c = Reputation::default();
        c.invoke("reward", &node_arg("w0"), &ctx()).unwrap();
        c.invoke("reward", &node_arg("w0"), &ctx()).unwrap();
        c.invoke("penalize", &node_arg("w1"), &ctx()).unwrap();
        assert_eq!(c.query("score", &node_arg("w0")).unwrap(), Json::Num(2.0));
        assert_eq!(c.query("score", &node_arg("w1")).unwrap(), Json::Num(-1.0));
        assert_eq!(c.query("score", &node_arg("w2")).unwrap(), Json::Num(0.0));
    }

    #[test]
    fn all_scores() {
        let mut c = Reputation::default();
        c.invoke("reward", &node_arg("a"), &ctx()).unwrap();
        let all = c.query("all", &Json::Null).unwrap();
        assert_eq!(all.get("a").unwrap().as_f64(), Some(1.0));
    }
}
