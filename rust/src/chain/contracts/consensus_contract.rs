//! On-chain aggregation consensus: the blockchain-delegated variant of the
//! paper's §2.5 pipeline. Workers submit (round, hash) proposals as
//! transactions; `decide(round)` returns the plurality hash, with ties
//! broken deterministically by lexicographic hash order (every honest chain
//! node must reach the same decision without randomness).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::chain::contract::{Contract, TxCtx};
use crate::chain::contracts::param_verify::{arg_str, arg_u64};
use crate::util::hash;
use crate::util::json::Json;

#[derive(Default)]
pub struct ConsensusContract {
    /// round -> worker -> proposed hash.
    proposals: BTreeMap<u64, BTreeMap<String, String>>,
}

impl Contract for ConsensusContract {
    fn name(&self) -> &'static str {
        "consensus"
    }

    fn invoke(&mut self, method: &str, args: &Json, ctx: &TxCtx) -> Result<Json> {
        match method {
            // propose(round, hash)
            "propose" => {
                let round = arg_u64(args, "round")?;
                let h = arg_str(args, "hash")?;
                self.proposals
                    .entry(round)
                    .or_default()
                    .insert(ctx.sender.clone(), h);
                Ok(Json::Bool(true))
            }
            _ => bail!("consensus: unknown method '{method}'"),
        }
    }

    fn query(&self, method: &str, args: &Json) -> Result<Json> {
        match method {
            // decide(round) -> {hash, votes, decisive} | null
            "decide" => {
                let round = arg_u64(args, "round")?;
                let Some(props) = self.proposals.get(&round) else {
                    return Ok(Json::Null);
                };
                let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
                for h in props.values() {
                    *counts.entry(h.as_str()).or_insert(0) += 1;
                }
                // Plurality; ties -> lexicographically smallest hash
                // (deterministic on every replica).
                let (winner, votes) = counts
                    .iter()
                    .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(a.0)))
                    .map(|(h, c)| (h.to_string(), *c))
                    .unwrap();
                Ok(Json::obj(vec![
                    ("hash", Json::from(winner.as_str())),
                    ("votes", Json::from(votes)),
                    ("decisive", Json::Bool(2 * votes > props.len())),
                ]))
            }
            "proposals" => {
                let round = arg_u64(args, "round")?;
                let props = self.proposals.get(&round).cloned().unwrap_or_default();
                Ok(Json::Obj(
                    props.into_iter().map(|(k, v)| (k, Json::Str(v))).collect(),
                ))
            }
            _ => bail!("consensus: unknown query '{method}'"),
        }
    }

    fn state_digest(&self) -> String {
        let mut s = String::new();
        for (r, m) in &self.proposals {
            s.push_str(&r.to_string());
            for (w, h) in m {
                s.push_str(w);
                s.push_str(h);
            }
        }
        hash::sha256_hex(s.as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(sender: &str) -> TxCtx {
        TxCtx {
            sender: sender.into(),
            height: 0,
        }
    }

    fn prop(round: u64, h: &str) -> Json {
        Json::obj(vec![("round", Json::from(round as usize)), ("hash", Json::from(h))])
    }

    fn round_arg(round: u64) -> Json {
        Json::obj(vec![("round", Json::from(round as usize))])
    }

    #[test]
    fn majority_decision_on_chain() {
        let mut c = ConsensusContract::default();
        c.invoke("propose", &prop(1, "honest"), &ctx("w1")).unwrap();
        c.invoke("propose", &prop(1, "honest"), &ctx("w2")).unwrap();
        c.invoke("propose", &prop(1, "evil"), &ctx("w0")).unwrap();
        let d = c.query("decide", &round_arg(1)).unwrap();
        assert_eq!(d.get("hash").unwrap().as_str(), Some("honest"));
        assert_eq!(d.get("votes").unwrap().as_f64(), Some(2.0));
        assert_eq!(d.get("decisive"), Some(&Json::Bool(true)));
    }

    #[test]
    fn tie_breaks_deterministically() {
        let mut c = ConsensusContract::default();
        c.invoke("propose", &prop(1, "bbb"), &ctx("w0")).unwrap();
        c.invoke("propose", &prop(1, "aaa"), &ctx("w1")).unwrap();
        let d = c.query("decide", &round_arg(1)).unwrap();
        assert_eq!(d.get("hash").unwrap().as_str(), Some("aaa"));
        assert_eq!(d.get("decisive"), Some(&Json::Bool(false)));
    }

    #[test]
    fn empty_round_is_null() {
        let c = ConsensusContract::default();
        assert_eq!(c.query("decide", &round_arg(3)).unwrap(), Json::Null);
    }

    #[test]
    fn reproposal_overwrites_same_worker() {
        let mut c = ConsensusContract::default();
        c.invoke("propose", &prop(1, "a"), &ctx("w0")).unwrap();
        c.invoke("propose", &prop(1, "b"), &ctx("w0")).unwrap();
        let props = c.query("proposals", &round_arg(1)).unwrap();
        assert_eq!(props.get("w0").unwrap().as_str(), Some("b"));
    }
}
