//! The FL smart-contract suite (paper §2.4's key benefits list):
//! parameter verification, provenance, reputation, on-chain consensus.

pub mod consensus_contract;
pub mod param_verify;
pub mod provenance;
pub mod reputation;

use crate::chain::contract::Contract;

/// The standard FLsim contract deployment set.
pub fn fl_contract_suite() -> Vec<Box<dyn Contract>> {
    vec![
        Box::new(param_verify::ParamVerify::default()),
        Box::new(provenance::Provenance::default()),
        Box::new(reputation::Reputation::default()),
        Box::new(consensus_contract::ConsensusContract::default()),
    ]
}
