//! Model-parameter verification contract: workers record the hash of the
//! client-parameter set they aggregated from; anyone can verify that a
//! given hash matches what the (honest) majority recorded for a round.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::chain::contract::{Contract, TxCtx};
use crate::util::hash;
use crate::util::json::Json;

#[derive(Default)]
pub struct ParamVerify {
    /// round -> worker -> recorded hash.
    records: BTreeMap<u64, BTreeMap<String, String>>,
}

impl Contract for ParamVerify {
    fn name(&self) -> &'static str {
        "param_verify"
    }

    fn invoke(&mut self, method: &str, args: &Json, ctx: &TxCtx) -> Result<Json> {
        match method {
            // record(round, hash)
            "record" => {
                let round = arg_u64(args, "round")?;
                let h = arg_str(args, "hash")?;
                self.records
                    .entry(round)
                    .or_default()
                    .insert(ctx.sender.clone(), h);
                Ok(Json::Bool(true))
            }
            _ => bail!("param_verify: unknown method '{method}'"),
        }
    }

    fn query(&self, method: &str, args: &Json) -> Result<Json> {
        match method {
            // verify(round, hash) -> bool: does `hash` match the plurality?
            "verify" => {
                let round = arg_u64(args, "round")?;
                let h = arg_str(args, "hash")?;
                let Some(recs) = self.records.get(&round) else {
                    return Ok(Json::Bool(false));
                };
                let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
                for v in recs.values() {
                    *counts.entry(v.as_str()).or_insert(0) += 1;
                }
                let max = counts.values().copied().max().unwrap_or(0);
                Ok(Json::Bool(
                    counts.get(h.as_str()).copied().unwrap_or(0) == max && max > 0,
                ))
            }
            // recorded(round) -> {worker: hash}
            "recorded" => {
                let round = arg_u64(args, "round")?;
                let recs = self.records.get(&round).cloned().unwrap_or_default();
                Ok(Json::Obj(
                    recs.into_iter().map(|(k, v)| (k, Json::Str(v))).collect(),
                ))
            }
            _ => bail!("param_verify: unknown query '{method}'"),
        }
    }

    fn state_digest(&self) -> String {
        let mut s = String::new();
        for (r, m) in &self.records {
            s.push_str(&r.to_string());
            for (w, h) in m {
                s.push_str(w);
                s.push_str(h);
            }
        }
        hash::sha256_hex(s.as_bytes())
    }
}

pub(crate) fn arg_u64(args: &Json, key: &str) -> Result<u64> {
    args.get(key)
        .and_then(Json::as_f64)
        .map(|f| f as u64)
        .ok_or_else(|| anyhow!("missing numeric arg '{key}'"))
}

pub(crate) fn arg_str(args: &Json, key: &str) -> Result<String> {
    args.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing string arg '{key}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(sender: &str) -> TxCtx {
        TxCtx {
            sender: sender.into(),
            height: 1,
        }
    }

    fn rec(round: u64, h: &str) -> Json {
        Json::obj(vec![("round", Json::from(round as usize)), ("hash", Json::from(h))])
    }

    #[test]
    fn majority_hash_verifies() {
        let mut c = ParamVerify::default();
        c.invoke("record", &rec(1, "aaa"), &ctx("w0")).unwrap();
        c.invoke("record", &rec(1, "aaa"), &ctx("w1")).unwrap();
        c.invoke("record", &rec(1, "bbb"), &ctx("w2")).unwrap();
        assert_eq!(c.query("verify", &rec(1, "aaa")).unwrap(), Json::Bool(true));
        assert_eq!(c.query("verify", &rec(1, "bbb")).unwrap(), Json::Bool(false));
        assert_eq!(c.query("verify", &rec(2, "aaa")).unwrap(), Json::Bool(false));
    }

    #[test]
    fn state_digest_changes_with_records() {
        let mut c = ParamVerify::default();
        let d0 = c.state_digest();
        c.invoke("record", &rec(1, "aaa"), &ctx("w0")).unwrap();
        assert_ne!(d0, c.state_digest());
    }

    #[test]
    fn unknown_method_errors() {
        let mut c = ParamVerify::default();
        assert!(c.invoke("mint", &Json::Null, &ctx("w0")).is_err());
        assert!(c.query("mint", &Json::Null).is_err());
    }
}
