//! Global-model provenance contract: an append-only lineage of the selected
//! global model per round — auditable ancestry for any trained model.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::chain::contract::{Contract, TxCtx};
use crate::chain::contracts::param_verify::{arg_str, arg_u64};
use crate::util::hash;
use crate::util::json::Json;

#[derive(Default)]
pub struct Provenance {
    /// round -> (model hash, selected-by, block height).
    lineage: BTreeMap<u64, (String, String, u64)>,
}

impl Contract for Provenance {
    fn name(&self) -> &'static str {
        "provenance"
    }

    fn invoke(&mut self, method: &str, args: &Json, ctx: &TxCtx) -> Result<Json> {
        match method {
            // record(round, hash)
            "record" => {
                let round = arg_u64(args, "round")?;
                let h = arg_str(args, "hash")?;
                if self.lineage.contains_key(&round) {
                    bail!("provenance: round {round} already recorded (append-only)");
                }
                self.lineage
                    .insert(round, (h, ctx.sender.clone(), ctx.height));
                Ok(Json::Bool(true))
            }
            _ => bail!("provenance: unknown method '{method}'"),
        }
    }

    fn query(&self, method: &str, args: &Json) -> Result<Json> {
        match method {
            // get(round) -> {hash, by, height} | null
            "get" => {
                let round = arg_u64(args, "round")?;
                Ok(match self.lineage.get(&round) {
                    None => Json::Null,
                    Some((h, by, height)) => Json::obj(vec![
                        ("hash", Json::from(h.as_str())),
                        ("by", Json::from(by.as_str())),
                        ("height", Json::from(*height as usize)),
                    ]),
                })
            }
            // lineage() -> [hash per round, ascending]
            "lineage" => Ok(Json::Arr(
                self.lineage
                    .values()
                    .map(|(h, _, _)| Json::from(h.as_str()))
                    .collect(),
            )),
            _ => bail!("provenance: unknown query '{method}'"),
        }
    }

    fn state_digest(&self) -> String {
        let mut s = String::new();
        for (r, (h, by, height)) in &self.lineage {
            s.push_str(&format!("{r}{h}{by}{height}"));
        }
        hash::sha256_hex(s.as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> TxCtx {
        TxCtx {
            sender: "controller".into(),
            height: 9,
        }
    }

    #[test]
    fn lineage_is_append_only() {
        let mut c = Provenance::default();
        let args = Json::obj(vec![("round", Json::from(1usize)), ("hash", Json::from("h1"))]);
        c.invoke("record", &args, &ctx()).unwrap();
        assert!(c.invoke("record", &args, &ctx()).is_err());
        let got = c
            .query("get", &Json::obj(vec![("round", Json::from(1usize))]))
            .unwrap();
        assert_eq!(got.get("hash").unwrap().as_str(), Some("h1"));
        assert_eq!(got.get("height").unwrap().as_f64(), Some(9.0));
    }

    #[test]
    fn full_lineage_query() {
        let mut c = Provenance::default();
        for r in 1..=3u64 {
            let args = Json::obj(vec![
                ("round", Json::from(r as usize)),
                ("hash", Json::from(format!("h{r}").as_str())),
            ]);
            c.invoke("record", &args, &ctx()).unwrap();
        }
        let l = c.query("lineage", &Json::Null).unwrap();
        assert_eq!(l.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn missing_round_is_null() {
        let c = Provenance::default();
        let got = c
            .query("get", &Json::obj(vec![("round", Json::from(5usize))]))
            .unwrap();
        assert_eq!(got, Json::Null);
    }
}
