//! Blocks and transactions shared by both simulated platforms.

use crate::util::hash;
use crate::util::json::Json;

/// A contract-call transaction.
#[derive(Clone, Debug)]
pub struct Tx {
    pub sender: String,
    pub contract: String,
    pub method: String,
    pub args: Json,
    pub nonce: u64,
}

impl Tx {
    pub fn new(sender: &str, contract: &str, method: &str, args: Json) -> Tx {
        Tx {
            sender: sender.to_string(),
            contract: contract.to_string(),
            method: method.to_string(),
            args,
            nonce: 0,
        }
    }

    /// Canonical byte encoding (hashing / integrity checks).
    pub fn encode(&self) -> Vec<u8> {
        format!(
            "{}|{}|{}|{}|{}",
            self.sender, self.contract, self.method, self.args, self.nonce
        )
        .into_bytes()
    }

    pub fn hash(&self) -> String {
        hash::sha256_hex(&self.encode())
    }

    /// Simulated gas: base cost + per-byte of calldata (Ethereum-flavored).
    pub fn gas(&self) -> u64 {
        21_000 + 16 * self.encode().len() as u64
    }
}

/// Result handed back on submission.
#[derive(Clone, Debug)]
pub struct TxReceipt {
    pub tx_hash: String,
    /// Contract return value (applied eagerly at submission in both sims;
    /// sealing batches the txs into a block).
    pub result: Json,
    pub gas_used: u64,
}

/// A sealed block.
#[derive(Clone, Debug)]
pub struct Block {
    pub height: u64,
    pub prev_hash: String,
    pub tx_hashes: Vec<String>,
    pub state_root: String,
    pub proposer: String,
    pub hash: String,
}

impl Block {
    pub fn seal(
        height: u64,
        prev_hash: &str,
        tx_hashes: Vec<String>,
        state_root: &str,
        proposer: &str,
    ) -> Block {
        let mut data = format!("{height}|{prev_hash}|{state_root}|{proposer}");
        for t in &tx_hashes {
            data.push('|');
            data.push_str(t);
        }
        let hash = hash::sha256_hex(data.as_bytes());
        Block {
            height,
            prev_hash: prev_hash.to_string(),
            tx_hashes,
            state_root: state_root.to_string(),
            proposer: proposer.to_string(),
            hash,
        }
    }

    /// Recompute the seal and compare (tamper detection).
    pub fn verify(&self) -> bool {
        let recomputed = Block::seal(
            self.height,
            &self.prev_hash,
            self.tx_hashes.clone(),
            &self.state_root,
            &self.proposer,
        );
        recomputed.hash == self.hash
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tx_hash_depends_on_fields() {
        let a = Tx::new("w0", "provenance", "record", Json::from("x"));
        let mut b = a.clone();
        b.nonce = 1;
        assert_ne!(a.hash(), b.hash());
        assert_eq!(a.hash(), a.clone().hash());
    }

    #[test]
    fn gas_grows_with_calldata() {
        let small = Tx::new("w0", "c", "m", Json::from("x"));
        let big = Tx::new("w0", "c", "m", Json::from("x".repeat(100).as_str()));
        assert!(big.gas() > small.gas());
        assert!(small.gas() >= 21_000);
    }

    #[test]
    fn block_seal_and_tamper_detection() {
        let b = Block::seal(1, "genesis", vec!["t1".into()], "root", "node0");
        assert!(b.verify());
        let mut tampered = b.clone();
        tampered.tx_hashes.push("t2".into());
        assert!(!tampered.verify());
        let mut tampered2 = b.clone();
        tampered2.state_root = "other".into();
        assert!(!tampered2.verify());
    }
}
