//! Smart-contract interface shared by both simulated platforms.

use anyhow::Result;

use crate::util::json::Json;

/// Call context handed to a contract (who called, at what height).
#[derive(Clone, Debug)]
pub struct TxCtx {
    pub sender: String,
    pub height: u64,
}

/// A deployed smart contract: named methods over persistent state.
///
/// The same contract objects deploy on EthereumSim and FabricSim — the
/// FLsim Blockchain API makes the platform interchangeable (paper RQ4).
pub trait Contract {
    fn name(&self) -> &'static str;

    /// State-mutating invocation (a transaction).
    fn invoke(&mut self, method: &str, args: &Json, ctx: &TxCtx) -> Result<Json>;

    /// Read-only query.
    fn query(&self, method: &str, args: &Json) -> Result<Json>;

    /// Deterministic digest of contract state (goes into the state root).
    fn state_digest(&self) -> String;
}
