//! EthereumSim: an Ethereum-flavoured simulated chain — account nonces, gas
//! accounting, proof-of-authority sealing with a round-robin validator set.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::chain::block::{Block, Tx, TxReceipt};
use crate::chain::contract::{Contract, TxCtx};
use crate::chain::contracts::fl_contract_suite;
use crate::chain::Blockchain;
use crate::util::hash;
use crate::util::json::Json;

pub struct EthereumSim {
    blocks: Vec<Block>,
    pending: Vec<String>,
    contracts: BTreeMap<String, Box<dyn Contract>>,
    nonces: BTreeMap<String, u64>,
    /// Total gas spent per account (the "cost" of BCFL participation).
    gas_spent: BTreeMap<String, u64>,
    validators: Vec<String>,
    total_txs: u64,
}

impl EthereumSim {
    pub fn new(contracts: Vec<Box<dyn Contract>>) -> EthereumSim {
        let mut map = BTreeMap::new();
        for c in contracts {
            map.insert(c.name().to_string(), c);
        }
        EthereumSim {
            blocks: vec![Block::seal(0, "0x0", Vec::new(), "genesis", "genesis")],
            pending: Vec::new(),
            contracts: map,
            nonces: BTreeMap::new(),
            gas_spent: BTreeMap::new(),
            validators: (0..4).map(|i| format!("validator_{i}")).collect(),
            total_txs: 0,
        }
    }

    pub fn with_fl_contracts() -> EthereumSim {
        EthereumSim::new(fl_contract_suite())
    }

    pub fn gas_spent_by(&self, account: &str) -> u64 {
        self.gas_spent.get(account).copied().unwrap_or(0)
    }

    pub fn total_txs(&self) -> u64 {
        self.total_txs
    }

    fn state_root(&self) -> String {
        let mut s = String::new();
        for (name, c) in &self.contracts {
            s.push_str(name);
            s.push_str(&c.state_digest());
        }
        hash::sha256_hex(s.as_bytes())
    }
}

impl Blockchain for EthereumSim {
    fn platform(&self) -> &'static str {
        "ethereum"
    }

    fn submit_tx(&mut self, mut tx: Tx) -> Result<TxReceipt> {
        // Account-model bookkeeping: per-sender nonce.
        let nonce = self.nonces.entry(tx.sender.clone()).or_insert(0);
        tx.nonce = *nonce;
        *nonce += 1;

        let contract = self
            .contracts
            .get_mut(&tx.contract)
            .ok_or_else(|| anyhow!("no contract '{}' deployed", tx.contract))?;
        let ctx = TxCtx {
            sender: tx.sender.clone(),
            height: self.blocks.len() as u64,
        };
        let result = contract.invoke(&tx.method, &tx.args, &ctx)?;
        let gas_used = tx.gas();
        *self.gas_spent.entry(tx.sender.clone()).or_insert(0) += gas_used;
        let tx_hash = tx.hash();
        self.pending.push(tx_hash.clone());
        self.total_txs += 1;
        Ok(TxReceipt {
            tx_hash,
            result,
            gas_used,
        })
    }

    fn seal_block(&mut self) -> Result<&Block> {
        let height = self.blocks.len() as u64;
        // PoA: validators take turns proposing.
        let proposer = self.validators[(height as usize) % self.validators.len()].clone();
        let prev_hash = self.blocks.last().unwrap().hash.clone();
        let txs = std::mem::take(&mut self.pending);
        let root = self.state_root();
        self.blocks
            .push(Block::seal(height, &prev_hash, txs, &root, &proposer));
        Ok(self.blocks.last().unwrap())
    }

    fn query(&self, contract: &str, method: &str, args: &Json) -> Result<Json> {
        self.contracts
            .get(contract)
            .ok_or_else(|| anyhow!("no contract '{contract}' deployed"))?
            .query(method, args)
    }

    fn height(&self) -> u64 {
        self.blocks.len() as u64 - 1
    }

    fn verify_integrity(&self) -> Result<()> {
        for (i, b) in self.blocks.iter().enumerate() {
            if !b.verify() {
                bail!("block {i} fails hash verification");
            }
            if i > 0 && b.prev_hash != self.blocks[i - 1].hash {
                bail!("block {i} prev-hash link broken");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record_tx(round: u64, h: &str, sender: &str) -> Tx {
        Tx::new(
            sender,
            "param_verify",
            "record",
            Json::obj(vec![
                ("round", Json::from(round as usize)),
                ("hash", Json::from(h)),
            ]),
        )
    }

    #[test]
    fn tx_flow_and_sealing() {
        let mut eth = EthereumSim::with_fl_contracts();
        let r = eth.submit_tx(record_tx(1, "abc", "worker_0")).unwrap();
        assert!(r.gas_used > 21_000);
        eth.submit_tx(record_tx(1, "abc", "worker_1")).unwrap();
        assert_eq!(eth.height(), 0);
        eth.seal_block().unwrap();
        assert_eq!(eth.height(), 1);
        eth.verify_integrity().unwrap();
        let ok = eth
            .query(
                "param_verify",
                "verify",
                &Json::obj(vec![("round", Json::from(1usize)), ("hash", Json::from("abc"))]),
            )
            .unwrap();
        assert_eq!(ok, Json::Bool(true));
    }

    #[test]
    fn nonces_increment_per_sender() {
        let mut eth = EthereumSim::with_fl_contracts();
        eth.submit_tx(record_tx(1, "a", "w0")).unwrap();
        eth.submit_tx(record_tx(2, "b", "w0")).unwrap();
        eth.submit_tx(record_tx(1, "a", "w1")).unwrap();
        assert_eq!(eth.nonces["w0"], 2);
        assert_eq!(eth.nonces["w1"], 1);
        assert!(eth.gas_spent_by("w0") > eth.gas_spent_by("w1"));
    }

    #[test]
    fn poa_round_robin_proposers() {
        let mut eth = EthereumSim::with_fl_contracts();
        let p1 = eth.seal_block().unwrap().proposer.clone();
        let p2 = eth.seal_block().unwrap().proposer.clone();
        assert_ne!(p1, p2);
        eth.verify_integrity().unwrap();
    }

    #[test]
    fn unknown_contract_rejected() {
        let mut eth = EthereumSim::with_fl_contracts();
        assert!(eth
            .submit_tx(Tx::new("w0", "defi", "swap", Json::Null))
            .is_err());
        assert!(eth.query("defi", "price", &Json::Null).is_err());
    }

    #[test]
    fn tamper_detection() {
        let mut eth = EthereumSim::with_fl_contracts();
        eth.submit_tx(record_tx(1, "a", "w0")).unwrap();
        eth.seal_block().unwrap();
        eth.blocks[1].tx_hashes.push("forged".into());
        assert!(eth.verify_integrity().is_err());
    }
}
