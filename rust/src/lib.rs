//! # FLsim — a modular, library-agnostic federated-learning simulation framework
//!
//! Rust reproduction of *"FLsim: A Modular and Library-Agnostic Simulation
//! Framework for Federated Learning"* (Mukherjee, Halder, Chandra — CS.DC 2025),
//! built as a three-layer stack:
//!
//! * **L3 (this crate)** — the paper's contribution: job orchestrator, logic
//!   controller (Algorithm 1), dataset distributor, pub-sub key-value store,
//!   topologies, FL strategies, aggregation, multi-worker consensus, pluggable
//!   blockchain, metrics/performance logger.
//! * **L2 (JAX, build-time)** — model forward/backward + optimizer steps and
//!   evaluation, AOT-lowered to HLO text in `artifacts/`.
//! * **L1 (Pallas, build-time)** — the tiled matmul kernel on the dense-layer
//!   hot path of every model, verified against a pure-jnp oracle.
//!
//! Python never runs at simulation time: [`runtime`] loads the AOT artifacts
//! through PJRT (the `xla` crate) and everything else is pure Rust.

pub mod adversary;
pub mod aggregate;
pub mod bench;
pub mod campaign;
pub mod chain;
pub mod config;
pub mod consensus;
pub mod controller;
pub mod data;
pub mod experiments;
pub mod kvstore;
pub mod metrics;
pub mod node;
pub mod orchestrator;
pub mod runtime;
pub mod strategy;
pub mod topology;
pub mod util;

/// Convenient re-exports for examples and binaries — the stable public
/// driving surface (see README "API stability"): construct a [`JobConfig`]
/// (or a [`CampaignSpec`]), drive it with
/// `Orchestrator::run(&job, RunOptions::default())` or a campaign runner,
/// and read [`RunReport`]s back — everything else in the crate is
/// internal-but-public plumbing that may reshape between minor versions.
pub mod prelude {
    pub use crate::campaign::{
        CampaignReport, CampaignSpec, CellOutcome, LeaseConfig, ResultStore, SchedulerSpec,
        WorkerOptions,
    };
    pub use crate::config::adversary::{AdversaryConfig, FaultsConfig, RobustAggConfig};
    pub use crate::config::job::JobConfig;
    pub use crate::controller::cancel::CancelToken;
    pub use crate::controller::sync::FaultPlan;
    pub use crate::data::dataset::DatasetSpec;
    pub use crate::kvstore::netsim::{LinkModel, LinkPolicy};
    pub use crate::metrics::report::RunReport;
    pub use crate::orchestrator::{Orchestrator, RunControl, RunHandle, RunOptions};
    pub use crate::runtime::pjrt::Runtime;
    pub use crate::strategy::StrategyKind;
    pub use crate::topology::TopologyKind;
    pub use crate::util::rng::Rng;
}
