//! Per-round metrics collection and run reports (accuracy/loss/time/CPU/
//! memory/bandwidth — the exact series the paper's evaluation figures plot),
//! with CSV and JSON export.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Everything the performance logger records for one FL round.
#[derive(Clone, Debug, Default)]
pub struct RoundMetrics {
    pub round: u64,
    pub test_accuracy: f64,
    pub test_loss: f64,
    /// Mean of clients' local training losses this round.
    pub train_loss: f64,
    /// Wall-clock seconds the round took (real time on this host).
    pub wall_secs: f64,
    /// CPU utilisation % during the round.
    pub cpu_pct: f64,
    /// Resident memory at round end (MiB).
    pub rss_mib: f64,
    /// Bytes through the KV store this round.
    pub net_bytes: u64,
    /// Simulated on-wire seconds this round (NetSim; sum over all
    /// deliveries, each priced over its overlay route).
    pub sim_net_secs: f64,
    /// Virtual-clock makespan of the round: the critical path through the
    /// parallel client phase (max download + train + upload) plus serial
    /// aggregation / consensus / gossip hops.
    pub sim_round_secs: f64,
    /// Global-model parameter hash (provenance / reproducibility).
    pub model_hash: String,
    /// Cumulative DP ε spent through this round (0.0 when the job has no
    /// `channel.dp` stage — the column always exists; see
    /// [`crate::metrics::privacy`]).
    pub dp_epsilon: f64,
    /// Cumulative DP δ spent through this round (0.0 when no DP stage).
    pub dp_delta: f64,
}

/// A complete run: configuration echo + per-round series.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub label: String,
    pub strategy: String,
    pub topology: String,
    pub backend: String,
    pub n_clients: usize,
    pub n_workers: usize,
    pub seed: u64,
    /// The run stopped at a round boundary before its configured round
    /// budget (campaign-scheduler rung stop or cooperative cancellation).
    /// By the determinism contract the recorded rounds are a bitwise
    /// prefix of the same job run to completion; `rounds.len()` is the
    /// number of rounds actually completed.
    pub stopped_early: bool,
    pub rounds: Vec<RoundMetrics>,
}

impl RunReport {
    pub fn final_accuracy(&self) -> f64 {
        self.rounds.last().map(|r| r.test_accuracy).unwrap_or(0.0)
    }

    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.test_accuracy)
            .fold(0.0, f64::max)
    }

    pub fn final_loss(&self) -> f64 {
        self.rounds.last().map(|r| r.test_loss).unwrap_or(f64::NAN)
    }

    pub fn total_wall_secs(&self) -> f64 {
        self.rounds.iter().map(|r| r.wall_secs).sum()
    }

    pub fn total_net_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.net_bytes).sum()
    }

    /// Total simulated on-wire seconds (per-delivery, route-priced).
    pub fn total_sim_net_secs(&self) -> f64 {
        self.rounds.iter().map(|r| r.sim_net_secs).sum()
    }

    /// Total virtual-clock makespan (what the run "takes" on the simulated
    /// deployment, with clients running in parallel).
    pub fn total_sim_round_secs(&self) -> f64 {
        self.rounds.iter().map(|r| r.sim_round_secs).sum()
    }

    pub fn accuracy_series(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.test_accuracy).collect()
    }

    /// Rounds actually completed (for a `stopped_early` report this is the
    /// rung/cancellation boundary, not the configured budget).
    pub fn rounds_completed(&self) -> u64 {
        self.rounds.len() as u64
    }

    /// The metric recorded *at* round `round` (1-based), if that round was
    /// completed. Campaign schedulers read rung-decision metrics with this.
    pub fn metric_at(&self, round: u64, metric: impl Fn(&RoundMetrics) -> f64) -> Option<f64> {
        if round == 0 {
            return None;
        }
        self.rounds.get(round as usize - 1).map(metric)
    }

    /// The prefix of this report up to `rounds` completed rounds, marked
    /// `stopped_early` when it is a strict prefix. By the determinism
    /// contract this equals the report of the same job run with a round
    /// budget of `rounds` — the campaign cache uses it to serve a deeper
    /// stored entry as a *rung-level* hit.
    pub fn truncated(&self, rounds: u64) -> RunReport {
        let keep = (rounds as usize).min(self.rounds.len());
        let mut out = self.clone();
        out.rounds.truncate(keep);
        if keep < self.rounds.len() {
            out.stopped_early = true;
        }
        out
    }

    pub fn loss_series(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.test_loss).collect()
    }

    /// CSV export (one row per round).
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "round,test_accuracy,test_loss,train_loss,wall_secs,cpu_pct,rss_mib,net_bytes,sim_net_secs,sim_round_secs,model_hash,dp_epsilon,dp_delta\n",
        );
        for r in &self.rounds {
            s.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{:.4},{:.1},{:.1},{},{:.4},{:.4},{},{:.6},{:e}\n",
                r.round,
                r.test_accuracy,
                r.test_loss,
                r.train_loss,
                r.wall_secs,
                r.cpu_pct,
                r.rss_mib,
                r.net_bytes,
                r.sim_net_secs,
                r.sim_round_secs,
                r.model_hash,
                r.dp_epsilon,
                r.dp_delta
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::from(self.label.as_str())),
            ("strategy", Json::from(self.strategy.as_str())),
            ("topology", Json::from(self.topology.as_str())),
            ("backend", Json::from(self.backend.as_str())),
            ("n_clients", Json::from(self.n_clients)),
            ("n_workers", Json::from(self.n_workers)),
            ("seed", Json::from(self.seed as usize)),
            ("stopped_early", Json::from(self.stopped_early)),
            (
                "rounds",
                Json::Arr(
                    self.rounds
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("round", Json::from(r.round as usize)),
                                ("test_accuracy", Json::from(r.test_accuracy)),
                                ("test_loss", Json::from(r.test_loss)),
                                ("train_loss", Json::from(r.train_loss)),
                                ("wall_secs", Json::from(r.wall_secs)),
                                ("cpu_pct", Json::from(r.cpu_pct)),
                                ("rss_mib", Json::from(r.rss_mib)),
                                ("net_bytes", Json::from(r.net_bytes as usize)),
                                ("sim_net_secs", Json::from(r.sim_net_secs)),
                                ("sim_round_secs", Json::from(r.sim_round_secs)),
                                ("model_hash", Json::from(r.model_hash.as_str())),
                                ("dp_epsilon", Json::from(r.dp_epsilon)),
                                ("dp_delta", Json::from(r.dp_delta)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Inverse of [`RunReport::to_json`] — used by the campaign result store
    /// to resume cached cells. Round-trips exactly: Rust's `f64` Display
    /// prints the shortest representation that re-parses to the same bits,
    /// so a report serialized, stored, and re-loaded yields byte-identical
    /// CSV/JSON again.
    pub fn from_json(j: &Json) -> Result<RunReport> {
        let s = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("run report json: missing string '{k}'"))
        };
        let n = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("run report json: missing number '{k}'"))
        };
        let mut rounds = Vec::new();
        for rj in j
            .get("rounds")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("run report json: missing 'rounds' array"))?
        {
            // Strict like the top level: `to_json` always writes every
            // field, so a missing one means a corrupt/stale document — the
            // campaign cache must treat that as a miss, not as zeros.
            let g = |k: &str| -> Result<f64> {
                rj.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("run report json: round missing number '{k}'"))
            };
            rounds.push(RoundMetrics {
                round: g("round")? as u64,
                test_accuracy: g("test_accuracy")?,
                test_loss: g("test_loss")?,
                train_loss: g("train_loss")?,
                wall_secs: g("wall_secs")?,
                cpu_pct: g("cpu_pct")?,
                rss_mib: g("rss_mib")?,
                net_bytes: g("net_bytes")? as u64,
                sim_net_secs: g("sim_net_secs")?,
                sim_round_secs: g("sim_round_secs")?,
                model_hash: rj
                    .get("model_hash")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("run report json: round missing 'model_hash'"))?
                    .to_string(),
                dp_epsilon: g("dp_epsilon")?,
                dp_delta: g("dp_delta")?,
            });
        }
        Ok(RunReport {
            label: s("label")?,
            strategy: s("strategy")?,
            topology: s("topology")?,
            backend: s("backend")?,
            n_clients: n("n_clients")? as usize,
            n_workers: n("n_workers")? as usize,
            seed: n("seed")? as u64,
            // Strict: `to_json` always writes the flag, so a missing one is
            // a stale/corrupt document (the campaign cache reads it as a
            // miss rather than silently treating a partial run as full).
            stopped_early: j
                .get("stopped_early")
                .and_then(Json::as_bool)
                .ok_or_else(|| anyhow!("run report json: missing bool 'stopped_early'"))?,
            rounds,
        })
    }

    pub fn save_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_csv())
            .with_context(|| format!("writing {:?}", path.as_ref()))
    }

    pub fn save_json(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_string())
            .with_context(|| format!("writing {:?}", path.as_ref()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunReport {
        RunReport {
            label: "t".into(),
            strategy: "fedavg".into(),
            topology: "client_server".into(),
            backend: "cnn".into(),
            n_clients: 10,
            n_workers: 1,
            seed: 42,
            stopped_early: false,
            rounds: vec![
                RoundMetrics {
                    round: 1,
                    test_accuracy: 0.4,
                    test_loss: 1.6,
                    net_bytes: 100,
                    wall_secs: 1.0,
                    sim_net_secs: 2.0,
                    sim_round_secs: 0.5,
                    ..Default::default()
                },
                RoundMetrics {
                    round: 2,
                    test_accuracy: 0.55,
                    test_loss: 1.2,
                    net_bytes: 150,
                    wall_secs: 2.0,
                    sim_net_secs: 3.0,
                    sim_round_secs: 0.75,
                    ..Default::default()
                },
            ],
        }
    }

    #[test]
    fn aggregates() {
        let r = sample();
        assert_eq!(r.final_accuracy(), 0.55);
        assert_eq!(r.best_accuracy(), 0.55);
        assert_eq!(r.total_net_bytes(), 250);
        assert!((r.total_wall_secs() - 3.0).abs() < 1e-12);
        assert!((r.total_sim_net_secs() - 5.0).abs() < 1e-12);
        assert!((r.total_sim_round_secs() - 1.25).abs() < 1e-12);
        assert_eq!(r.accuracy_series(), vec![0.4, 0.55]);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = sample().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("round,test_accuracy"));
        assert!(lines[1].starts_with("1,0.400000"));
    }

    #[test]
    fn json_roundtrip_parses() {
        let j = sample().to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("strategy").unwrap().as_str(), Some("fedavg"));
        let rounds = parsed.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 2);
        assert_eq!(
            rounds[0].get("sim_round_secs").and_then(Json::as_f64),
            Some(0.5)
        );
    }

    #[test]
    fn json_roundtrip_is_byte_identical() {
        let r = sample();
        let j1 = r.to_json().to_string();
        let back = RunReport::from_json(&Json::parse(&j1).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), j1);
        assert_eq!(back.to_csv(), r.to_csv());
        assert_eq!(back.seed, 42);
        assert_eq!(back.rounds[1].net_bytes, 150);
    }

    #[test]
    fn empty_report_defaults() {
        let r = RunReport::default();
        assert_eq!(r.final_accuracy(), 0.0);
        assert!(r.final_loss().is_nan());
        assert!(!r.stopped_early);
        assert_eq!(r.rounds_completed(), 0);
    }

    #[test]
    fn truncated_marks_strict_prefixes_stopped_early() {
        let r = sample();
        let t = r.truncated(1);
        assert!(t.stopped_early);
        assert_eq!(t.rounds_completed(), 1);
        assert_eq!(t.rounds[0].test_accuracy, 0.4);
        // Truncating to (or beyond) the full length changes nothing.
        let full = r.truncated(2);
        assert!(!full.stopped_early);
        assert_eq!(full.to_json().to_string(), r.to_json().to_string());
        let beyond = r.truncated(99);
        assert!(!beyond.stopped_early);
        assert_eq!(beyond.rounds_completed(), 2);
        // A truncated partial round-trips through JSON with the flag intact.
        let back = RunReport::from_json(&Json::parse(&t.to_json().to_string()).unwrap()).unwrap();
        assert!(back.stopped_early);
        assert_eq!(back.to_json().to_string(), t.to_json().to_string());
    }

    #[test]
    fn metric_at_reads_one_based_rounds() {
        let r = sample();
        assert_eq!(r.metric_at(1, |m| m.test_accuracy), Some(0.4));
        assert_eq!(r.metric_at(2, |m| m.test_loss), Some(1.2));
        assert_eq!(r.metric_at(0, |m| m.test_accuracy), None);
        assert_eq!(r.metric_at(3, |m| m.test_accuracy), None);
    }

    #[test]
    fn dp_columns_always_present_and_round_trip() {
        let mut r = sample();
        r.rounds[1].dp_epsilon = 15.3;
        r.rounds[1].dp_delta = 0.00002;
        let csv = r.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.ends_with("model_hash,dp_epsilon,dp_delta"));
        // Zero rows keep the columns (no-DP runs stay schema-compatible).
        assert!(csv.lines().nth(1).unwrap().ends_with(",0.000000,0e0"));
        let back = RunReport::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.rounds[1].dp_epsilon, 15.3);
        assert_eq!(back.rounds[1].dp_delta, 0.00002);
        assert_eq!(back.to_json().to_string(), r.to_json().to_string());
        // Strict like every other field: a document without the dp columns
        // is a stale schema — a cache miss, not a zero-spend run.
        let doc = r.to_json().to_string().replace("\"dp_epsilon\":15.3,", "");
        assert!(RunReport::from_json(&Json::parse(&doc).unwrap()).is_err());
    }

    #[test]
    fn from_json_requires_stopped_early() {
        // A pre-partial-results document (no flag) must read as corrupt —
        // the campaign cache treats that as a miss, not as a complete run.
        let mut doc = sample().to_json().to_string();
        doc = doc.replace("\"stopped_early\":false,", "");
        assert!(RunReport::from_json(&Json::parse(&doc).unwrap()).is_err());
    }
}
