//! Process resource probes: CPU time and resident memory, read from procfs
//! (`/proc/self/stat`, `/proc/self/status`) — the "resource usage" series of
//! the paper's Figures 8/9/11.
//!
//! Pure-std implementation (no `libc` in the offline image); on non-Linux
//! hosts the probes degrade to zeros, which only blanks the resource columns
//! of the report.

/// A point-in-time resource snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResourceSnapshot {
    /// User+system CPU seconds consumed so far.
    pub cpu_secs: f64,
    /// Resident set size in MiB.
    pub rss_mib: f64,
}

pub fn snapshot() -> ResourceSnapshot {
    ResourceSnapshot {
        cpu_secs: cpu_secs(),
        rss_mib: rss_mib(),
    }
}

/// Kernel clock ticks per second. `_SC_CLK_TCK` is 100 on every mainstream
/// Linux configuration (procfs itself documents utime/stime in those units).
const CLK_TCK: f64 = 100.0;

fn cpu_secs() -> f64 {
    let Ok(stat) = std::fs::read_to_string("/proc/self/stat") else {
        return 0.0;
    };
    // Fields after the comm, which is parenthesized and may contain spaces —
    // split on the *last* ')'.
    let Some(rest) = stat.rsplit_once(')').map(|(_, r)| r) else {
        return 0.0;
    };
    let fields: Vec<&str> = rest.split_whitespace().collect();
    // rest starts at field 3 ("state"); utime/stime are fields 14/15 of the
    // full line, i.e. indexes 11/12 here.
    let tick = |i: usize| {
        fields
            .get(i)
            .and_then(|s| s.parse::<f64>().ok())
            .unwrap_or(0.0)
    };
    (tick(11) + tick(12)) / CLK_TCK
}

fn rss_mib() -> f64 {
    status_kb("VmRSS:") as f64 / 1024.0
}

/// One `Vm*` field of `/proc/self/status`, in kB (0 off-Linux / on parse
/// failure — same degradation as the other probes).
fn status_kb(prefix: &str) -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(prefix) {
            return rest
                .split_whitespace()
                .next()
                .and_then(|s| s.parse::<u64>().ok())
                .unwrap_or(0);
        }
    }
    0
}

/// Current resident set size in bytes (`VmRSS`). Deltas of this probe bound
/// the *incremental* footprint of a scaffold or run, which is what the
/// scale tests assert ceilings on.
pub fn rss_bytes() -> u64 {
    status_kb("VmRSS:") * 1024
}

/// Peak resident set size in bytes (`VmHWM`): the process high-water mark.
/// Monotone over the process lifetime — comparable across runs of the same
/// bench binary, which is why the `mem_peak_bytes` series samples it at
/// fixed points in the bench sequence.
pub fn peak_rss_bytes() -> u64 {
    status_kb("VmHWM:") * 1024
}

/// CPU utilisation (%) between two snapshots over `wall_secs`.
pub fn cpu_util_pct(before: ResourceSnapshot, after: ResourceSnapshot, wall_secs: f64) -> f64 {
    if wall_secs <= 0.0 {
        return 0.0;
    }
    100.0 * (after.cpu_secs - before.cpu_secs).max(0.0) / wall_secs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_sane() {
        let s = snapshot();
        assert!(s.cpu_secs >= 0.0);
        assert!(s.rss_mib > 1.0, "rss {} MiB", s.rss_mib);
    }

    #[test]
    fn peak_rss_is_a_high_water_mark() {
        let rss = rss_bytes();
        let peak = peak_rss_bytes();
        assert!(rss > 1 << 20, "rss {rss} bytes");
        assert!(peak >= rss, "peak {peak} < current {rss}");
        // Touch a real allocation; the high-water mark never decreases.
        let buf = vec![1u8; 4 << 20];
        std::hint::black_box(&buf);
        assert!(peak_rss_bytes() >= peak);
    }

    #[test]
    fn cpu_advances_under_load() {
        let a = snapshot();
        // Busy-spin some real work.
        let mut acc = 0u64;
        for i in 0..8_000_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let b = snapshot();
        assert!(b.cpu_secs >= a.cpu_secs);
    }

    #[test]
    fn util_pct() {
        let a = ResourceSnapshot { cpu_secs: 1.0, rss_mib: 0.0 };
        let b = ResourceSnapshot { cpu_secs: 2.0, rss_mib: 0.0 };
        assert!((cpu_util_pct(a, b, 2.0) - 50.0).abs() < 1e-9);
        assert_eq!(cpu_util_pct(a, b, 0.0), 0.0);
    }
}
