//! Process resource probes: CPU time and resident memory, read from the OS
//! (getrusage + /proc/self/statm) — the "resource usage" series of the
//! paper's Figures 8/9/11.

/// A point-in-time resource snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResourceSnapshot {
    /// User+system CPU seconds consumed so far.
    pub cpu_secs: f64,
    /// Resident set size in MiB.
    pub rss_mib: f64,
}

pub fn snapshot() -> ResourceSnapshot {
    ResourceSnapshot {
        cpu_secs: cpu_secs(),
        rss_mib: rss_mib(),
    }
}

fn cpu_secs() -> f64 {
    // SAFETY: plain libc call with an out-param struct.
    unsafe {
        let mut ru: libc::rusage = std::mem::zeroed();
        if libc::getrusage(libc::RUSAGE_SELF, &mut ru) != 0 {
            return 0.0;
        }
        let tv = |t: libc::timeval| t.tv_sec as f64 + t.tv_usec as f64 / 1e6;
        tv(ru.ru_utime) + tv(ru.ru_stime)
    }
}

fn rss_mib() -> f64 {
    let Ok(statm) = std::fs::read_to_string("/proc/self/statm") else {
        return 0.0;
    };
    let Some(resident_pages) = statm
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<f64>().ok())
    else {
        return 0.0;
    };
    let page = unsafe { libc::sysconf(libc::_SC_PAGESIZE) } as f64;
    resident_pages * page / (1024.0 * 1024.0)
}

/// CPU utilisation (%) between two snapshots over `wall_secs`.
pub fn cpu_util_pct(before: ResourceSnapshot, after: ResourceSnapshot, wall_secs: f64) -> f64 {
    if wall_secs <= 0.0 {
        return 0.0;
    }
    100.0 * (after.cpu_secs - before.cpu_secs).max(0.0) / wall_secs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_sane() {
        let s = snapshot();
        assert!(s.cpu_secs >= 0.0);
        assert!(s.rss_mib > 1.0, "rss {} MiB", s.rss_mib);
    }

    #[test]
    fn cpu_advances_under_load() {
        let a = snapshot();
        // Busy-spin some real work.
        let mut acc = 0u64;
        for i in 0..8_000_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let b = snapshot();
        assert!(b.cpu_secs >= a.cpu_secs);
    }

    #[test]
    fn util_pct() {
        let a = ResourceSnapshot { cpu_secs: 1.0, rss_mib: 0.0 };
        let b = ResourceSnapshot { cpu_secs: 2.0, rss_mib: 0.0 };
        assert!((cpu_util_pct(a, b, 2.0) - 50.0).abs() < 1e-9);
        assert_eq!(cpu_util_pct(a, b, 0.0), 0.0);
    }
}
