//! ASCII FL-Dashboard: sparkline learning curves + summary tables rendered
//! to the terminal (the paper ships a web dashboard; the information content
//! — learning trajectory and resource profile at a glance — is the same).

use crate::metrics::report::RunReport;

const TICKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Render a unicode sparkline of a series.
pub fn sparkline(xs: &[f64]) -> String {
    if xs.is_empty() {
        return String::new();
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    xs.iter()
        .map(|&x| {
            let t = ((x - lo) / span * (TICKS.len() - 1) as f64).round() as usize;
            TICKS[t.min(TICKS.len() - 1)]
        })
        .collect()
}

/// One-line summary of a run.
pub fn run_line(r: &RunReport) -> String {
    format!(
        "{:<22} acc {:<5.3} {} | loss {:<6.3} | {:>7.1}s | sim {:>7.1}s | {:>8} KiB",
        r.label,
        r.final_accuracy(),
        sparkline(&r.accuracy_series()),
        r.final_loss(),
        r.total_wall_secs(),
        r.total_sim_round_secs(),
        r.total_net_bytes() / 1024,
    )
}

/// Multi-run comparison table (a paper-figure in ASCII form).
pub fn comparison(title: &str, runs: &[RunReport]) -> String {
    let mut out = format!("== {title} ==\n");
    out.push_str(&format!(
        "{:<22} {:>6} {:>6} {:>9} {:>9} {:>9} {:>10} {:>8}\n",
        "run", "acc", "loss", "time(s)", "sim(s)", "cpu(%)", "mem(MiB)", "net(KiB)"
    ));
    for r in runs {
        let cpu = crate::util::stats::mean(
            &r.rounds.iter().map(|m| m.cpu_pct).collect::<Vec<_>>(),
        );
        let mem = r.rounds.last().map(|m| m.rss_mib).unwrap_or(0.0);
        out.push_str(&format!(
            "{:<22} {:>6.3} {:>6.3} {:>9.1} {:>9.1} {:>9.1} {:>10.1} {:>8}\n",
            r.label,
            r.final_accuracy(),
            r.final_loss(),
            r.total_wall_secs(),
            r.total_sim_round_secs(),
            cpu,
            mem,
            r.total_net_bytes() / 1024
        ));
    }
    out
}

/// Round-by-round accuracy table (paper Tables 1-2 shape).
pub fn round_table(runs: &[RunReport], metric: fn(&RunReport) -> Vec<f64>, name: &str) -> String {
    let max_rounds = runs.iter().map(|r| r.rounds.len()).max().unwrap_or(0);
    let mut out = format!("{name} at FL round:\n{:<22}", "run");
    for i in 1..=max_rounds {
        out.push_str(&format!(" {i:>7}"));
    }
    out.push('\n');
    for r in runs {
        out.push_str(&format!("{:<22}", r.label));
        for v in metric(r) {
            out.push_str(&format!(" {v:>7.4}"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::report::RoundMetrics;

    fn run(label: &str, accs: &[f64]) -> RunReport {
        RunReport {
            label: label.into(),
            rounds: accs
                .iter()
                .enumerate()
                .map(|(i, &a)| RoundMetrics {
                    round: i as u64 + 1,
                    test_accuracy: a,
                    test_loss: 1.0 - a,
                    ..Default::default()
                })
                .collect(),
            ..Default::default()
        }
    }

    #[test]
    fn sparkline_monotone() {
        let s = sparkline(&[0.0, 0.5, 1.0]);
        assert_eq!(s.chars().count(), 3);
        let cs: Vec<char> = s.chars().collect();
        assert_eq!(cs[0], '▁');
        assert_eq!(cs[2], '█');
    }

    #[test]
    fn sparkline_flat_and_empty() {
        assert_eq!(sparkline(&[]), "");
        assert_eq!(sparkline(&[1.0, 1.0]).chars().count(), 2);
    }

    #[test]
    fn comparison_contains_rows() {
        let runs = vec![run("fedavg", &[0.4, 0.6]), run("scaffold", &[0.5, 0.7])];
        let t = comparison("fig8", &runs);
        assert!(t.contains("fedavg"));
        assert!(t.contains("scaffold"));
        assert!(t.lines().count() >= 4);
    }

    #[test]
    fn round_table_grid() {
        let runs = vec![run("a", &[0.1, 0.2, 0.3])];
        let t = round_table(&runs, |r| r.accuracy_series(), "Accuracy");
        assert!(t.contains("0.1000"));
        assert!(t.contains("0.3000"));
    }
}
