//! Performance Logger & FL-Dashboard (paper §2.1 component 6): per-round
//! model metrics + resource usage, exports, and an ASCII dashboard.

pub mod dashboard;
pub mod html;
pub mod privacy;
pub mod report;
pub mod resources;

pub use report::{RoundMetrics, RunReport};
