//! HTML FL-Dashboard: a self-contained report (inline SVG charts, zero
//! external assets) mirroring the paper's web dashboard — learning curves,
//! resource profiles and bandwidth per run, side by side.

use crate::metrics::report::RunReport;

const PALETTE: [&str; 8] = [
    "#1f77b4", "#d62728", "#2ca02c", "#9467bd", "#ff7f0e", "#8c564b",
    "#e377c2", "#17becf",
];

/// Render an SVG line chart of one series per run.
pub fn svg_chart(
    title: &str,
    runs: &[RunReport],
    series_of: impl Fn(&RunReport) -> Vec<f64>,
) -> String {
    let (w, h, pad) = (460.0, 260.0, 40.0);
    let all: Vec<Vec<f64>> = runs.iter().map(&series_of).collect();
    let max_len = all.iter().map(Vec::len).max().unwrap_or(0).max(2);
    let lo = all
        .iter()
        .flatten()
        .cloned()
        .fold(f64::INFINITY, f64::min)
        .min(0.0);
    let hi = all
        .iter()
        .flatten()
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max)
        .max(lo + 1e-9);

    let x = |i: usize| pad + (w - 2.0 * pad) * i as f64 / (max_len - 1) as f64;
    let y = |v: f64| h - pad - (h - 2.0 * pad) * (v - lo) / (hi - lo);

    let mut s = format!(
        r##"<svg width="{w}" height="{h}" xmlns="http://www.w3.org/2000/svg">
<text x="{}" y="18" text-anchor="middle" font-size="13" font-family="sans-serif">{title}</text>
<line x1="{pad}" y1="{}" x2="{}" y2="{}" stroke="#888"/>
<line x1="{pad}" y1="{pad}" x2="{pad}" y2="{}" stroke="#888"/>
<text x="8" y="{}" font-size="10" font-family="sans-serif">{:.2}</text>
<text x="8" y="{}" font-size="10" font-family="sans-serif">{:.2}</text>
"##,
        w / 2.0,
        h - pad,
        w - pad,
        h - pad,
        h - pad,
        pad + 4.0,
        hi,
        h - pad,
        lo,
    );
    for (ri, series) in all.iter().enumerate() {
        if series.is_empty() {
            continue;
        }
        let color = PALETTE[ri % PALETTE.len()];
        let pts: Vec<String> = series
            .iter()
            .enumerate()
            .map(|(i, &v)| format!("{:.1},{:.1}", x(i), y(v)))
            .collect();
        s.push_str(&format!(
            r##"<polyline fill="none" stroke="{color}" stroke-width="1.8" points="{}"/>
"##,
            pts.join(" ")
        ));
        s.push_str(&format!(
            r##"<text x="{}" y="{}" font-size="10" fill="{color}" font-family="sans-serif">{}</text>
"##,
            w - pad + 4.0,
            y(*series.last().unwrap()),
            escape(&runs[ri].label)
        ));
    }
    s.push_str("</svg>\n");
    s
}

/// Full report page for a set of runs (one experiment).
pub fn render_report(title: &str, runs: &[RunReport]) -> String {
    let mut html = format!(
        "<!doctype html><html><head><meta charset=\"utf-8\">\
         <title>{t}</title>\
         <style>body{{font-family:sans-serif;margin:24px}} \
         table{{border-collapse:collapse}} td,th{{border:1px solid #ccc;\
         padding:4px 10px;font-size:13px}} .charts{{display:flex;\
         flex-wrap:wrap;gap:12px}}</style></head><body><h1>{t}</h1>\n",
        t = escape(title)
    );

    html.push_str("<table><tr><th>run</th><th>strategy</th><th>topology</th>\
                   <th>backend</th><th>final acc</th><th>final loss</th>\
                   <th>time (s)</th><th>net (KiB)</th></tr>\n");
    for r in runs {
        html.push_str(&format!(
            "<tr><td>{}</td><td>{}</td><td>{}</td><td>{}</td>\
             <td>{:.4}</td><td>{:.4}</td><td>{:.1}</td><td>{}</td></tr>\n",
            escape(&r.label),
            escape(&r.strategy),
            escape(&r.topology),
            escape(&r.backend),
            r.final_accuracy(),
            r.final_loss(),
            r.total_wall_secs(),
            r.total_net_bytes() / 1024
        ));
    }
    html.push_str("</table>\n<div class=\"charts\">\n");

    html.push_str(&svg_chart("Test accuracy", runs, |r| r.accuracy_series()));
    html.push_str(&svg_chart("Test loss", runs, |r| r.loss_series()));
    html.push_str(&svg_chart("Round wall time (s)", runs, |r| {
        r.rounds.iter().map(|m| m.wall_secs).collect()
    }));
    html.push_str(&svg_chart("Network bytes / round (KiB)", runs, |r| {
        r.rounds.iter().map(|m| m.net_bytes as f64 / 1024.0).collect()
    }));
    html.push_str(&svg_chart("Memory (MiB)", runs, |r| {
        r.rounds.iter().map(|m| m.rss_mib).collect()
    }));
    html.push_str(&svg_chart("CPU (%)", runs, |r| {
        r.rounds.iter().map(|m| m.cpu_pct).collect()
    }));

    html.push_str("</div></body></html>\n");
    html
}

fn escape(s: &str) -> String {
    s.replace('&', "&amp;")
        .replace('<', "&lt;")
        .replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::report::RoundMetrics;

    fn run(label: &str, n: usize) -> RunReport {
        RunReport {
            label: label.into(),
            strategy: "fedavg".into(),
            rounds: (1..=n)
                .map(|i| RoundMetrics {
                    round: i as u64,
                    test_accuracy: i as f64 / n as f64,
                    test_loss: 1.0 / i as f64,
                    wall_secs: 1.0,
                    net_bytes: 1024 * i as u64,
                    rss_mib: 100.0,
                    cpu_pct: 90.0,
                    ..Default::default()
                })
                .collect(),
            ..Default::default()
        }
    }

    #[test]
    fn svg_has_one_polyline_per_run() {
        let runs = vec![run("a", 5), run("b", 5)];
        let svg = svg_chart("Accuracy", &runs, |r| r.accuracy_series());
        assert_eq!(svg.matches("<polyline").count(), 2);
        assert!(svg.contains("Accuracy"));
        assert!(svg.starts_with("<svg"));
    }

    #[test]
    fn report_is_self_contained_html() {
        let runs = vec![run("x<&y", 3)];
        let html = render_report("Fig 8", &runs);
        assert!(html.starts_with("<!doctype html>"));
        assert!(html.contains("x&lt;&amp;y")); // escaped label
        assert!(!html.contains("http://") || html.contains("www.w3.org")); // only the SVG ns
        assert_eq!(html.matches("<svg").count(), 6);
    }

    #[test]
    fn empty_series_does_not_panic() {
        let runs = vec![RunReport::default()];
        let _ = render_report("empty", &runs);
    }
}
