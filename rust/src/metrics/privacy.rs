//! DP accountant for the channel's `dp:` stage: cumulative (ε, δ) spend of
//! the Gaussian mechanism applied round-by-round by
//! [`apply_dp_noise`](crate::aggregate::mean::apply_dp_noise).
//!
//! The accountant is a *pure function of the config and the round number*
//! — no state is threaded across rounds, so a resumed/cached run reports
//! exactly the same series as a fresh one, and truncating a report to a
//! round prefix keeps every row's spend correct.
//!
//! Accounting model: each round is one Gaussian-mechanism release at noise
//! multiplier σ, giving the classical analytic per-round bound
//! ε = √(2·ln(1.25/δ)) / σ (Dwork & Roth, Thm 3.22), composed linearly over
//! rounds: ε(T) = T·ε, δ(T) = T·δ. Linear composition is deliberately
//! conservative — it over-reports spend relative to advanced/RDP
//! composition, so the dashboards never *understate* the privacy cost.

use crate::config::channel::DpConfig;

/// Per-round ε of the Gaussian mechanism at noise multiplier `sigma` and
/// per-round `delta`. Returns `None` when σ ≤ 0: zero noise carries no
/// finite guarantee, and the accountant reports zero spend rather than
/// serializing an infinity into the metrics schema.
pub fn epsilon_per_round(sigma: f64, delta: f64) -> Option<f64> {
    if sigma <= 0.0 || !(0.0 < delta && delta < 1.0) {
        return None;
    }
    Some((2.0 * (1.25 / delta).ln()).sqrt() / sigma)
}

/// Cumulative (ε, δ) after `round` completed rounds under linear
/// composition. `(0.0, 0.0)` when the job has no DP stage (or a σ = 0 one)
/// — the metrics columns always exist, a zero row means "no spend".
pub fn cumulative(dp: Option<&DpConfig>, round: u64) -> (f64, f64) {
    match dp.and_then(|d| epsilon_per_round(d.sigma, d.delta).map(|e| (e, d.delta))) {
        Some((eps, delta)) => (round as f64 * eps, round as f64 * delta),
        None => (0.0, 0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dp(sigma: f64, delta: f64) -> DpConfig {
        DpConfig {
            clip: 10.0,
            sigma,
            delta,
        }
    }

    #[test]
    fn per_round_matches_analytic_bound() {
        let eps = epsilon_per_round(0.01, 1e-5).unwrap();
        let expect = (2.0f64 * (1.25f64 / 1e-5).ln()).sqrt() / 0.01;
        assert!((eps - expect).abs() < 1e-12);
        // More noise => less spend.
        assert!(epsilon_per_round(0.02, 1e-5).unwrap() < eps);
    }

    #[test]
    fn zero_sigma_has_no_finite_guarantee() {
        assert_eq!(epsilon_per_round(0.0, 1e-5), None);
        assert_eq!(epsilon_per_round(-1.0, 1e-5), None);
        assert_eq!(cumulative(Some(&dp(0.0, 1e-5)), 10), (0.0, 0.0));
    }

    #[test]
    fn cumulative_is_linear_in_rounds() {
        let d = dp(0.01, 1e-5);
        let (e1, d1) = cumulative(Some(&d), 1);
        let (e5, d5) = cumulative(Some(&d), 5);
        assert!((e5 - 5.0 * e1).abs() < 1e-9);
        assert!((d5 - 5.0 * d1).abs() < 1e-18);
        assert_eq!(cumulative(Some(&d), 0), (0.0, 0.0));
    }

    #[test]
    fn no_dp_reports_zero_spend() {
        assert_eq!(cumulative(None, 100), (0.0, 0.0));
    }

    #[test]
    fn resume_stability_is_positional() {
        // Row T of a resumed run must equal row T of a fresh run: the spend
        // is a pure function of (config, round), never of visited history.
        let d = dp(0.005, 1e-6);
        assert_eq!(cumulative(Some(&d), 7), cumulative(Some(&d), 7));
    }
}
