//! `ModelBackend` — the library-agnostic model handle (paper RQ2).
//!
//! The coordinator never names a model family: it drives whatever backends
//! the manifest declares through this uniform interface, exactly as FLsim
//! drives PyTorch/TensorFlow/Scikit-Learn strategies through one Strategy
//! class. Parameters cross the interface as flat `f32` vectors (or as
//! opaque device literals inside a local-training loop).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::runtime::pjrt::Runtime;
use crate::runtime::tensor::Literal;

#[derive(Clone)]
pub struct ModelBackend {
    rt: Arc<Runtime>,
    pub name: String,
    pub param_count: usize,
    pub input_shape: Vec<usize>,
    pub train_batch: usize,
    pub eval_batch: usize,
}

impl ModelBackend {
    pub fn new(rt: Arc<Runtime>, name: &str) -> Result<ModelBackend> {
        let desc = rt.manifest.backend(name)?;
        Ok(ModelBackend {
            name: desc.name.clone(),
            param_count: desc.param_count,
            input_shape: desc.input_shape.clone(),
            train_batch: rt.manifest.train_batch,
            eval_batch: rt.manifest.eval_batch,
            rt,
        })
    }

    /// True if the backend's manifest declares a strategy-specific artifact
    /// (e.g. "scaffold", "moon").
    pub fn supports(&self, step: &str) -> bool {
        self.rt
            .manifest
            .backend(&self.name)
            .map(|b| b.artifacts.contains_key(step))
            .unwrap_or(false)
    }

    /// Deterministic parameter initialization (seed comes from the node
    /// seed-synchronization stream, paper §5/RQ6).
    pub fn init(&self, seed: i32) -> Result<Vec<f32>> {
        let outs = self
            .rt
            .execute(&self.name, "init", &[Runtime::scalar_i32(seed)])?;
        Runtime::to_f32s(&outs[0])
    }

    /// Upload a parameter vector as a device literal.
    pub fn params_lit(&self, params: &[f32]) -> Result<Literal> {
        if params.len() != self.param_count {
            return Err(anyhow!(
                "backend {}: params len {} != {}",
                self.name,
                params.len(),
                self.param_count
            ));
        }
        Runtime::lit_f32(params, &[self.param_count])
    }

    pub fn to_params(&self, lit: &Literal) -> Result<Vec<f32>> {
        Runtime::to_f32s(lit)
    }

    fn step2(&self, step: &str, inputs: &[&Literal]) -> Result<(Literal, f32)> {
        let outs = self.rt.execute_refs(&self.name, step, inputs)?;
        let mut it = outs.into_iter();
        let new_params = it.next().ok_or_else(|| anyhow!("missing params out"))?;
        let loss = it
            .next()
            .ok_or_else(|| anyhow!("missing loss out"))?
            .first_f32()?;
        Ok((new_params, loss))
    }

    /// One SGD batch step: returns (new params literal, batch loss).
    pub fn sgd(
        &self,
        params: &Literal,
        x: &Literal,
        y: &Literal,
        lr: f32,
    ) -> Result<(Literal, f32)> {
        let lr = Runtime::scalar_f32(lr);
        self.step2("sgd", &[params, x, y, &lr])
    }

    /// FedProx batch step with proximal pull toward `global`.
    pub fn prox(
        &self,
        params: &Literal,
        global: &Literal,
        x: &Literal,
        y: &Literal,
        lr: f32,
        mu: f32,
    ) -> Result<(Literal, f32)> {
        let lr = Runtime::scalar_f32(lr);
        let mu = Runtime::scalar_f32(mu);
        self.step2("prox", &[params, global, x, y, &lr, &mu])
    }

    /// SCAFFOLD batch step with control variates (c_global, c_local).
    pub fn scaffold(
        &self,
        params: &Literal,
        c_global: &Literal,
        c_local: &Literal,
        x: &Literal,
        y: &Literal,
        lr: f32,
    ) -> Result<(Literal, f32)> {
        let lr = Runtime::scalar_f32(lr);
        self.step2("scaffold", &[params, c_global, c_local, x, y, &lr])
    }

    /// MOON batch step (contrastive against global + previous-local nets).
    #[allow(clippy::too_many_arguments)]
    pub fn moon(
        &self,
        params: &Literal,
        global: &Literal,
        prev: &Literal,
        x: &Literal,
        y: &Literal,
        lr: f32,
        mu: f32,
        tau: f32,
    ) -> Result<(Literal, f32)> {
        let lr = Runtime::scalar_f32(lr);
        let mu = Runtime::scalar_f32(mu);
        let tau = Runtime::scalar_f32(tau);
        self.step2("moon", &[params, global, prev, x, y, &lr, &mu, &tau])
    }

    /// One eval batch: returns (summed loss, correct count) over unmasked rows.
    pub fn eval_batch(
        &self,
        params: &Literal,
        x: &Literal,
        y: &Literal,
        mask: &Literal,
    ) -> Result<(f32, f32)> {
        let outs = self
            .rt
            .execute_refs(&self.name, "eval", &[params, x, y, mask])?;
        let loss = outs[0].first_f32()?;
        let correct = outs[1].first_f32()?;
        Ok((loss, correct))
    }

    /// Upload a train batch as literals.
    pub fn batch_lits(&self, x: &[f32], y: &[i32]) -> Result<(Literal, Literal)> {
        let mut dims = vec![self.train_batch];
        dims.extend_from_slice(&self.input_shape);
        Ok((Runtime::lit_f32(x, &dims)?, Runtime::lit_i32(y, &[self.train_batch])?))
    }

    /// Upload an eval batch (with validity mask) as literals.
    pub fn eval_lits(
        &self,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<(Literal, Literal, Literal)> {
        let mut dims = vec![self.eval_batch];
        dims.extend_from_slice(&self.input_shape);
        Ok((
            Runtime::lit_f32(x, &dims)?,
            Runtime::lit_i32(y, &[self.eval_batch])?,
            Runtime::lit_f32(mask, &[self.eval_batch])?,
        ))
    }

    /// Bytes one full model transfer costs on the (simulated) wire.
    pub fn model_bytes(&self) -> u64 {
        (self.param_count * 4) as u64
    }
}

impl std::fmt::Debug for ModelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelBackend")
            .field("name", &self.name)
            .field("param_count", &self.param_count)
            .finish()
    }
}
