//! The execution runtime: one `Runtime` owns the manifest and the execution
//! engine, and replays step calls for (potentially) hundreds of thousands of
//! invocations.
//!
//! `Runtime` is `Send + Sync` by construction — the engine is a shared
//! `Box<dyn Engine + Send + Sync>` and the stats counter sits behind a
//! `Mutex` — so the orchestrator hands one `Arc<Runtime>` to every client
//! worker thread of the parallel round engine (previously this was
//! `Rc<Runtime>` + `RefCell`, which pinned the whole simulation to one
//! thread).
//!
//! Engine selection: the pure-Rust [`ReferenceEngine`] is compiled into
//! every build and needs no artifacts. The original PJRT/AOT path (HLO
//! artifacts + the `xla` crate, see `python/compile/aot.py`) plugs into the
//! same [`Engine`] trait when that native toolchain is present; builds
//! without it — like this image — always run the reference engine, and an
//! artifact directory containing a manifest is ignored with a warning
//! (the `Manifest::load` plumbing stays for the PJRT engine to consume).

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::engine::Engine;
use crate::runtime::manifest::{ArtifactDesc, Manifest};
use crate::runtime::reference::{reference_manifest, ReferenceEngine};
use crate::runtime::tensor::Literal;

/// Counters for EXPERIMENTS.md §Perf and the metrics logger.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// Artifact compilations (always 0 on the reference engine).
    pub compiles: usize,
    pub executions: usize,
    pub compile_secs: f64,
    pub execute_secs: f64,
}

pub struct Runtime {
    dir: PathBuf,
    pub manifest: Manifest,
    engine: Box<dyn Engine>,
    stats: Mutex<RuntimeStats>,
}

impl Runtime {
    /// Open a runtime over `artifact_dir`. The directory is optional for the
    /// reference engine; when it does contain AOT artifacts, say loudly that
    /// they are being ignored rather than pretending to use them.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifact_dir.as_ref().to_path_buf();
        if dir.join("manifest.json").exists() {
            crate::warnlog!(
                "runtime",
                "{dir:?} holds AOT artifacts, but this build carries no PJRT \
                 engine — running on the pure-Rust reference engine instead"
            );
        }
        Ok(Runtime {
            dir,
            manifest: reference_manifest(),
            engine: Box::new(ReferenceEngine::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    /// Shared (thread-safe, reference-counted) runtime — the orchestrator,
    /// nodes and strategies all hold clones of this, and the parallel round
    /// engine shares it across worker threads.
    pub fn shared(artifact_dir: impl AsRef<Path>) -> Result<Arc<Runtime>> {
        Ok(Arc::new(Self::new(artifact_dir)?))
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().expect("stats lock poisoned").clone()
    }

    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn artifact(&self, backend: &str, step: &str) -> Result<&ArtifactDesc> {
        self.manifest
            .backend(backend)?
            .artifacts
            .get(step)
            .ok_or_else(|| {
                anyhow::anyhow!("backend {backend} has no '{step}' artifact")
            })
    }

    /// Execute an artifact; returns the untupled outputs.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        backend: &str,
        step: &str,
        inputs: &[L],
    ) -> Result<Vec<Literal>> {
        let desc = self.artifact(backend, step)?;
        if inputs.len() != desc.inputs.len() {
            bail!(
                "{backend}/{step}: expected {} inputs, got {}",
                desc.inputs.len(),
                inputs.len()
            );
        }
        let n_outputs = desc.n_outputs;
        let refs: Vec<&Literal> = inputs.iter().map(|l| l.borrow()).collect();
        let t0 = Instant::now();
        let outs = self.engine.run(backend, step, &refs)?;
        {
            let mut st = self.stats.lock().expect("stats lock poisoned");
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        if outs.len() != n_outputs {
            bail!(
                "{backend}/{step}: manifest says {n_outputs} outputs, got {}",
                outs.len()
            );
        }
        Ok(outs)
    }

    /// `execute` over borrowed literals (the common hot-path call shape:
    /// chained step outputs + cached batch literals, zero copies).
    pub fn execute_refs(
        &self,
        backend: &str,
        step: &str,
        inputs: &[&Literal],
    ) -> Result<Vec<Literal>> {
        self.execute(backend, step, inputs)
    }

    // -- literal helpers -----------------------------------------------------

    pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
        Literal::vec_f32(data.to_vec()).reshape(dims)
    }

    pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
        Literal::vec_i32(data.to_vec()).reshape(dims)
    }

    pub fn scalar_f32(v: f32) -> Literal {
        Literal::scalar_f32(v)
    }

    pub fn scalar_i32(v: i32) -> Literal {
        Literal::scalar_i32(v)
    }

    pub fn to_f32s(lit: &Literal) -> Result<Vec<f32>> {
        lit.to_f32_vec()
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("engine", &self.engine.name())
            .field("backends", &self.manifest.backends.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Runtime>();
        assert_send_sync::<Arc<Runtime>>();
    }

    #[test]
    fn execute_checks_input_count_and_meters() {
        let rt = Runtime::new("artifacts").unwrap();
        assert!(rt
            .execute("logreg", "sgd", &[Runtime::scalar_i32(0)])
            .is_err());
        let out = rt
            .execute("logreg", "init", &[Runtime::scalar_i32(3)])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(
            out[0].f32s().unwrap().len(),
            rt.manifest.backend("logreg").unwrap().param_count
        );
        let st = rt.stats();
        assert_eq!(st.executions, 1);
        assert_eq!(st.compiles, 0);
    }

    #[test]
    fn shared_runtime_executes_from_many_threads() {
        let rt = Runtime::shared("artifacts").unwrap();
        let base = rt
            .execute("logreg", "init", &[Runtime::scalar_i32(7)])
            .unwrap()[0]
            .f32s()
            .unwrap()
            .to_vec();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let rt = rt.clone();
                std::thread::spawn(move || {
                    rt.execute("logreg", "init", &[Runtime::scalar_i32(7)]).unwrap()[0]
                        .f32s()
                        .unwrap()
                        .to_vec()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), base, "cross-thread init not bitwise");
        }
        assert_eq!(rt.stats().executions, 5);
    }
}
