//! PJRT execution engine: compile-once / execute-many over the AOT HLO
//! artifacts (adapted from /opt/xla-example/load_hlo).
//!
//! One `Runtime` owns the PJRT CPU client and an executable cache keyed by
//! artifact name — every artifact is compiled exactly once per process and
//! then replayed for (potentially) hundreds of thousands of step calls.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::runtime::manifest::{ArtifactDesc, Manifest};

/// Counters for EXPERIMENTS.md §Perf and the metrics logger.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub executions: usize,
    pub compile_secs: f64,
    pub execute_secs: f64,
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Open the artifact directory and create the PJRT CPU client.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Shared (reference-counted) runtime — the orchestrator, nodes and
    /// strategies all hold clones of this.
    pub fn shared(artifact_dir: impl AsRef<Path>) -> Result<Rc<Runtime>> {
        Ok(Rc::new(Self::new(artifact_dir)?))
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    /// Look up (or compile) the executable for `backend`/`step`.
    pub fn executable(
        &self,
        backend: &str,
        step: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{backend}/{step}");
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let desc = self.artifact(backend, step)?;
        let path = self.dir.join(&desc.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {key}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(key, exe.clone());
        let mut st = self.stats.borrow_mut();
        st.compiles += 1;
        st.compile_secs += t0.elapsed().as_secs_f64();
        Ok(exe)
    }

    pub fn artifact(&self, backend: &str, step: &str) -> Result<&ArtifactDesc> {
        self.manifest
            .backend(backend)?
            .artifacts
            .get(step)
            .ok_or_else(|| anyhow!("backend {backend} has no '{step}' artifact"))
    }

    /// Execute an artifact with literal inputs; returns the untupled outputs.
    ///
    /// The AOT path lowers with `return_tuple=True`, so the program has a
    /// single tuple output which we decompose into `n_outputs` literals.
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        backend: &str,
        step: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let desc = self.artifact(backend, step)?;
        if inputs.len() != desc.inputs.len() {
            bail!(
                "{backend}/{step}: expected {} inputs, got {}",
                desc.inputs.len(),
                inputs.len()
            );
        }
        let n_outputs = desc.n_outputs;
        let exe = self.executable(backend, step)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<L>(inputs)
            .map_err(|e| anyhow!("executing {backend}/{step}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {backend}/{step} output: {e:?}"))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling {backend}/{step} output: {e:?}"))?;
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_secs += t0.elapsed().as_secs_f64();
        if outs.len() != n_outputs {
            bail!(
                "{backend}/{step}: manifest says {n_outputs} outputs, got {}",
                outs.len()
            );
        }
        Ok(outs)
    }

    /// `execute` over borrowed literals (the common hot-path call shape:
    /// chained step outputs + cached batch literals, zero copies).
    pub fn execute_refs(
        &self,
        backend: &str,
        step: &str,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.execute(backend, step, inputs)
    }

    // -- literal helpers -----------------------------------------------------

    pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("literal shape {dims:?} != data len {}", data.len());
        }
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("literal shape {dims:?} != data len {}", data.len());
        }
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    pub fn scalar_f32(v: f32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    pub fn scalar_i32(v: i32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    pub fn to_f32s(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>()
            .map_err(|e| anyhow!("literal to_vec<f32>: {e:?}"))
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("cached", &self.cache.borrow().len())
            .finish()
    }
}
