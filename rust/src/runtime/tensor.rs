//! Host tensor type crossing the runtime boundary.
//!
//! `Literal` replaces the `xla::Literal` device handle of the original PJRT
//! backend with a pure-Rust, `Send + Sync` value: a shape plus an `Arc`-held
//! buffer. Cloning a literal is a refcount bump, so chained step outputs and
//! cached batch uploads stay zero-copy across the whole local-training loop
//! — including when client loops run on worker threads (the parallel round
//! engine relies on literals being freely shareable).

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

/// The underlying buffer (f32 or i32, matching the manifest dtypes).
#[derive(Clone, Debug, PartialEq)]
pub enum Buf {
    F32(Arc<Vec<f32>>),
    I32(Arc<Vec<i32>>),
}

/// A shaped host tensor. Scalars have an empty `dims`.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<usize>,
    buf: Buf,
}

impl Literal {
    pub fn vec_f32(data: Vec<f32>) -> Literal {
        Literal {
            dims: vec![data.len()],
            buf: Buf::F32(Arc::new(data)),
        }
    }

    pub fn vec_i32(data: Vec<i32>) -> Literal {
        Literal {
            dims: vec![data.len()],
            buf: Buf::I32(Arc::new(data)),
        }
    }

    pub fn scalar_f32(v: f32) -> Literal {
        Literal {
            dims: Vec::new(),
            buf: Buf::F32(Arc::new(vec![v])),
        }
    }

    pub fn scalar_i32(v: i32) -> Literal {
        Literal {
            dims: Vec::new(),
            buf: Buf::I32(Arc::new(vec![v])),
        }
    }

    /// Reinterpret under a new shape (element count must match).
    pub fn reshape(mut self, dims: &[usize]) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if n != self.element_count() {
            bail!(
                "reshape to {dims:?} ({n} elems) from {} elems",
                self.element_count()
            );
        }
        self.dims = dims.to_vec();
        Ok(self)
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        match &self.buf {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
        }
    }

    pub fn dtype(&self) -> &'static str {
        match &self.buf {
            Buf::F32(_) => "f32",
            Buf::I32(_) => "s32",
        }
    }

    /// Borrow as f32 slice (errors on dtype mismatch).
    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.buf {
            Buf::F32(v) => Ok(v),
            Buf::I32(_) => Err(anyhow!("literal is s32, expected f32")),
        }
    }

    /// Borrow as i32 slice (errors on dtype mismatch).
    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.buf {
            Buf::I32(v) => Ok(v),
            Buf::F32(_) => Err(anyhow!("literal is f32, expected s32")),
        }
    }

    /// Copy out as an owned f32 vector.
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.f32s()?.to_vec())
    }

    /// First element as f32 (scalar reads on loss/metric outputs).
    pub fn first_f32(&self) -> Result<f32> {
        self.f32s()?
            .first()
            .copied()
            .ok_or_else(|| anyhow!("empty literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_reads() {
        let l = Literal::vec_f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
            .reshape(&[2, 3])
            .unwrap();
        assert_eq!(l.dims(), &[2, 3]);
        assert_eq!(l.element_count(), 6);
        assert_eq!(l.f32s().unwrap()[4], 5.0);
        assert_eq!(l.first_f32().unwrap(), 1.0);
        assert!(l.i32s().is_err());
    }

    #[test]
    fn reshape_checks_count() {
        assert!(Literal::vec_f32(vec![0.0; 5]).reshape(&[2, 3]).is_err());
        assert!(Literal::vec_i32(vec![0; 6]).reshape(&[3, 2]).is_ok());
    }

    #[test]
    fn scalars() {
        assert_eq!(Literal::scalar_f32(7.5).first_f32().unwrap(), 7.5);
        assert_eq!(Literal::scalar_i32(3).i32s().unwrap(), &[3]);
        assert!(Literal::scalar_f32(0.0).dims().is_empty());
    }

    #[test]
    fn clone_is_shallow() {
        let l = Literal::vec_f32(vec![0.0; 1024]);
        let c = l.clone();
        match (&l.buf, &c.buf) {
            (Buf::F32(a), Buf::F32(b)) => assert!(Arc::ptr_eq(a, b)),
            _ => unreachable!(),
        }
    }
}
