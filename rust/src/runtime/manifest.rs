//! `artifacts/manifest.json` — the contract between the AOT compiler (L2)
//! and the Rust runtime (L3).
//!
//! The manifest declares, per model backend, the parameter count, input
//! shape and the artifact set (init / sgd / eval / prox / scaffold / moon)
//! with full input signatures. The coordinator is *model-agnostic*: it only
//! consumes this file, mirroring the paper's ML-library agnosticism.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorDesc {
    pub shape: Vec<usize>,
    /// "f32" or "s32".
    pub dtype: String,
}

impl TensorDesc {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactDesc {
    pub file: String,
    pub inputs: Vec<TensorDesc>,
    pub n_outputs: usize,
}

#[derive(Clone, Debug)]
pub struct BackendDesc {
    pub name: String,
    pub param_count: usize,
    pub input_shape: Vec<usize>,
    pub use_pallas: bool,
    pub artifacts: BTreeMap<String, ArtifactDesc>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub train_batch: usize,
    pub eval_batch: usize,
    pub jax_version: String,
    pub backends: BTreeMap<String, BackendDesc>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let train_batch = j
            .get("train_batch")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest: missing train_batch"))?;
        let eval_batch = j
            .get("eval_batch")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest: missing eval_batch"))?;
        let jax_version = j
            .get("jax_version")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let mut backends = BTreeMap::new();
        let bmap = j
            .get("backends")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing backends"))?;
        for (name, bj) in bmap {
            let param_count = bj
                .get("param_count")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("backend {name}: missing param_count"))?;
            let input_shape: Vec<usize> = bj
                .get("input_shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("backend {name}: missing input_shape"))?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect();
            let use_pallas = matches!(bj.get("use_pallas"), Some(Json::Bool(true)));
            let mut artifacts = BTreeMap::new();
            let amap = bj
                .get("artifacts")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow!("backend {name}: missing artifacts"))?;
            for (step, aj) in amap {
                artifacts.insert(step.clone(), parse_artifact(name, step, aj)?);
            }
            for required in ["init", "sgd", "eval"] {
                if !artifacts.contains_key(required) {
                    bail!("backend {name}: missing required artifact '{required}'");
                }
            }
            backends.insert(
                name.clone(),
                BackendDesc {
                    name: name.clone(),
                    param_count,
                    input_shape,
                    use_pallas,
                    artifacts,
                },
            );
        }
        Ok(Manifest {
            train_batch,
            eval_batch,
            jax_version,
            backends,
        })
    }

    pub fn backend(&self, name: &str) -> Result<&BackendDesc> {
        self.backends
            .get(name)
            .ok_or_else(|| anyhow!("unknown backend '{name}' (have: {:?})",
                                   self.backends.keys().collect::<Vec<_>>()))
    }
}

fn parse_artifact(backend: &str, step: &str, aj: &Json) -> Result<ArtifactDesc> {
    let file = aj
        .get("file")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("{backend}/{step}: missing file"))?
        .to_string();
    let n_outputs = aj
        .get("n_outputs")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("{backend}/{step}: missing n_outputs"))?;
    let mut inputs = Vec::new();
    for ij in aj
        .get("inputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{backend}/{step}: missing inputs"))?
    {
        let shape = ij
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{backend}/{step}: input missing shape"))?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect();
        let dtype = ij
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("f32")
            .to_string();
        inputs.push(TensorDesc { shape, dtype });
    }
    Ok(ArtifactDesc {
        file,
        inputs,
        n_outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "train_batch": 64, "eval_batch": 256, "jax_version": "0.8.2",
      "backends": {
        "logreg": {
          "param_count": 7850, "input_shape": [784], "use_pallas": true,
          "artifacts": {
            "init": {"file": "logreg_init.hlo.txt", "n_outputs": 1,
                     "inputs": [{"shape": [], "dtype": "s32"}]},
            "sgd": {"file": "logreg_sgd.hlo.txt", "n_outputs": 2,
                    "inputs": [{"shape": [7850], "dtype": "f32"},
                               {"shape": [64, 784], "dtype": "f32"},
                               {"shape": [64], "dtype": "s32"},
                               {"shape": [], "dtype": "f32"}]},
            "eval": {"file": "logreg_eval.hlo.txt", "n_outputs": 2,
                     "inputs": [{"shape": [7850], "dtype": "f32"},
                                {"shape": [256, 784], "dtype": "f32"},
                                {"shape": [256], "dtype": "s32"},
                                {"shape": [256], "dtype": "f32"}]}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.train_batch, 64);
        let b = m.backend("logreg").unwrap();
        assert_eq!(b.param_count, 7850);
        assert_eq!(b.artifacts["sgd"].inputs.len(), 4);
        assert_eq!(b.artifacts["sgd"].inputs[1].element_count(), 64 * 784);
        assert_eq!(b.artifacts["sgd"].inputs[2].dtype, "s32");
    }

    #[test]
    fn missing_required_artifact_rejected() {
        let bad = SAMPLE.replace("\"eval\"", "\"evalX\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn unknown_backend_error_lists_known() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = m.backend("resnet").unwrap_err().to_string();
        assert!(e.contains("logreg"));
    }
}
