//! The pure-Rust reference execution engine.
//!
//! The original L2/L1 pipeline AOT-compiles JAX/Pallas models to HLO and
//! replays them through PJRT. That native toolchain (the `xla` crate) is not
//! available in this offline image, so the runtime ships this reference
//! engine instead: every backend in the manifest is implemented as a dense
//! MLP family (logreg = no hidden layer) over flattened inputs, with the
//! exact step contract of the AOT artifacts — `init` / `sgd` / `eval` plus
//! the strategy steps `prox`, `scaffold` and `moon`.
//!
//! Backend names and roles mirror the AOT manifest (`cnn`, `cnn_v2`, `mlp`,
//! `logreg`); widths are sized for the single-core CI box, and the `cnn*`
//! backends are dense stand-ins for the conv models (the coordinator is
//! library-agnostic and only sees flat parameter vectors either way).
//!
//! Determinism contract (RQ6, and the parallel round engine's foundation):
//! every operation is a fixed-order sequential f32 loop, so a step call is
//! bitwise-reproducible on any thread at any worker count.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::runtime::engine::Engine;
use crate::runtime::manifest::{ArtifactDesc, BackendDesc, Manifest, TensorDesc};
use crate::runtime::tensor::Literal;
use crate::util::rng::Rng;

pub const TRAIN_BATCH: usize = 64;
pub const EVAL_BATCH: usize = 256;
const NUM_CLASSES: usize = 10;

/// Inputs are scaled by this factor inside the model; it normalizes the
/// effective per-step logit movement for the synthetic feature variance so
/// the paper's learning rates (0.01–0.05) sit in the stable regime.
const INPUT_SCALE: f32 = 0.5;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Relu,
    Tanh,
}

/// One reference backend: a dense stack `sizes[0] -> ... -> sizes.last()`.
#[derive(Clone, Debug)]
pub struct RefModel {
    pub name: &'static str,
    /// Manifest input shape (product = sizes[0]).
    pub input_shape: &'static [usize],
    /// Layer widths, input first, classes last.
    pub sizes: &'static [usize],
    pub act: Act,
    /// Strategy artifacts beyond the required init/sgd/eval set.
    pub extra_steps: &'static [&'static str],
}

/// The backend table — the reference analogue of `make artifacts`.
pub const MODELS: &[RefModel] = &[
    RefModel {
        name: "cnn",
        input_shape: &[32, 32, 3],
        sizes: &[3072, 24, 10],
        act: Act::Relu,
        extra_steps: &["prox", "scaffold", "moon"],
    },
    RefModel {
        name: "cnn_v2",
        input_shape: &[32, 32, 3],
        sizes: &[3072, 20, 10],
        act: Act::Tanh,
        extra_steps: &["prox"],
    },
    RefModel {
        name: "mlp",
        input_shape: &[3072],
        sizes: &[3072, 32, 10],
        act: Act::Relu,
        extra_steps: &["prox", "scaffold"],
    },
    RefModel {
        name: "logreg",
        input_shape: &[784],
        sizes: &[784, 10],
        act: Act::Relu,
        extra_steps: &[],
    },
];

impl RefModel {
    pub fn param_count(&self) -> usize {
        self.layer_dims().map(|(fin, fout)| fin * fout + fout).sum()
    }

    fn n_layers(&self) -> usize {
        self.sizes.len() - 1
    }

    fn layer_dims(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.sizes.windows(2).map(|w| (w[0], w[1]))
    }

    /// (offset, fan_in, fan_out) per layer into the flat parameter vector.
    fn layer_offsets(&self) -> Vec<(usize, usize, usize)> {
        let mut out = Vec::with_capacity(self.n_layers());
        let mut off = 0usize;
        for (fin, fout) in self.layer_dims() {
            out.push((off, fin, fout));
            off += fin * fout + fout;
        }
        out
    }

    /// Deterministic Glorot-uniform init (biases zero).
    pub fn init(&self, seed: i32) -> Vec<f32> {
        let mut rng = Rng::seed_from(0x5EED_0000_0000_0000 ^ (seed as i64 as u64));
        let mut out = Vec::with_capacity(self.param_count());
        for (fin, fout) in self.layer_dims() {
            let lim = (6.0 / (fin + fout) as f64).sqrt();
            for _ in 0..fin * fout {
                out.push(((rng.next_f64() * 2.0 - 1.0) * lim) as f32);
            }
            for _ in 0..fout {
                out.push(0.0);
            }
        }
        out
    }

    /// Forward pass; returns post-activation values per layer (the last
    /// entry is the raw logits).
    fn forward(&self, w: &[f32], x: &[f32], bs: usize) -> Vec<Vec<f32>> {
        let offsets = self.layer_offsets();
        let n_layers = self.n_layers();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
        for (l, &(off, fin, fout)) in offsets.iter().enumerate() {
            let z = {
                let a_prev: &[f32] = if l == 0 { x } else { &acts[l - 1] };
                let scale = if l == 0 { INPUT_SCALE } else { 1.0 };
                let wmat = &w[off..off + fin * fout];
                let bias = &w[off + fin * fout..off + fin * fout + fout];
                let mut z = vec![0f32; bs * fout];
                for i in 0..bs {
                    let xi = &a_prev[i * fin..(i + 1) * fin];
                    let zi = &mut z[i * fout..(i + 1) * fout];
                    zi.copy_from_slice(bias);
                    for (k, &xk) in xi.iter().enumerate() {
                        let xv = xk * scale;
                        if xv != 0.0 {
                            let wrow = &wmat[k * fout..(k + 1) * fout];
                            for j in 0..fout {
                                zi[j] += xv * wrow[j];
                            }
                        }
                    }
                }
                if l + 1 < n_layers {
                    match self.act {
                        Act::Relu => {
                            for v in z.iter_mut() {
                                if *v < 0.0 {
                                    *v = 0.0;
                                }
                            }
                        }
                        Act::Tanh => {
                            for v in z.iter_mut() {
                                *v = v.tanh();
                            }
                        }
                    }
                }
                z
            };
            acts.push(z);
        }
        acts
    }

    /// Mean softmax cross-entropy and its parameter gradient over a batch.
    fn grad(&self, w: &[f32], x: &[f32], y: &[i32], bs: usize) -> (Vec<f32>, f32) {
        let offsets = self.layer_offsets();
        let n_layers = self.n_layers();
        let acts = self.forward(w, x, bs);
        let logits = &acts[n_layers - 1];
        let ncls = *self.sizes.last().unwrap();

        // Softmax + CE + dL/dlogits.
        let mut dz_cur = vec![0f32; bs * ncls];
        let mut exps = vec![0f32; ncls];
        let mut loss_sum = 0f64;
        for i in 0..bs {
            let zi = &logits[i * ncls..(i + 1) * ncls];
            let m = zi.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0f32;
            for j in 0..ncls {
                let e = (zi[j] - m).exp();
                exps[j] = e;
                sum += e;
            }
            let yi = (y[i].max(0) as usize).min(ncls - 1);
            let p_yi = (exps[yi] / sum).max(1e-12);
            loss_sum += -(p_yi as f64).ln();
            let dzi = &mut dz_cur[i * ncls..(i + 1) * ncls];
            for j in 0..ncls {
                let onehot = if j == yi { 1.0 } else { 0.0 };
                dzi[j] = (exps[j] / sum - onehot) / bs as f32;
            }
        }

        // Backprop through the dense stack.
        let mut grad = vec![0f32; w.len()];
        for l in (0..n_layers).rev() {
            let (off, fin, fout) = offsets[l];
            let scale = if l == 0 { INPUT_SCALE } else { 1.0 };
            let a_prev: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            {
                let (gw, gb) =
                    grad[off..off + fin * fout + fout].split_at_mut(fin * fout);
                for i in 0..bs {
                    let ai = &a_prev[i * fin..(i + 1) * fin];
                    let dzi = &dz_cur[i * fout..(i + 1) * fout];
                    for (k, &ak) in ai.iter().enumerate() {
                        let av = ak * scale;
                        if av != 0.0 {
                            let gw_row = &mut gw[k * fout..(k + 1) * fout];
                            for j in 0..fout {
                                gw_row[j] += av * dzi[j];
                            }
                        }
                    }
                    for j in 0..fout {
                        gb[j] += dzi[j];
                    }
                }
            }
            if l > 0 {
                let wmat = &w[off..off + fin * fout];
                let upstream = &acts[l - 1];
                let mut dz_prev = vec![0f32; bs * fin];
                for i in 0..bs {
                    let dzi = &dz_cur[i * fout..(i + 1) * fout];
                    let dpi = &mut dz_prev[i * fin..(i + 1) * fin];
                    let ai = &upstream[i * fin..(i + 1) * fin];
                    for k in 0..fin {
                        let wrow = &wmat[k * fout..(k + 1) * fout];
                        let mut s = 0f32;
                        for j in 0..fout {
                            s += dzi[j] * wrow[j];
                        }
                        // Activation derivative at the post-activation value.
                        s = match self.act {
                            Act::Relu => {
                                if ai[k] > 0.0 {
                                    s
                                } else {
                                    0.0
                                }
                            }
                            Act::Tanh => s * (1.0 - ai[k] * ai[k]),
                        };
                        dpi[k] = s;
                    }
                }
                dz_cur = dz_prev;
            }
        }
        (grad, (loss_sum / bs as f64) as f32)
    }

    /// Masked evaluation: (summed CE loss, correct count) over `mask`.
    fn eval(&self, w: &[f32], x: &[f32], y: &[i32], mask: &[f32], bs: usize) -> (f32, f32) {
        let acts = self.forward(w, x, bs);
        let logits = &acts[self.n_layers() - 1];
        let ncls = *self.sizes.last().unwrap();
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        for i in 0..bs {
            if mask[i] == 0.0 {
                continue;
            }
            let zi = &logits[i * ncls..(i + 1) * ncls];
            let m = zi.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0f32;
            let mut best = 0usize;
            for j in 0..ncls {
                sum += (zi[j] - m).exp();
                if zi[j] > zi[best] {
                    best = j;
                }
            }
            let yi = (y[i].max(0) as usize).min(ncls - 1);
            let p_yi = (((zi[yi] - m).exp()) / sum).max(1e-12);
            loss_sum += -(p_yi as f64).ln() * mask[i] as f64;
            if best == yi {
                correct += mask[i] as f64;
            }
        }
        (loss_sum as f32, correct as f32)
    }
}

/// Build the built-in manifest describing [`MODELS`] with full artifact
/// signatures — the contract `Runtime`/`ModelBackend` consume.
pub fn reference_manifest() -> Manifest {
    let vecdesc = |shape: Vec<usize>, dtype: &str| TensorDesc {
        shape,
        dtype: dtype.to_string(),
    };
    let mut backends = BTreeMap::new();
    for m in MODELS {
        let p = m.param_count();
        let f: usize = m.input_shape.iter().product();
        let params = || vecdesc(vec![p], "f32");
        let scalar_f = || vecdesc(vec![], "f32");
        let train_x = || vecdesc(vec![TRAIN_BATCH, f], "f32");
        let train_y = || vecdesc(vec![TRAIN_BATCH], "s32");

        let mut artifacts = BTreeMap::new();
        artifacts.insert(
            "init".to_string(),
            ArtifactDesc {
                file: "<builtin>".into(),
                inputs: vec![vecdesc(vec![], "s32")],
                n_outputs: 1,
            },
        );
        artifacts.insert(
            "sgd".to_string(),
            ArtifactDesc {
                file: "<builtin>".into(),
                inputs: vec![params(), train_x(), train_y(), scalar_f()],
                n_outputs: 2,
            },
        );
        artifacts.insert(
            "eval".to_string(),
            ArtifactDesc {
                file: "<builtin>".into(),
                inputs: vec![
                    params(),
                    vecdesc(vec![EVAL_BATCH, f], "f32"),
                    vecdesc(vec![EVAL_BATCH], "s32"),
                    vecdesc(vec![EVAL_BATCH], "f32"),
                ],
                n_outputs: 2,
            },
        );
        for &step in m.extra_steps {
            let inputs = match step {
                "prox" => vec![
                    params(),
                    params(),
                    train_x(),
                    train_y(),
                    scalar_f(),
                    scalar_f(),
                ],
                "scaffold" => vec![
                    params(),
                    params(),
                    params(),
                    train_x(),
                    train_y(),
                    scalar_f(),
                ],
                "moon" => vec![
                    params(),
                    params(),
                    params(),
                    train_x(),
                    train_y(),
                    scalar_f(),
                    scalar_f(),
                    scalar_f(),
                ],
                other => unreachable!("unknown extra step '{other}'"),
            };
            artifacts.insert(
                step.to_string(),
                ArtifactDesc {
                    file: "<builtin>".into(),
                    inputs,
                    n_outputs: 2,
                },
            );
        }
        backends.insert(
            m.name.to_string(),
            BackendDesc {
                name: m.name.to_string(),
                param_count: p,
                input_shape: m.input_shape.to_vec(),
                use_pallas: false,
                artifacts,
            },
        );
    }
    Manifest {
        train_batch: TRAIN_BATCH,
        eval_batch: EVAL_BATCH,
        jax_version: "reference (pure-rust)".to_string(),
        backends,
    }
}

/// The engine: stateless (models are immutable), hence trivially `Sync`.
pub struct ReferenceEngine {
    models: BTreeMap<&'static str, &'static RefModel>,
}

impl ReferenceEngine {
    pub fn new() -> ReferenceEngine {
        ReferenceEngine {
            models: MODELS.iter().map(|m| (m.name, m)).collect(),
        }
    }

    fn model(&self, backend: &str) -> Result<&RefModel> {
        self.models
            .get(backend)
            .copied()
            .ok_or_else(|| anyhow!("reference engine: unknown backend '{backend}'"))
    }
}

impl Default for ReferenceEngine {
    fn default() -> Self {
        Self::new()
    }
}

/// Shared shape of every train-style step: unpack params/x/y, compute the
/// base CE gradient, let the variant adjust (gradient, loss), apply SGD.
struct TrainIn<'a> {
    w: &'a [f32],
    x: &'a [f32],
    y: &'a [i32],
    bs: usize,
    lr: f32,
}

impl ReferenceEngine {
    /// Validate an auxiliary parameter-shaped input (global model, previous
    /// model, control variates): must be f32 and exactly `param_count` long
    /// — a silent zip-truncation would apply corrections to a prefix only.
    fn unpack_aux<'a>(
        model: &RefModel,
        what: &str,
        lit: &'a Literal,
    ) -> Result<&'a [f32]> {
        let v = lit.f32s()?;
        if v.len() != model.param_count() {
            bail!(
                "{}: {what} len {} != param_count {}",
                model.name,
                v.len(),
                model.param_count()
            );
        }
        Ok(v)
    }

    fn unpack_train<'a>(
        model: &RefModel,
        params: &'a Literal,
        x: &'a Literal,
        y: &'a Literal,
        lr: &Literal,
    ) -> Result<TrainIn<'a>> {
        let w = params.f32s()?;
        if w.len() != model.param_count() {
            bail!(
                "{}: params len {} != {}",
                model.name,
                w.len(),
                model.param_count()
            );
        }
        let xs = x.f32s()?;
        let ys = y.i32s()?;
        let fin: usize = model.sizes[0];
        if xs.len() % fin != 0 {
            bail!("{}: batch len {} not divisible by {fin}", model.name, xs.len());
        }
        let bs = xs.len() / fin;
        if ys.len() != bs {
            bail!("{}: {} labels for batch of {bs}", model.name, ys.len());
        }
        Ok(TrainIn {
            w,
            x: xs,
            y: ys,
            bs,
            lr: lr.first_f32()?,
        })
    }

    fn finish_step(t: &TrainIn, grad: &[f32], loss: f32) -> Vec<Literal> {
        // Blocked `w − lr·g` (same per-element ops as the scalar map it
        // replaced — see aggregate::kernel): this runs once per batch per
        // client on the fallback backend.
        let mut new_w = vec![0f32; t.w.len()];
        crate::aggregate::kernel::sub_scaled_into(&mut new_w, t.w, t.lr, grad);
        vec![Literal::vec_f32(new_w), Literal::scalar_f32(loss)]
    }
}

impl Engine for ReferenceEngine {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn run(&self, backend: &str, step: &str, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let model = self.model(backend)?;
        let declared = matches!(step, "init" | "sgd" | "eval")
            || model.extra_steps.contains(&step);
        if !declared {
            bail!("reference engine: backend {backend} has no '{step}' artifact");
        }
        let need = match step {
            "init" => 1,
            "sgd" | "eval" => 4,
            "prox" | "scaffold" => 6,
            "moon" => 8,
            _ => unreachable!(),
        };
        if inputs.len() != need {
            bail!("{backend}/{step}: expected {need} inputs, got {}", inputs.len());
        }
        match step {
            "init" => {
                // Propagate dtype mismatches — a silent seed-0 fallback would
                // mask caller bugs behind "deterministic" identical inits.
                let seed = inputs[0].i32s()?.first().copied().unwrap_or(0);
                Ok(vec![Literal::vec_f32(model.init(seed))])
            }
            "sgd" => {
                let t = Self::unpack_train(model, inputs[0], inputs[1], inputs[2], inputs[3])?;
                let (grad, loss) = model.grad(t.w, t.x, t.y, t.bs);
                Ok(Self::finish_step(&t, &grad, loss))
            }
            "prox" => {
                // [params, global, x, y, lr, mu]
                let t = Self::unpack_train(model, inputs[0], inputs[2], inputs[3], inputs[4])?;
                let global = Self::unpack_aux(model, "global", inputs[1])?;
                let mu = inputs[5].first_f32()?;
                let (mut grad, loss) = model.grad(t.w, t.x, t.y, t.bs);
                for (g, (&w, &wg)) in grad.iter_mut().zip(t.w.iter().zip(global)) {
                    *g += mu * (w - wg);
                }
                Ok(Self::finish_step(&t, &grad, loss))
            }
            "scaffold" => {
                // [params, c_global, c_local, x, y, lr]
                let t = Self::unpack_train(model, inputs[0], inputs[3], inputs[4], inputs[5])?;
                let c_global = Self::unpack_aux(model, "c_global", inputs[1])?;
                let c_local = Self::unpack_aux(model, "c_local", inputs[2])?;
                let (mut grad, loss) = model.grad(t.w, t.x, t.y, t.bs);
                for (g, (&cg, &cl)) in grad.iter_mut().zip(c_global.iter().zip(c_local)) {
                    *g += cg - cl;
                }
                Ok(Self::finish_step(&t, &grad, loss))
            }
            "moon" => {
                // [params, global, prev, x, y, lr, mu, tau]
                // Parameter-space contrastive surrogate: pull toward the
                // global model, push (half as hard) away from the previous
                // local one — the drift-control effect of MOON's
                // representation-level loss, expressible without a second
                // and third forward graph.
                let t = Self::unpack_train(model, inputs[0], inputs[3], inputs[4], inputs[5])?;
                let global = Self::unpack_aux(model, "global", inputs[1])?;
                let prev = Self::unpack_aux(model, "prev", inputs[2])?;
                let mu = inputs[6].first_f32()?;
                let tau = inputs[7].first_f32()?.max(1e-6);
                let pull = 0.1 * mu / tau;
                let (mut grad, loss) = model.grad(t.w, t.x, t.y, t.bs);
                let mut sq_g = 0f64;
                let mut sq_p = 0f64;
                for i in 0..t.w.len() {
                    let dg = t.w[i] - global[i];
                    let dp = t.w[i] - prev[i];
                    sq_g += (dg * dg) as f64;
                    sq_p += (dp * dp) as f64;
                    grad[i] += pull * (dg - 0.5 * dp);
                }
                let con = pull as f64 * (0.5 * sq_g - 0.25 * sq_p) / t.w.len().max(1) as f64;
                Ok(Self::finish_step(&t, &grad, loss + con as f32))
            }
            "eval" => {
                // [params, x, y, mask]
                let w = inputs[0].f32s()?;
                let xs = inputs[1].f32s()?;
                let ys = inputs[2].i32s()?;
                let mask = inputs[3].f32s()?;
                let fin = model.sizes[0];
                let bs = xs.len() / fin;
                if ys.len() != bs || mask.len() != bs {
                    bail!("{backend}/eval: inconsistent batch sizes");
                }
                let (loss_sum, correct) = model.eval(w, xs, ys, mask, bs);
                Ok(vec![
                    Literal::scalar_f32(loss_sum),
                    Literal::scalar_f32(correct),
                ])
            }
            other => bail!("reference engine: backend {backend} has no step '{other}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cnn() -> &'static RefModel {
        MODELS.iter().find(|m| m.name == "cnn").unwrap()
    }

    fn logreg() -> &'static RefModel {
        MODELS.iter().find(|m| m.name == "logreg").unwrap()
    }

    #[test]
    fn param_counts_match_layout() {
        assert_eq!(logreg().param_count(), 784 * 10 + 10);
        assert_eq!(cnn().param_count(), 3072 * 24 + 24 + 24 * 10 + 10);
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let m = logreg();
        assert_eq!(m.init(7), m.init(7));
        assert_ne!(m.init(7), m.init(8));
        assert_eq!(m.init(7).len(), m.param_count());
    }

    #[test]
    fn manifest_declares_required_artifacts() {
        let mf = reference_manifest();
        for name in ["cnn", "cnn_v2", "mlp", "logreg"] {
            let b = mf.backend(name).unwrap();
            for s in ["init", "sgd", "eval"] {
                assert!(b.artifacts.contains_key(s), "{name} missing {s}");
            }
        }
        assert!(mf.backend("cnn").unwrap().artifacts.contains_key("moon"));
        assert!(!mf.backend("mlp").unwrap().artifacts.contains_key("moon"));
    }

    /// Central-difference check of the analytic gradient on a tiny batch.
    #[test]
    fn gradient_matches_finite_differences() {
        let m = logreg();
        let mut rng = Rng::seed_from(3);
        let w = m.init(1);
        let bs = 3usize;
        let x: Vec<f32> = (0..bs * 784).map(|_| rng.normal_f32()).collect();
        let y: Vec<i32> = (0..bs).map(|_| rng.below(10) as i32).collect();
        let (grad, _) = m.grad(&w, &x, &y, bs);
        let loss_at = |w: &[f32]| {
            let acts = m.forward(w, &x, bs);
            let logits = acts.last().unwrap();
            let mut s = 0f64;
            for i in 0..bs {
                let zi = &logits[i * 10..(i + 1) * 10];
                let mx = zi.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let sum: f32 = zi.iter().map(|&z| (z - mx).exp()).sum();
                let p = ((zi[y[i] as usize] - mx).exp() / sum).max(1e-12);
                s += -(p as f64).ln();
            }
            s / bs as f64
        };
        // Check a spread of coordinates (weights + biases).
        for &idx in &[0usize, 57, 784 * 10 - 1, 784 * 10 + 3] {
            let eps = 1e-2f32;
            let mut wp = w.clone();
            wp[idx] += eps;
            let mut wm = w.clone();
            wm[idx] -= eps;
            let num = (loss_at(&wp) - loss_at(&wm)) / (2.0 * eps as f64);
            let ana = grad[idx] as f64;
            assert!(
                (num - ana).abs() < 1e-3 + 0.05 * ana.abs(),
                "coord {idx}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn sgd_reduces_loss_on_a_fixed_batch() {
        let m = cnn();
        let mut rng = Rng::seed_from(5);
        let mut w = m.init(0);
        let bs = 16usize;
        // Learnable signal: class = sign pattern on the first features.
        let mut x = vec![0f32; bs * 3072];
        let mut y = vec![0i32; bs];
        for i in 0..bs {
            let c = (i % 10) as i32;
            y[i] = c;
            for k in 0..3072 {
                x[i * 3072 + k] =
                    if k % 10 == c as usize { 2.0 } else { 0.0 } + 0.3 * rng.normal_f32();
            }
        }
        let (_, first_loss) = m.grad(&w, &x, &y, bs);
        for _ in 0..30 {
            let (g, _) = m.grad(&w, &x, &y, bs);
            for (wv, gv) in w.iter_mut().zip(&g) {
                *wv -= 0.05 * gv;
            }
        }
        let (_, final_loss) = m.grad(&w, &x, &y, bs);
        assert!(
            final_loss < first_loss * 0.7,
            "loss did not drop: {first_loss} -> {final_loss}"
        );
    }

    #[test]
    fn engine_steps_are_deterministic() {
        let eng = ReferenceEngine::new();
        let m = logreg();
        let mut rng = Rng::seed_from(9);
        let w = Literal::vec_f32(m.init(2));
        let x = Literal::vec_f32((0..4 * 784).map(|_| rng.normal_f32()).collect());
        let y = Literal::vec_i32((0..4).map(|_| rng.below(10) as i32).collect());
        let lr = Literal::scalar_f32(0.05);
        let a = eng.run("logreg", "sgd", &[&w, &x, &y, &lr]).unwrap();
        let b = eng.run("logreg", "sgd", &[&w, &x, &y, &lr]).unwrap();
        assert_eq!(a[0].f32s().unwrap(), b[0].f32s().unwrap());
        assert_eq!(a[1].first_f32().unwrap(), b[1].first_f32().unwrap());
        // And across threads (the parallel engine's determinism premise).
        let eng = std::sync::Arc::new(eng);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let eng = eng.clone();
                let (w, x, y, lr) = (w.clone(), x.clone(), y.clone(), lr.clone());
                std::thread::spawn(move || {
                    let out = eng.run("logreg", "sgd", &[&w, &x, &y, &lr]).unwrap();
                    out[0].f32s().unwrap().to_vec()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), a[0].f32s().unwrap());
        }
    }

    #[test]
    fn unknown_backend_and_step_error() {
        let eng = ReferenceEngine::new();
        assert!(eng.run("resnet", "sgd", &[]).is_err());
        let w = Literal::vec_f32(logreg().init(0));
        assert!(eng.run("logreg", "moon", &[&w]).is_err());
    }
}
