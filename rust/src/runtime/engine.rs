//! The execution-engine boundary of the runtime layer.
//!
//! [`crate::runtime::pjrt::Runtime`] dispatches every step call through this
//! trait, which is deliberately `Send + Sync`: the parallel round engine
//! shares one `Arc<Runtime>` across all client worker threads, so an engine
//! must tolerate concurrent `run` calls and must be deterministic per call
//! (same inputs ⇒ bitwise-same outputs, regardless of which thread runs it).
//!
//! Two engines exist conceptually:
//! * [`crate::runtime::reference::ReferenceEngine`] — the pure-Rust
//!   deterministic engine compiled into every build (no external deps).
//! * a PJRT engine executing the AOT HLO artifacts — requires the `xla`
//!   native toolchain, which this offline image does not carry; the trait is
//!   the slot it plugs back into.

use anyhow::Result;

use crate::runtime::tensor::Literal;

pub trait Engine: Send + Sync {
    /// Engine identifier for logs / `flsim info`.
    fn name(&self) -> &'static str;

    /// Execute one step artifact for a backend. Inputs and outputs follow
    /// the manifest signature for `backend`/`step`.
    fn run(&self, backend: &str, step: &str, inputs: &[&Literal]) -> Result<Vec<Literal>>;
}
