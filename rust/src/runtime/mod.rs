//! PJRT runtime: loads the AOT HLO artifacts produced by `python/compile/aot.py`
//! and executes them on the hot path. Python is never involved at run time.

pub mod backend;
pub mod manifest;
pub mod pjrt;

pub use backend::ModelBackend;
pub use manifest::{ArtifactDesc, Manifest, TensorDesc};
pub use pjrt::Runtime;
