//! The runtime layer: a `Send + Sync` execution engine behind a manifest of
//! model backends. The default engine is the pure-Rust deterministic
//! [`reference`] engine; the PJRT/AOT path (HLO artifacts produced by
//! `python/compile/aot.py`) plugs into the same [`engine::Engine`] trait
//! when its native toolchain is available.

pub mod backend;
pub mod engine;
pub mod manifest;
pub mod pjrt;
pub mod reference;
pub mod tensor;

pub use backend::ModelBackend;
pub use engine::Engine;
pub use manifest::{ArtifactDesc, Manifest, TensorDesc};
pub use pjrt::Runtime;
pub use tensor::Literal;
