//! Dataset substrate: synthetic dataset generators, IID / non-IID
//! partitioners, and the Dataset Distributor (paper §2.1 component 3).

pub mod dataset;
pub mod distributor;
pub mod partition;
pub mod synthetic;

pub use dataset::{Dataset, DatasetSpec};
pub use distributor::{ChunkIndex, Distributor};
pub use partition::Partition;
