//! In-memory dataset representation and job-facing dataset specification.

use crate::util::rng::Rng;

/// A labelled dataset held as a dense row-major feature matrix.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Per-example feature shape, e.g. `[32, 32, 3]` or `[784]`.
    pub feature_shape: Vec<usize>,
    /// `n * feature_len` features.
    pub x: Vec<f32>,
    /// `n` labels in `0..num_classes`.
    pub y: Vec<i32>,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn feature_len(&self) -> usize {
        self.feature_shape.iter().product()
    }

    /// Row-view of example `i`.
    pub fn features(&self, i: usize) -> &[f32] {
        let f = self.feature_len();
        &self.x[i * f..(i + 1) * f]
    }

    /// Materialize a subset in index order.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let f = self.feature_len();
        let mut x = Vec::with_capacity(idx.len() * f);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.features(i));
            y.push(self.y[i]);
        }
        Dataset {
            feature_shape: self.feature_shape.clone(),
            x,
            y,
            num_classes: self.num_classes,
        }
    }

    /// Deterministic train/test split (paper default 0.8/0.2).
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        let n_train = ((self.len() as f64) * train_frac).round() as usize;
        let (tr, te) = idx.split_at(n_train.min(self.len()));
        (self.subset(tr), self.subset(te))
    }

    /// Per-class index lists.
    pub fn indices_by_class(&self) -> Vec<Vec<usize>> {
        let mut by = vec![Vec::new(); self.num_classes];
        for (i, &c) in self.y.iter().enumerate() {
            by[c as usize].push(i);
        }
        by
    }

    /// Raw byte size (for distributor accounting).
    pub fn byte_size(&self) -> u64 {
        (self.x.len() * 4 + self.y.len() * 4) as u64
    }
}

/// Which dataset a job wants and how it is distributed — section (a) of the
/// paper's job configuration (Fig 2a).
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetSpec {
    /// "cifar10_synth" or "mnist_synth".
    pub name: String,
    /// Total examples to generate.
    pub n: usize,
    /// Train fraction (rest is the global test set).
    pub train_frac: f64,
    /// Partitioning scheme across clients.
    pub distribution: Distribution,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Distribution {
    /// Uniform IID split.
    Iid,
    /// Label-Dirichlet non-IID split (the paper's default, alpha = 0.5).
    Dirichlet { alpha: f64 },
    /// Pathological shard split (each client sees `shards_per_client` label
    /// shards, à la McMahan et al.).
    Shards { shards_per_client: usize },
}

impl DatasetSpec {
    pub fn cifar_dirichlet(n: usize, alpha: f64) -> DatasetSpec {
        DatasetSpec {
            name: "cifar10_synth".into(),
            n,
            train_frac: 0.8,
            distribution: Distribution::Dirichlet { alpha },
        }
    }

    pub fn mnist_iid(n: usize) -> DatasetSpec {
        DatasetSpec {
            name: "mnist_synth".into(),
            n,
            train_frac: 0.8,
            distribution: Distribution::Iid,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn subset_and_views() {
        let ds = synthetic::mnist_synth(50, 42);
        let sub = ds.subset(&[0, 5, 7]);
        assert_eq!(sub.len(), 3);
        assert_eq!(sub.features(1), ds.features(5));
        assert_eq!(sub.y[2], ds.y[7]);
    }

    #[test]
    fn split_partitions_everything() {
        let ds = synthetic::mnist_synth(100, 1);
        let mut rng = Rng::seed_from(9);
        let (tr, te) = ds.split(0.8, &mut rng);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.byte_size() + te.byte_size(), ds.byte_size());
    }

    #[test]
    fn split_deterministic() {
        let ds = synthetic::mnist_synth(60, 2);
        let (a, _) = ds.split(0.5, &mut Rng::seed_from(3));
        let (b, _) = ds.split(0.5, &mut Rng::seed_from(3));
        assert_eq!(a.y, b.y);
        assert_eq!(a.x, b.x);
    }

    #[test]
    fn indices_by_class_cover_all() {
        let ds = synthetic::mnist_synth(200, 5);
        let by = ds.indices_by_class();
        let total: usize = by.iter().map(Vec::len).sum();
        assert_eq!(total, ds.len());
        for (c, idxs) in by.iter().enumerate() {
            for &i in idxs {
                assert_eq!(ds.y[i] as usize, c);
            }
        }
    }
}
