//! Deterministic synthetic dataset generators (DESIGN.md §3 substitution:
//! no dataset downloads exist in this offline environment, so CIFAR-10 /
//! MNIST are replaced by shape-compatible class-conditional generators).
//!
//! Construction: every class owns a small bank of smooth "prototype"
//! patterns; an example is a randomly-weighted prototype mix plus Gaussian
//! pixel noise. The class structure is linearly detectable but noisy enough
//! that accuracy climbs over tens of FL rounds instead of saturating in one
//! — which is what the paper's learning-curve figures need.

use crate::data::dataset::Dataset;
use crate::util::rng::Rng;

pub const NUM_CLASSES: usize = 10;

/// CIFAR-10 stand-in: 32x32x3 images, 10 classes.
pub fn cifar10_synth(n: usize, seed: u64) -> Dataset {
    class_mixture(n, &[32, 32, 3], seed ^ 0xC1FA_C1FA, 3, 1.0, 2.2)
}

/// MNIST stand-in: 784-feature vectors, 10 classes.
pub fn mnist_synth(n: usize, seed: u64) -> Dataset {
    class_mixture(n, &[784], seed ^ 0x3141_5926, 2, 1.0, 1.6)
}

/// Generate by spec name.
pub fn by_name(name: &str, n: usize, seed: u64) -> Option<Dataset> {
    match name {
        "cifar10_synth" => Some(cifar10_synth(n, seed)),
        "mnist_synth" => Some(mnist_synth(n, seed)),
        _ => None,
    }
}

fn class_mixture(
    n: usize,
    feature_shape: &[usize],
    seed: u64,
    modes_per_class: usize,
    signal: f32,
    noise: f32,
) -> Dataset {
    let f: usize = feature_shape.iter().product();
    let root = Rng::seed_from(seed);

    // Smooth per-class prototypes: low-frequency random walks so conv
    // filters have local structure to latch onto.
    let mut protos = vec![vec![0f32; f]; NUM_CLASSES * modes_per_class];
    let mut proto_rng = root.derive("prototypes", 0);
    for proto in protos.iter_mut() {
        let mut v = 0f32;
        for p in proto.iter_mut() {
            v = 0.9 * v + 0.45 * proto_rng.normal_f32();
            *p = v;
        }
        // Normalize prototype energy so classes are equally detectable.
        let norm = (proto.iter().map(|&x| x * x).sum::<f32>() / f as f32).sqrt();
        if norm > 0.0 {
            for p in proto.iter_mut() {
                *p /= norm;
            }
        }
    }

    let mut label_rng = root.derive("labels", 1);
    let mut mix_rng = root.derive("mixing", 2);
    let mut noise_rng = root.derive("noise", 3);

    let mut x = Vec::with_capacity(n * f);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = label_rng.below(NUM_CLASSES);
        let mode = mix_rng.below(modes_per_class);
        let w_main = 0.7 + 0.3 * mix_rng.next_f32();
        let proto = &protos[c * modes_per_class + mode];
        for &p in proto.iter() {
            x.push(signal * w_main * p + noise * noise_rng.normal_f32());
        }
        y.push(c as i32);
    }

    Dataset {
        feature_shape: feature_shape.to_vec(),
        x,
        y,
        num_classes: NUM_CLASSES,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = cifar10_synth(20, 7);
        let b = cifar10_synth(20, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn different_seeds_differ() {
        let a = cifar10_synth(20, 7);
        let b = cifar10_synth(20, 8);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn shapes() {
        let a = cifar10_synth(5, 1);
        assert_eq!(a.feature_len(), 32 * 32 * 3);
        assert_eq!(a.len(), 5);
        let m = mnist_synth(5, 1);
        assert_eq!(m.feature_len(), 784);
    }

    #[test]
    fn all_classes_present() {
        let a = mnist_synth(500, 3);
        let by = a.indices_by_class();
        assert!(by.iter().all(|v| !v.is_empty()));
    }

    #[test]
    fn classes_are_separable_by_nearest_prototype() {
        // Sanity: class-conditional means of a training half should classify
        // a held-out half far above chance. (Guards against generating
        // unlearnable noise — the learning curves in every figure depend
        // on this property.)
        let ds = mnist_synth(2000, 11);
        let f = ds.feature_len();
        let half = ds.len() / 2;
        let mut means = vec![vec![0f64; f]; NUM_CLASSES];
        let mut counts = vec![0usize; NUM_CLASSES];
        for i in 0..half {
            let c = ds.y[i] as usize;
            counts[c] += 1;
            for (m, &v) in means[c].iter_mut().zip(ds.features(i)) {
                *m += v as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in half..ds.len() {
            let xi = ds.features(i);
            let mut best = (f64::INFINITY, 0usize);
            for (c, m) in means.iter().enumerate() {
                let d: f64 = m
                    .iter()
                    .zip(xi)
                    .map(|(&a, &b)| (a - b as f64) * (a - b as f64))
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == ds.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / (ds.len() - half) as f64;
        assert!(acc > 0.5, "nearest-mean accuracy {acc} too low");
    }

    #[test]
    fn by_name_dispatch() {
        assert!(by_name("cifar10_synth", 3, 0).is_some());
        assert!(by_name("mnist_synth", 3, 0).is_some());
        assert!(by_name("imagenet", 3, 0).is_none());
    }
}
