//! Dataset Distributor (paper §2.1 component 3): archives the partitioned
//! dataset into compressed chunks, indexes them, and serves per-node
//! downloads with byte accounting.
//!
//! In the paper this is an HTTP chunk server; here chunks are compressed
//! in-memory archives (word-RLE, [`crate::util::codec`]) handed to nodes
//! through the same interface, with download volumes feeding the bandwidth
//! metrics.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::data::dataset::Dataset;
use crate::data::partition::Partition;
use crate::util::codec;
use crate::util::hash;

/// A compressed, content-addressed dataset chunk.
#[derive(Clone, Debug)]
pub struct Chunk {
    pub id: String,
    pub bytes: Vec<u8>,
    pub uncompressed_len: u64,
    pub n_examples: usize,
}

/// Index of archived chunks: chunk id per (node, split).
#[derive(Clone, Debug, Default)]
pub struct ChunkIndex {
    pub entries: BTreeMap<String, String>,
}

impl ChunkIndex {
    fn key(node: &str, split: &str) -> String {
        format!("{node}/{split}")
    }
}

/// The distributor: archive side + download side.
pub struct Distributor {
    chunks: BTreeMap<String, Chunk>,
    index: ChunkIndex,
    /// Total bytes served (compressed), per node.
    served: BTreeMap<String, u64>,
}

impl Distributor {
    pub fn new() -> Distributor {
        Distributor {
            chunks: BTreeMap::new(),
            index: ChunkIndex::default(),
            served: BTreeMap::new(),
        }
    }

    /// Archive a partitioned training set: one chunk per client plus a
    /// shared "test" chunk every node can fetch.
    pub fn archive_partition(
        &mut self,
        train: &Dataset,
        part: &Partition,
        node_names: &[String],
        test: &Dataset,
    ) -> Result<()> {
        if node_names.len() != part.n_clients() {
            return Err(anyhow!(
                "{} node names for {} partitions",
                node_names.len(),
                part.n_clients()
            ));
        }
        for (name, idxs) in node_names.iter().zip(&part.assignments) {
            let sub = train.subset(idxs);
            self.put(name, "train", &sub)?;
        }
        self.put_shared("test", test)?;
        Ok(())
    }

    /// Archive a chunk for one node.
    pub fn put(&mut self, node: &str, split: &str, ds: &Dataset) -> Result<()> {
        let chunk = encode_chunk(ds)?;
        self.index
            .entries
            .insert(ChunkIndex::key(node, split), chunk.id.clone());
        self.chunks.insert(chunk.id.clone(), chunk);
        Ok(())
    }

    /// Archive a shared chunk under the pseudo-node "*".
    pub fn put_shared(&mut self, split: &str, ds: &Dataset) -> Result<()> {
        self.put("*", split, ds)
    }

    /// Node-side download (with per-node byte accounting). Falls back to the
    /// shared chunk when the node has no dedicated one.
    pub fn download(&mut self, node: &str, split: &str) -> Result<Dataset> {
        let id = self
            .index
            .entries
            .get(&ChunkIndex::key(node, split))
            .or_else(|| self.index.entries.get(&ChunkIndex::key("*", split)))
            .ok_or_else(|| anyhow!("no chunk for {node}/{split}"))?
            .clone();
        let chunk = self
            .chunks
            .get(&id)
            .ok_or_else(|| anyhow!("dangling chunk id {id}"))?;
        *self.served.entry(node.to_string()).or_insert(0) += chunk.bytes.len() as u64;
        decode_chunk(chunk)
    }

    pub fn bytes_served(&self, node: &str) -> u64 {
        self.served.get(node).copied().unwrap_or(0)
    }

    pub fn total_bytes_served(&self) -> u64 {
        self.served.values().sum()
    }

    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }
}

impl Default for Distributor {
    fn default() -> Self {
        Self::new()
    }
}

/// Chunk wire format: header (shape / classes / counts) + LE f32/i32 bodies,
/// word-RLE-compressed, content-addressed by SHA-256.
fn encode_chunk(ds: &Dataset) -> Result<Chunk> {
    let mut raw = Vec::with_capacity(ds.x.len() * 4 + ds.y.len() * 4 + 64);
    raw.extend_from_slice(&(ds.feature_shape.len() as u32).to_le_bytes());
    for &d in &ds.feature_shape {
        raw.extend_from_slice(&(d as u32).to_le_bytes());
    }
    raw.extend_from_slice(&(ds.num_classes as u32).to_le_bytes());
    raw.extend_from_slice(&(ds.len() as u32).to_le_bytes());
    for &v in &ds.x {
        raw.extend_from_slice(&v.to_le_bytes());
    }
    for &v in &ds.y {
        raw.extend_from_slice(&v.to_le_bytes());
    }
    let bytes = codec::compress(&raw);
    Ok(Chunk {
        id: hash::sha256_hex(&bytes)[..32].to_string(),
        uncompressed_len: raw.len() as u64,
        n_examples: ds.len(),
        bytes,
    })
}

fn decode_chunk(chunk: &Chunk) -> Result<Dataset> {
    let raw = codec::decompress(&chunk.bytes)?;
    if raw.len() as u64 != chunk.uncompressed_len {
        return Err(anyhow!(
            "chunk decompressed to {} bytes, expected {}",
            raw.len(),
            chunk.uncompressed_len
        ));
    }
    let mut pos = 0usize;
    let mut take_u32 = |raw: &[u8]| -> Result<u32> {
        if pos + 4 > raw.len() {
            return Err(anyhow!("truncated chunk"));
        }
        let v = u32::from_le_bytes(raw[pos..pos + 4].try_into().unwrap());
        pos += 4;
        Ok(v)
    };
    let ndim = take_u32(&raw)? as usize;
    let mut feature_shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        feature_shape.push(take_u32(&raw)? as usize);
    }
    let num_classes = take_u32(&raw)? as usize;
    let n = take_u32(&raw)? as usize;
    let f: usize = feature_shape.iter().product();
    let need = pos + n * f * 4 + n * 4;
    if raw.len() != need {
        return Err(anyhow!("chunk size mismatch: {} != {need}", raw.len()));
    }
    let mut x = Vec::with_capacity(n * f);
    for i in 0..n * f {
        let o = pos + i * 4;
        x.push(f32::from_le_bytes(raw[o..o + 4].try_into().unwrap()));
    }
    let ybase = pos + n * f * 4;
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let o = ybase + i * 4;
        y.push(i32::from_le_bytes(raw[o..o + 4].try_into().unwrap()));
    }
    Ok(Dataset {
        feature_shape,
        x,
        y,
        num_classes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Distribution;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    #[test]
    fn chunk_roundtrip() {
        let ds = synthetic::mnist_synth(37, 1);
        let c = encode_chunk(&ds).unwrap();
        let back = decode_chunk(&c).unwrap();
        assert_eq!(back.x, ds.x);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.feature_shape, ds.feature_shape);
        assert_eq!(back.num_classes, ds.num_classes);
    }

    #[test]
    fn archive_and_download_with_accounting() {
        let ds = synthetic::mnist_synth(100, 2);
        let mut rng = Rng::seed_from(1);
        let (train, test) = ds.split(0.8, &mut rng);
        let part = Partition::build(&train, 4, &Distribution::Iid, &mut rng);
        let names: Vec<String> = (0..4).map(|i| format!("node_{i}")).collect();

        let mut dist = Distributor::new();
        dist.archive_partition(&train, &part, &names, &test).unwrap();
        assert_eq!(dist.chunk_count(), 5);

        let d0 = dist.download("node_0", "train").unwrap();
        assert_eq!(d0.len(), part.assignments[0].len());
        assert!(dist.bytes_served("node_0") > 0);

        // Every node can fetch the shared test chunk.
        let t = dist.download("node_3", "test").unwrap();
        assert_eq!(t.len(), test.len());
        assert!(dist.bytes_served("node_3") > dist.bytes_served("node_1"));
    }

    #[test]
    fn missing_chunk_errors() {
        let mut dist = Distributor::new();
        assert!(dist.download("ghost", "train").is_err());
    }

    #[test]
    fn compression_helps_on_structured_data() {
        // Constant features compress massively; guards the zlib plumbing.
        let ds = Dataset {
            feature_shape: vec![100],
            x: vec![1.0; 100 * 50],
            y: vec![0; 50],
            num_classes: 10,
        };
        let c = encode_chunk(&ds).unwrap();
        assert!((c.bytes.len() as u64) < c.uncompressed_len / 10);
    }
}
