//! Client partitioners: IID, label-Dirichlet (the paper's non-iid default,
//! alpha = 0.5) and pathological label shards.

use crate::data::dataset::{Dataset, Distribution};
use crate::util::rng::Rng;

/// Result of partitioning a training set across clients.
#[derive(Clone, Debug)]
pub struct Partition {
    /// `assignments[c]` = indices of the training set owned by client `c`.
    pub assignments: Vec<Vec<usize>>,
}

impl Partition {
    pub fn build(
        ds: &Dataset,
        n_clients: usize,
        dist: &Distribution,
        rng: &mut Rng,
    ) -> Partition {
        assert!(n_clients > 0);
        let assignments = match dist {
            Distribution::Iid => iid(ds.len(), n_clients, rng),
            Distribution::Dirichlet { alpha } => dirichlet(ds, n_clients, *alpha, rng),
            Distribution::Shards { shards_per_client } => {
                shards(ds, n_clients, *shards_per_client, rng)
            }
        };
        Partition { assignments }
    }

    pub fn n_clients(&self) -> usize {
        self.assignments.len()
    }

    pub fn total_examples(&self) -> usize {
        self.assignments.iter().map(Vec::len).sum()
    }

    /// Per-client label histogram (for non-IID diagnostics / dashboards).
    pub fn label_histogram(&self, ds: &Dataset) -> Vec<Vec<usize>> {
        self.assignments
            .iter()
            .map(|idxs| {
                let mut h = vec![0usize; ds.num_classes];
                for &i in idxs {
                    h[ds.y[i] as usize] += 1;
                }
                h
            })
            .collect()
    }
}

fn iid(n: usize, n_clients: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut out = vec![Vec::new(); n_clients];
    for (i, &e) in idx.iter().enumerate() {
        out[i % n_clients].push(e);
    }
    out
}

/// Label-Dirichlet partition: for each class, split its examples across
/// clients with proportions ~ Dir(alpha). Low alpha => highly skewed.
fn dirichlet(ds: &Dataset, n_clients: usize, alpha: f64, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); n_clients];
    for mut class_idx in ds.indices_by_class() {
        rng.shuffle(&mut class_idx);
        let props = rng.dirichlet(alpha, n_clients);
        // Convert proportions to contiguous cut points.
        let n = class_idx.len();
        let mut start = 0usize;
        let mut acc = 0f64;
        for (c, &p) in props.iter().enumerate() {
            acc += p;
            let end = if c + 1 == n_clients {
                n
            } else {
                (acc * n as f64).round() as usize
            }
            .clamp(start, n);
            out[c].extend_from_slice(&class_idx[start..end]);
            start = end;
        }
    }
    // Guarantee every client trains on something (the paper's controller
    // would otherwise stall waiting for an empty client).
    rebalance_empty(&mut out, rng);
    out
}

/// Pathological shards: sort by label, cut into `n_clients * k` shards,
/// deal k shards to each client.
fn shards(ds: &Dataset, n_clients: usize, k: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    idx.sort_by_key(|&i| (ds.y[i], i));
    let n_shards = n_clients * k.max(1);
    let shard_size = ds.len().div_ceil(n_shards);
    let mut shard_ids: Vec<usize> = (0..n_shards).collect();
    rng.shuffle(&mut shard_ids);
    let mut out = vec![Vec::new(); n_clients];
    for (slot, &sid) in shard_ids.iter().enumerate() {
        let lo = (sid * shard_size).min(ds.len());
        let hi = ((sid + 1) * shard_size).min(ds.len());
        out[slot % n_clients].extend_from_slice(&idx[lo..hi]);
    }
    rebalance_empty(&mut out, rng);
    out
}

fn rebalance_empty(out: &mut [Vec<usize>], rng: &mut Rng) {
    loop {
        let Some(empty) = out.iter().position(Vec::is_empty) else {
            return;
        };
        // Steal half from the largest client.
        let (donor, _) = out
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| v.len())
            .unwrap();
        if out[donor].len() < 2 {
            // Nothing to redistribute; give up (degenerate tiny dataset).
            return;
        }
        let mut stolen = out[donor].split_off(out[donor].len() / 2);
        rng.shuffle(&mut stolen);
        out[empty] = stolen;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn check_is_partition(p: &Partition, n: usize) {
        let mut seen = vec![false; n];
        for a in &p.assignments {
            for &i in a {
                assert!(!seen[i], "index {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "not all indices assigned");
    }

    #[test]
    fn iid_balanced_partition() {
        let ds = synthetic::mnist_synth(103, 1);
        let p = Partition::build(&ds, 10, &Distribution::Iid, &mut Rng::seed_from(4));
        check_is_partition(&p, 103);
        for a in &p.assignments {
            assert!(a.len() == 10 || a.len() == 11);
        }
    }

    #[test]
    fn dirichlet_is_partition_and_skewed() {
        let ds = synthetic::mnist_synth(1000, 2);
        let p = Partition::build(
            &ds,
            10,
            &Distribution::Dirichlet { alpha: 0.5 },
            &mut Rng::seed_from(5),
        );
        check_is_partition(&p, 1000);
        // Non-IID: client label histograms should differ substantially from
        // uniform for at least some clients.
        let hist = p.label_histogram(&ds);
        let mut max_frac: f64 = 0.0;
        for h in &hist {
            let tot: usize = h.iter().sum();
            if tot == 0 {
                continue;
            }
            let mx = *h.iter().max().unwrap() as f64 / tot as f64;
            max_frac = max_frac.max(mx);
        }
        assert!(max_frac > 0.25, "alpha=0.5 should skew labels, got {max_frac}");
    }

    #[test]
    fn dirichlet_no_empty_clients() {
        let ds = synthetic::mnist_synth(200, 3);
        for seed in 0..5 {
            let p = Partition::build(
                &ds,
                20,
                &Distribution::Dirichlet { alpha: 0.1 },
                &mut Rng::seed_from(seed),
            );
            assert!(p.assignments.iter().all(|a| !a.is_empty()), "seed {seed}");
            check_is_partition(&p, 200);
        }
    }

    #[test]
    fn shards_limits_labels_per_client() {
        let ds = synthetic::mnist_synth(1000, 4);
        let p = Partition::build(
            &ds,
            10,
            &Distribution::Shards { shards_per_client: 2 },
            &mut Rng::seed_from(6),
        );
        check_is_partition(&p, 1000);
        let hist = p.label_histogram(&ds);
        for h in &hist {
            let distinct = h.iter().filter(|&&c| c > 0).count();
            assert!(distinct <= 4, "client saw {distinct} labels");
        }
    }

    #[test]
    fn deterministic_partitions() {
        let ds = synthetic::mnist_synth(300, 5);
        let d = Distribution::Dirichlet { alpha: 0.5 };
        let a = Partition::build(&ds, 7, &d, &mut Rng::seed_from(9));
        let b = Partition::build(&ds, 7, &d, &mut Rng::seed_from(9));
        assert_eq!(a.assignments, b.assignments);
    }
}
