//! Minimal benchmarking harness (criterion is not available in this
//! offline image): warmup + timed iterations with mean/σ/min/max reporting,
//! used by every `benches/*.rs` target.

use std::time::Instant;

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub stddev_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "bench {:<40} {:>10.3} ms/iter (σ {:>7.3}, min {:>8.3}, max {:>8.3}, n={})",
            self.name,
            self.mean_secs * 1e3,
            self.stddev_secs * 1e3,
            self.min_secs * 1e3,
            self.max_secs * 1e3,
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_secs: stats::mean(&samples),
        stddev_secs: stats::stddev(&samples),
        min_secs: stats::min(&samples),
        max_secs: stats::max(&samples),
    };
    println!("{}", r.report_line());
    r
}

/// Time a fallible one-shot section (used for end-to-end experiment runs
/// where a single iteration is already minutes of work).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("time  {name:<40} {secs:>10.3} s");
    (out, secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_secs > 0.0);
        assert!(r.min_secs <= r.mean_secs && r.mean_secs <= r.max_secs);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once("quick", || 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
