//! Minimal benchmarking harness (criterion is not available in this
//! offline image): warmup + timed iterations with mean/σ/min/max reporting,
//! used by every `benches/*.rs` target — plus a machine-readable
//! [`BenchSuite`] collector that emits `BENCH_micro.json` so the perf
//! trajectory is tracked per PR.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub stddev_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "bench {:<40} {:>10.3} ms/iter (σ {:>7.3}, min {:>8.3}, max {:>8.3}, n={})",
            self.name,
            self.mean_secs * 1e3,
            self.stddev_secs * 1e3,
            self.min_secs * 1e3,
            self.max_secs * 1e3,
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_secs: stats::mean(&samples),
        stddev_secs: stats::stddev(&samples),
        min_secs: stats::min(&samples),
        max_secs: stats::max(&samples),
    };
    println!("{}", r.report_line());
    r
}

/// Time a fallible one-shot section (used for end-to-end experiment runs
/// where a single iteration is already minutes of work).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("time  {name:<40} {secs:>10.3} s");
    (out, secs)
}

/// One entry of a machine-readable benchmark artifact.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub name: String,
    /// Nanoseconds per operation (mean).
    pub ns_per_op: f64,
    pub iters: usize,
}

/// A throughput measurement (e.g. FL rounds per second at a parallelism
/// level).
#[derive(Clone, Debug)]
pub struct ThroughputRecord {
    pub name: String,
    pub ops_per_sec: f64,
}

/// A virtual-clock makespan measurement (`sim_round_secs` summed over a
/// job's rounds): what the run takes on the *simulated* deployment, which
/// is invariant to host speed and worker count.
#[derive(Clone, Debug)]
pub struct MakespanRecord {
    pub name: String,
    pub sim_round_secs: f64,
}

/// A peak-memory measurement (`VmHWM` sampled at a fixed point in the bench
/// sequence, or a scoped `VmRSS` delta). Bytes; higher is worse.
#[derive(Clone, Debug)]
pub struct MemoryRecord {
    pub name: String,
    pub mem_peak_bytes: u64,
}

/// Collects bench results and serializes them as a stable JSON artifact
/// (`BENCH_micro.json`) for per-PR perf tracking.
#[derive(Clone, Debug, Default)]
pub struct BenchSuite {
    pub results: Vec<BenchRecord>,
    pub throughput: Vec<ThroughputRecord>,
    pub makespan: Vec<MakespanRecord>,
    pub memory: Vec<MemoryRecord>,
}

impl BenchSuite {
    pub fn new() -> BenchSuite {
        BenchSuite::default()
    }

    /// Record a timed bench result.
    pub fn push(&mut self, r: &BenchResult) {
        self.results.push(BenchRecord {
            name: r.name.clone(),
            ns_per_op: r.mean_secs * 1e9,
            iters: r.iters,
        });
    }

    /// Record a throughput number (ops — e.g. rounds — per second).
    pub fn push_throughput(&mut self, name: &str, ops_per_sec: f64) {
        self.throughput.push(ThroughputRecord {
            name: name.to_string(),
            ops_per_sec,
        });
    }

    /// Record a virtual-clock makespan (summed `sim_round_secs` of a run).
    pub fn push_makespan(&mut self, name: &str, sim_round_secs: f64) {
        self.makespan.push(MakespanRecord {
            name: name.to_string(),
            sim_round_secs,
        });
    }

    /// Record a peak-memory measurement in bytes (higher = worse; the
    /// regression gate inverts its tolerance accordingly).
    pub fn push_memory(&mut self, name: &str, mem_peak_bytes: u64) {
        self.memory.push(MemoryRecord {
            name: name.to_string(),
            mem_peak_bytes,
        });
    }

    /// Serialize through [`crate::util::json::Json`] (escaping included).
    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::from(r.name.as_str())),
                    ("ns_per_op", Json::from((r.ns_per_op * 10.0).round() / 10.0)),
                    ("iters", Json::from(r.iters)),
                ])
            })
            .collect();
        let throughput: Vec<Json> = self
            .throughput
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("name", Json::from(t.name.as_str())),
                    ("ops_per_sec", Json::from((t.ops_per_sec * 1e4).round() / 1e4)),
                ])
            })
            .collect();
        let makespan: Vec<Json> = self
            .makespan
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("name", Json::from(m.name.as_str())),
                    (
                        "sim_round_secs",
                        Json::from((m.sim_round_secs * 1e4).round() / 1e4),
                    ),
                ])
            })
            .collect();
        let memory: Vec<Json> = self
            .memory
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("name", Json::from(m.name.as_str())),
                    ("mem_peak_bytes", Json::from(m.mem_peak_bytes as usize)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("schema", Json::from("flsim-bench-v1")),
            ("results", Json::Arr(results)),
            ("throughput", Json::Arr(throughput)),
            ("makespan", Json::Arr(makespan)),
            ("memory", Json::Arr(memory)),
        ]);
        format!("{doc}\n")
    }

    /// Write the artifact to `path`.
    pub fn write(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json()).with_context(|| format!("writing {path}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_secs > 0.0);
        assert!(r.min_secs <= r.mean_secs && r.mean_secs <= r.max_secs);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once("quick", || 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn suite_emits_valid_machine_readable_json() {
        let mut suite = BenchSuite::new();
        suite.push(&BenchResult {
            name: "agg/\"q\"".into(),
            iters: 5,
            mean_secs: 1.5e-6,
            stddev_secs: 0.0,
            min_secs: 1e-6,
            max_secs: 2e-6,
        });
        suite.push_throughput("round/parallelism=4", 12.5);
        suite.push_makespan("topology/client_server", 3.14159);
        suite.push_memory("scale/n=100000", 123_456_789);
        let j = suite.to_json();
        // Parses with the in-repo JSON parser and carries the values.
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(
            parsed.get("schema").and_then(crate::util::json::Json::as_str),
            Some("flsim-bench-v1")
        );
        let results = parsed
            .get("results")
            .and_then(crate::util::json::Json::as_arr)
            .unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(
            results[0].get("ns_per_op").and_then(crate::util::json::Json::as_f64),
            Some(1500.0)
        );
        let tp = parsed
            .get("throughput")
            .and_then(crate::util::json::Json::as_arr)
            .unwrap();
        assert_eq!(tp[0].get("ops_per_sec").and_then(crate::util::json::Json::as_f64), Some(12.5));
        let ms = parsed
            .get("makespan")
            .and_then(crate::util::json::Json::as_arr)
            .unwrap();
        assert_eq!(
            ms[0].get("name").and_then(crate::util::json::Json::as_str),
            Some("topology/client_server")
        );
        assert_eq!(
            ms[0].get("sim_round_secs").and_then(crate::util::json::Json::as_f64),
            Some(3.1416)
        );
        let mem = parsed
            .get("memory")
            .and_then(crate::util::json::Json::as_arr)
            .unwrap();
        assert_eq!(
            mem[0].get("name").and_then(crate::util::json::Json::as_str),
            Some("scale/n=100000")
        );
        assert_eq!(
            mem[0].get("mem_peak_bytes").and_then(crate::util::json::Json::as_f64),
            Some(123_456_789.0)
        );
    }
}
