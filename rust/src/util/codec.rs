//! Chunk compression for the dataset distributor.
//!
//! The offline image carries no `flate2`, so the distributor uses this
//! self-contained 32-bit-word run-length codec instead. It is tuned for the
//! chunk wire format (LE f32/i32 words): constant or zero-heavy tensors
//! collapse to a few bytes, while incompressible float data pays < 1% of
//! framing overhead. Deterministic by construction (no dictionaries, no
//! heuristics), which keeps chunk ids content-addressed and reproducible.
//!
//! Format:
//! ```text
//! [orig_len: u32 LE]
//! tokens*:
//!   0b1xxxxxxx  -> run: the next 4-byte word repeats (x+1) times (1..=127)
//!   0b0xxxxxxx  -> literal: the next (x+1) words (1..=127) copied verbatim
//! [remainder: orig_len % 4 raw bytes]
//! ```

use anyhow::{bail, Result};

const MAX_RUN: usize = 127;

/// Word `i` of `data` as a byte slice (scans in place — no staging copy of
/// the input, which matters for multi-megabyte dataset chunks).
#[inline]
fn word(data: &[u8], i: usize) -> &[u8] {
    &data[i * 4..i * 4 + 4]
}

/// Compress `data`; always succeeds, output is at most ~1% larger than the
/// input on incompressible bytes.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let n_words = data.len() / 4;
    let mut out = Vec::with_capacity(8 + data.len() + data.len() / (4 * MAX_RUN) + 8);
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());

    let mut i = 0usize;
    while i < n_words {
        // Measure the run starting at i.
        let mut run = 1usize;
        while run < MAX_RUN && i + run < n_words && word(data, i + run) == word(data, i) {
            run += 1;
        }
        if run >= 2 {
            out.push(0x80 | (run - 1) as u8);
            out.extend_from_slice(word(data, i));
            i += run;
        } else {
            // Literal stretch: scan ahead until the next run of >= 3 equal
            // words (2 is break-even) or the cap.
            let start = i;
            let mut j = i + 1;
            while j < n_words && j - start < MAX_RUN {
                if j + 2 < n_words
                    && word(data, j) == word(data, j + 1)
                    && word(data, j) == word(data, j + 2)
                {
                    break;
                }
                j += 1;
            }
            out.push((j - start - 1) as u8);
            out.extend_from_slice(&data[start * 4..j * 4]);
            i = j;
        }
    }
    out.extend_from_slice(&data[n_words * 4..]);
    out
}

/// Decompress a [`compress`] buffer.
pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() < 4 {
        bail!("codec: truncated header");
    }
    let orig_len = u32::from_le_bytes(data[..4].try_into().unwrap()) as usize;
    let n_words = orig_len / 4;
    let tail = orig_len % 4;
    let mut out = Vec::with_capacity(orig_len);
    let mut pos = 4usize;
    while out.len() < n_words * 4 {
        let Some(&ctrl) = data.get(pos) else {
            bail!("codec: truncated token stream");
        };
        pos += 1;
        let count = ((ctrl & 0x7F) as usize) + 1;
        if ctrl & 0x80 != 0 {
            if pos + 4 > data.len() {
                bail!("codec: truncated run word");
            }
            let w = &data[pos..pos + 4];
            pos += 4;
            for _ in 0..count {
                out.extend_from_slice(w);
            }
        } else {
            let need = count * 4;
            if pos + need > data.len() {
                bail!("codec: truncated literal words");
            }
            out.extend_from_slice(&data[pos..pos + need]);
            pos += need;
        }
    }
    if out.len() != n_words * 4 {
        bail!("codec: token stream overran {} words", n_words);
    }
    if pos + tail != data.len() {
        bail!("codec: trailing-byte mismatch");
    }
    out.extend_from_slice(&data[pos..]);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8]) {
        let c = compress(data);
        let d = decompress(&c).unwrap();
        assert_eq!(d, data, "roundtrip failed for len {}", data.len());
    }

    #[test]
    fn roundtrips() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"abc");
        roundtrip(b"abcd");
        roundtrip(b"abcde");
        roundtrip(&[0u8; 1000]);
        let mut rng = Rng::seed_from(1);
        for len in [3usize, 4, 7, 64, 257, 4096, 10_001] {
            let data: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            roundtrip(&data);
        }
        // Mixed runs and literals.
        let mut mixed = Vec::new();
        for i in 0..2000u32 {
            if i % 7 == 0 {
                mixed.extend_from_slice(&[0u8; 4]);
            } else {
                mixed.extend_from_slice(&i.to_le_bytes());
            }
        }
        roundtrip(&mixed);
    }

    #[test]
    fn constant_data_compresses_hard() {
        let data = vec![0x3Fu8; 20_000];
        let c = compress(&data);
        assert!(c.len() * 10 < data.len(), "{} vs {}", c.len(), data.len());
        roundtrip(&data);
    }

    #[test]
    fn incompressible_overhead_is_small() {
        let mut rng = Rng::seed_from(2);
        let data: Vec<u8> = (0..100_000).map(|_| rng.next_u64() as u8).collect();
        let c = compress(&data);
        assert!(c.len() < data.len() + data.len() / 50 + 16);
        roundtrip(&data);
    }

    #[test]
    fn corrupt_input_errors_not_panics() {
        assert!(decompress(&[]).is_err());
        assert!(decompress(&[1, 2]).is_err());
        // Claims 8 words but provides none.
        assert!(decompress(&32u32.to_le_bytes()).is_err());
        let mut c = compress(&[7u8; 64]);
        c.truncate(c.len() - 1);
        assert!(decompress(&c).is_err());
    }

    #[test]
    fn deterministic_output() {
        let data: Vec<u8> = (0..999u32).flat_map(|i| (i % 50).to_le_bytes()).collect();
        assert_eq!(compress(&data), compress(&data));
    }
}
