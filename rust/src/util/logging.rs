//! Leveled logger (the offline image carries no `log`/`env_logger` pair
//! wired for binaries, so FLsim ships its own minimal logger).
//!
//! Controlled by `FLSIM_LOG` = `error|warn|info|debug|trace` (default
//! `info`). The orchestrator and logic controller emit the paper's
//! Algorithm-1 "emit" lines at `info`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

/// Initialize from `FLSIM_LOG` (idempotent; called by binaries).
pub fn init_from_env() {
    let lvl = std::env::var("FLSIM_LOG").unwrap_or_default();
    set_level(match lvl.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    });
    let _ = start();
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, target: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut out = std::io::stderr().lock();
    let _ = writeln!(out, "[{t:9.3}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnlog {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debuglog {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target,
                                   &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
