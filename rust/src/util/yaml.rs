//! YAML-subset parser for FLsim job configurations (paper Fig 2).
//!
//! Supports the features the job-config schema uses: nested block mappings,
//! block sequences, inline scalars (str/int/float/bool/null), quoted strings,
//! comments, anchors (`&name`), aliases (`*name`) and merge keys (`<<:`) —
//! the exact constructs in the paper's Figure 2 examples. Flow collections
//! (`[a, b]` / `{a: b}`) are supported one level deep for convenience.
//!
//! Not a general YAML 1.2 implementation (no multi-docs, block scalars,
//! tags, or complex keys) — the config layer validates against the schema
//! anyway, and a hand-rolled subset keeps the offline build dependency-free.

use std::collections::BTreeMap;
use std::collections::HashMap;

pub type Map = BTreeMap<String, Yaml>;

#[derive(Clone, Debug, PartialEq)]
pub enum Yaml {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Seq(Vec<Yaml>),
    Map(Map),
}

#[derive(Debug)]
pub struct YamlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for YamlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "yaml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for YamlError {}

impl Yaml {
    pub fn parse(src: &str) -> Result<Yaml, YamlError> {
        let lines = preprocess(src);
        let mut anchors = HashMap::new();
        let mut pos = 0;
        if lines.is_empty() {
            return Ok(Yaml::Null);
        }
        let v = parse_block(&lines, &mut pos, lines[0].indent, &mut anchors)?;
        if pos != lines.len() {
            return Err(YamlError {
                line: lines[pos].number,
                msg: "unexpected trailing content (bad indentation?)".into(),
            });
        }
        Ok(v)
    }

    // -- accessors ----------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Yaml> {
        match self {
            Yaml::Map(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Yaml::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Yaml::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Yaml::Float(f) => Some(*f),
            Yaml::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Yaml::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_seq(&self) -> Option<&[Yaml]> {
        match self {
            Yaml::Seq(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&Map> {
        match self {
            Yaml::Map(m) => Some(m),
            _ => None,
        }
    }
}

impl From<&str> for Yaml {
    fn from(s: &str) -> Yaml {
        Yaml::Str(s.to_string())
    }
}
impl From<String> for Yaml {
    fn from(s: String) -> Yaml {
        Yaml::Str(s)
    }
}
impl From<i64> for Yaml {
    fn from(i: i64) -> Yaml {
        Yaml::Int(i)
    }
}
impl From<f64> for Yaml {
    fn from(f: f64) -> Yaml {
        Yaml::Float(f)
    }
}
impl From<bool> for Yaml {
    fn from(b: bool) -> Yaml {
        Yaml::Bool(b)
    }
}

struct Line {
    indent: usize,
    text: String,
    number: usize,
}

/// Strip comments/blank lines, compute indents.
fn preprocess(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    for (i, raw) in src.lines().enumerate() {
        let mut text = String::new();
        let mut in_single = false;
        let mut in_double = false;
        for c in raw.chars() {
            match c {
                '\'' if !in_double => in_single = !in_single,
                '"' if !in_single => in_double = !in_double,
                '#' if !in_single && !in_double => break,
                _ => {}
            }
            text.push(c);
        }
        let trimmed = text.trim_end();
        if trimmed.trim().is_empty() {
            continue;
        }
        let indent = trimmed.len() - trimmed.trim_start().len();
        out.push(Line {
            indent,
            text: trimmed.trim_start().to_string(),
            number: i + 1,
        });
    }
    out
}

fn parse_block(
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
    anchors: &mut HashMap<String, Yaml>,
) -> Result<Yaml, YamlError> {
    if *pos >= lines.len() {
        return Ok(Yaml::Null);
    }
    if lines[*pos].text.starts_with("- ") || lines[*pos].text == "-" {
        parse_seq(lines, pos, indent, anchors)
    } else {
        parse_map(lines, pos, indent, anchors)
    }
}

fn parse_seq(
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
    anchors: &mut HashMap<String, Yaml>,
) -> Result<Yaml, YamlError> {
    let mut items = Vec::new();
    while *pos < lines.len() && lines[*pos].indent == indent {
        let line = &lines[*pos];
        if !(line.text.starts_with("- ") || line.text == "-") {
            break;
        }
        let rest = line.text[1..].trim_start().to_string();
        if rest.is_empty() {
            // Nested block under the dash.
            *pos += 1;
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent, anchors)?);
            } else {
                items.push(Yaml::Null);
            }
        } else if rest.contains(": ") || rest.ends_with(':') {
            // Inline map start: "- key: value" — treat the remainder plus any
            // deeper lines as a map indented at dash+2.
            let item_indent = indent + 2;
            let synthetic = Line {
                indent: item_indent,
                text: rest,
                number: line.number,
            };
            *pos += 1; // consume the dash line itself
            // Parse the first key from the synthetic line, then continue.
            let mut map = Map::new();
            parse_map_entry(&synthetic, lines, pos, item_indent, anchors, &mut map, true)?;
            while *pos < lines.len() && lines[*pos].indent == item_indent {
                let l = &lines[*pos];
                if l.text.starts_with("- ") {
                    break;
                }
                let l = Line {
                    indent: l.indent,
                    text: l.text.clone(),
                    number: l.number,
                };
                parse_map_entry(&l, lines, pos, item_indent, anchors, &mut map, false)?;
            }
            items.push(Yaml::Map(map));
        } else {
            *pos += 1;
            items.push(parse_scalar_or_alias(&rest, line.number, anchors)?);
        }
    }
    Ok(Yaml::Seq(items))
}

fn parse_map(
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
    anchors: &mut HashMap<String, Yaml>,
) -> Result<Yaml, YamlError> {
    let mut map = Map::new();
    while *pos < lines.len() && lines[*pos].indent == indent {
        let line = Line {
            indent: lines[*pos].indent,
            text: lines[*pos].text.clone(),
            number: lines[*pos].number,
        };
        if line.text.starts_with("- ") {
            break;
        }
        parse_map_entry(&line, lines, pos, indent, anchors, &mut map, false)?;
    }
    if map.is_empty() {
        return Err(YamlError {
            line: lines.get(*pos).map(|l| l.number).unwrap_or(0),
            msg: "expected mapping".into(),
        });
    }
    Ok(Yaml::Map(map))
}

/// Parse one `key: ...` entry. If `synthetic` the key line was already
/// consumed (inline seq-item map), otherwise advances past the current line.
fn parse_map_entry(
    line: &Line,
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
    anchors: &mut HashMap<String, Yaml>,
    map: &mut Map,
    synthetic: bool,
) -> Result<(), YamlError> {
    let (key_part, value_part) = split_key(&line.text).ok_or(YamlError {
        line: line.number,
        msg: format!("expected 'key: value', got {:?}", line.text),
    })?;
    if !synthetic {
        *pos += 1;
    }
    let key = unquote(key_part.trim());
    let rest = value_part.trim();

    // Anchor on the value: `key: &name value` / `key: &name` + nested block.
    let (anchor, rest) = take_anchor(rest);

    let value = if rest.is_empty() {
        // Nested block (or null).
        if *pos < lines.len() && lines[*pos].indent > indent {
            let child_indent = lines[*pos].indent;
            parse_block(lines, pos, child_indent, anchors)?
        } else {
            Yaml::Null
        }
    } else {
        parse_scalar_or_alias(rest, line.number, anchors)?
    };

    if let Some(name) = anchor {
        anchors.insert(name, value.clone());
    }

    if key == "<<" {
        // Merge key: fold the aliased map's entries in (existing keys win,
        // per the YAML merge-key spec).
        if let Yaml::Map(src) = value {
            for (k, v) in src {
                map.entry(k).or_insert(v);
            }
        } else {
            return Err(YamlError {
                line: line.number,
                msg: "'<<' merge value must be a mapping".into(),
            });
        }
    } else {
        map.insert(key, value);
    }
    Ok(())
}

fn take_anchor(s: &str) -> (Option<String>, &str) {
    if let Some(rest) = s.strip_prefix('&') {
        let end = rest.find(char::is_whitespace).unwrap_or(rest.len());
        let name = rest[..end].to_string();
        (Some(name), rest[end..].trim_start())
    } else {
        (None, s)
    }
}

/// Split "key: value" at the first unquoted ": " (or trailing ':').
fn split_key(text: &str) -> Option<(&str, &str)> {
    let mut in_single = false;
    let mut in_double = false;
    let bytes = text.as_bytes();
    for i in 0..bytes.len() {
        match bytes[i] {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b':' if !in_single && !in_double => {
                if i + 1 == bytes.len() {
                    return Some((&text[..i], ""));
                }
                if bytes[i + 1] == b' ' {
                    return Some((&text[..i], &text[i + 2..]));
                }
            }
            _ => {}
        }
    }
    None
}

fn unquote(s: &str) -> String {
    let s = s.trim();
    if (s.starts_with('"') && s.ends_with('"') && s.len() >= 2)
        || (s.starts_with('\'') && s.ends_with('\'') && s.len() >= 2)
    {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}

fn parse_scalar_or_alias(
    s: &str,
    line: usize,
    anchors: &HashMap<String, Yaml>,
) -> Result<Yaml, YamlError> {
    let s = s.trim();
    if let Some(name) = s.strip_prefix('*') {
        return anchors.get(name).cloned().ok_or(YamlError {
            line,
            msg: format!("unknown alias '*{name}'"),
        });
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        if inner.trim().is_empty() {
            return Ok(Yaml::Seq(vec![]));
        }
        let items = inner
            .split(',')
            .map(|it| parse_scalar_or_alias(it, line, anchors))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Yaml::Seq(items));
    }
    if s.starts_with('{') && s.ends_with('}') {
        let inner = &s[1..s.len() - 1];
        let mut m = Map::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                let (k, v) = split_key(part.trim()).ok_or(YamlError {
                    line,
                    msg: format!("bad flow map entry {part:?}"),
                })?;
                m.insert(unquote(k), parse_scalar_or_alias(v, line, anchors)?);
            }
        }
        return Ok(Yaml::Map(m));
    }
    Ok(scalar(s))
}

fn scalar(s: &str) -> Yaml {
    match s {
        "null" | "~" | "Null" | "NULL" => return Yaml::Null,
        "true" | "True" | "TRUE" => return Yaml::Bool(true),
        "false" | "False" | "FALSE" => return Yaml::Bool(false),
        _ => {}
    }
    if s.starts_with('"') || s.starts_with('\'') {
        return Yaml::Str(unquote(s));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Yaml::Int(i);
    }
    if let Ok(f) = s.parse::<f64>() {
        return Yaml::Float(f);
    }
    Yaml::Str(s.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_maps_and_scalars() {
        let y = Yaml::parse(
            "dataset:\n  name: cifar\n  alpha: 0.5\n  n: 5000\n  iid: false\n",
        )
        .unwrap();
        let d = y.get("dataset").unwrap();
        assert_eq!(d.get("name").unwrap().as_str(), Some("cifar"));
        assert_eq!(d.get("alpha").unwrap().as_f64(), Some(0.5));
        assert_eq!(d.get("n").unwrap().as_i64(), Some(5000));
        assert_eq!(d.get("iid").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn sequences() {
        let y = Yaml::parse("clients:\n  - node_0\n  - node_1\nworkers:\n  - w0\n").unwrap();
        let c = y.get("clients").unwrap().as_seq().unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c[1].as_str(), Some("node_1"));
    }

    #[test]
    fn seq_of_maps() {
        let y = Yaml::parse("nodes:\n  - id: a\n    role: client\n  - id: b\n    role: worker\n")
            .unwrap();
        let n = y.get("nodes").unwrap().as_seq().unwrap();
        assert_eq!(n[0].get("role").unwrap().as_str(), Some("client"));
        assert_eq!(n[1].get("id").unwrap().as_str(), Some("b"));
    }

    #[test]
    fn anchors_aliases_and_merge() {
        // The paper's Fig 2 idiom: defaults anchored then merged with `<<:`.
        let src = "\
defaults:\n  train: &train_defaults\n    lr: 0.001\n    batch_size: 64\nnode_0:\n  <<: *train_defaults\n  lr: 0.1\nnode_1:\n  <<: *train_defaults\n";
        let y = Yaml::parse(src).unwrap();
        // node_0 overrides lr, inherits batch_size.
        assert_eq!(y.get("node_0").unwrap().get("lr").unwrap().as_f64(), Some(0.1));
        assert_eq!(
            y.get("node_0").unwrap().get("batch_size").unwrap().as_i64(),
            Some(64)
        );
        assert_eq!(
            y.get("node_1").unwrap().get("lr").unwrap().as_f64(),
            Some(0.001)
        );
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let y = Yaml::parse("# header\na: 1\n\n  # indented comment\nb: 2 # trailing\n").unwrap();
        assert_eq!(y.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(y.get("b").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn flow_collections() {
        let y = Yaml::parse("dims: [1, 2, 3]\nopts: {lr: 0.1, m: test}\n").unwrap();
        assert_eq!(y.get("dims").unwrap().as_seq().unwrap().len(), 3);
        assert_eq!(
            y.get("opts").unwrap().get("m").unwrap().as_str(),
            Some("test")
        );
    }

    #[test]
    fn quoted_strings_preserve_specials() {
        let y = Yaml::parse("a: \"x: y # not comment\"\n").unwrap();
        assert_eq!(y.get("a").unwrap().as_str(), Some("x: y # not comment"));
    }

    #[test]
    fn null_values() {
        let y = Yaml::parse("a: null\nb:\nc: 1\n").unwrap();
        assert_eq!(y.get("a"), Some(&Yaml::Null));
        assert_eq!(y.get("b"), Some(&Yaml::Null));
    }

    #[test]
    fn unknown_alias_errors() {
        assert!(Yaml::parse("a: *nope\n").is_err());
    }

    #[test]
    fn deep_nesting() {
        let y = Yaml::parse("a:\n  b:\n    c:\n      d: 4\n").unwrap();
        assert_eq!(
            y.get("a").unwrap().get("b").unwrap().get("c").unwrap()
                .get("d").unwrap().as_i64(),
            Some(4)
        );
    }
}
