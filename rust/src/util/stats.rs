//! Small statistics helpers for the metrics logger and bench harness.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// L2 norm of an f32 vector (accumulated in f64).
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// L2 distance between two equal-length vectors.
pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Cosine similarity between two vectors (0 when either is all-zero).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0f64;
    let mut na = 0f64;
    let mut nb = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((l2_dist(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_cases() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0], &[1.0]), 0.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
