//! Hashing helpers: SHA-256 digests of model parameters.
//!
//! Parameter hashes drive the multi-worker consensus (workers vote on the
//! hash of their aggregated model, §2.5 phase 2) and the blockchain
//! contracts (parameter verification / provenance).
//!
//! SHA-256 (FIPS 180-4) is implemented here directly: the offline image has
//! no crates.io registry, so the `sha2` crate is not available.

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Incremental SHA-256 state (same `update`/`finalize` shape as `sha2`).
pub struct Sha256 {
    h: [u32; 8],
    /// Partial block buffer.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes.
    len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 {
            h: [
                0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c,
                0x1f83d9ab, 0x5be0cd19,
            ],
            buf: [0u8; 64],
            buf_len: 0,
            len: 0,
        }
    }

    pub fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.len += data.len() as u64;
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let (block, rest) = data.split_at(64);
            self.compress(block.try_into().unwrap());
            data = rest;
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.len.wrapping_mul(8);
        self.update([0x80u8]);
        while self.buf_len != 56 {
            self.update([0u8]);
        }
        // The 8 length bytes complete the final 64-byte block exactly.
        self.update(bit_len.to_be_bytes());
        debug_assert_eq!(self.buf_len, 0);
        let mut out = [0u8; 32];
        for (i, word) in self.h.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes(chunk.try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.h[0] = self.h[0].wrapping_add(a);
        self.h[1] = self.h[1].wrapping_add(b);
        self.h[2] = self.h[2].wrapping_add(c);
        self.h[3] = self.h[3].wrapping_add(d);
        self.h[4] = self.h[4].wrapping_add(e);
        self.h[5] = self.h[5].wrapping_add(f);
        self.h[6] = self.h[6].wrapping_add(g);
        self.h[7] = self.h[7].wrapping_add(h);
    }
}

/// SHA-256 of raw bytes, hex-encoded.
pub fn sha256_hex(bytes: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(bytes);
    hex(&h.finalize())
}

/// SHA-256 digest of an f32 parameter vector (over its IEEE-754 LE bytes,
/// so bitwise-identical models — and only those — collide).
pub fn hash_params(params: &[f32]) -> String {
    let mut h = Sha256::new();
    let mut buf = Vec::with_capacity(4096 * 4);
    for chunk in params.chunks(4096) {
        buf.clear();
        for v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        h.update(&buf);
    }
    hex(&h.finalize())
}

/// Short (16-hex-char) parameter hash for logs and chain txs.
pub fn short_hash(params: &[f32]) -> String {
    hash_params(params)[..16].to_string()
}

pub fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_known_vector() {
        // sha256("abc")
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn sha256_empty_and_streamed_vectors() {
        // sha256("")
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        // sha256("abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // One million 'a's (FIPS 180-4 test vector), streamed in odd chunks
        // to exercise the partial-block buffer.
        let mut h = Sha256::new();
        let chunk = [b'a'; 997];
        let mut fed = 0usize;
        while fed < 1_000_000 {
            let take = (1_000_000 - fed).min(chunk.len());
            h.update(&chunk[..take]);
            fed += take;
        }
        assert_eq!(
            hex(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn param_hash_sensitive_to_any_element() {
        let a = vec![1.0f32, 2.0, 3.0];
        let mut b = a.clone();
        b[2] = 3.0000002;
        assert_eq!(hash_params(&a), hash_params(&a));
        assert_ne!(hash_params(&a), hash_params(&b));
    }

    #[test]
    fn param_hash_distinguishes_nan_payloads_consistently() {
        let a = vec![f32::NAN];
        assert_eq!(hash_params(&a), hash_params(&a));
    }

    #[test]
    fn short_hash_is_prefix() {
        let p = vec![0.5f32; 10];
        assert!(hash_params(&p).starts_with(&short_hash(&p)));
    }
}
