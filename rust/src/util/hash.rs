//! Hashing helpers: SHA-256 digests of model parameters.
//!
//! Parameter hashes drive the multi-worker consensus (workers vote on the
//! hash of their aggregated model, §2.5 phase 2) and the blockchain
//! contracts (parameter verification / provenance).

use sha2::{Digest, Sha256};

/// SHA-256 of raw bytes, hex-encoded.
pub fn sha256_hex(bytes: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(bytes);
    hex(&h.finalize())
}

/// SHA-256 digest of an f32 parameter vector (over its IEEE-754 LE bytes,
/// so bitwise-identical models — and only those — collide).
pub fn hash_params(params: &[f32]) -> String {
    let mut h = Sha256::new();
    for chunk in params.chunks(4096) {
        // SAFETY-free path: serialize to LE bytes explicitly.
        let mut buf = Vec::with_capacity(chunk.len() * 4);
        for v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        h.update(&buf);
    }
    hex(&h.finalize())
}

/// Short (16-hex-char) parameter hash for logs and chain txs.
pub fn short_hash(params: &[f32]) -> String {
    hash_params(params)[..16].to_string()
}

pub fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_known_vector() {
        // sha256("abc")
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn param_hash_sensitive_to_any_element() {
        let a = vec![1.0f32, 2.0, 3.0];
        let mut b = a.clone();
        b[2] = 3.0000002;
        assert_eq!(hash_params(&a), hash_params(&a));
        assert_ne!(hash_params(&a), hash_params(&b));
    }

    #[test]
    fn param_hash_distinguishes_nan_payloads_consistently() {
        let a = vec![f32::NAN];
        assert_eq!(hash_params(&a), hash_params(&a));
    }

    #[test]
    fn short_hash_is_prefix() {
        let p = vec![0.5f32; 10];
        assert!(hash_params(&p).starts_with(&short_hash(&p)));
    }
}
