//! Support substrates built from scratch for the offline environment:
//! deterministic RNG, JSON/YAML parsing, hashing, statistics, logging.

pub mod codec;
pub mod hash;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod yaml;
