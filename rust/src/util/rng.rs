//! Deterministic random-number generation (the paper's "node seed
//! synchronization", §5 / RQ6).
//!
//! A single root seed deterministically derives every stream in the system
//! (per-node, per-round, per-purpose) via SplitMix64 stream splitting, so a
//! run is reproducible bit-for-bit regardless of scheduling. The core
//! generator is xoshiro256++ seeded through SplitMix64, both implemented
//! here because the offline image carries no `rand` crate.

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ deterministic PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from the Box-Muller pair.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream; `purpose` keeps streams for
    /// different uses (init / batching / noise / consensus) disjoint even
    /// for the same node id.
    pub fn derive(&self, purpose: &str, index: u64) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in purpose.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let mut sm = self.s[0] ^ h.rotate_left(17) ^ index.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::seed_from(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection to avoid modulo bias.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang (shape > 0).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.next_f64().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k) sample — drives the paper's non-iid label
    /// partitioning (Dirichlet distribution algorithm, alpha = 0.5).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let sum: f64 = g.iter().sum();
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from [0, len).
    pub fn choose_indices(&mut self, len: usize, n: usize) -> Vec<usize> {
        assert!(n <= len);
        let mut idx: Vec<usize> = (0..len).collect();
        self.shuffle(&mut idx);
        idx.truncate(n);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn derive_is_stable_and_disjoint() {
        let root = Rng::seed_from(7);
        let mut c1 = root.derive("init", 3);
        let mut c2 = root.derive("init", 3);
        let mut c3 = root.derive("noise", 3);
        let mut c4 = root.derive("init", 4);
        let v = c1.next_u64();
        assert_eq!(v, c2.next_u64());
        assert_ne!(v, c3.next_u64());
        assert_ne!(v, c4.next_u64());
    }

    #[test]
    fn below_is_unbiased_range() {
        let mut r = Rng::seed_from(3);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!(c > 800 && c < 1200, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::seed_from(9);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 10);
            assert_eq!(p.len(), 10);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_low_alpha_is_skewed() {
        let mut r = Rng::seed_from(11);
        let lo = r.dirichlet(0.1, 10);
        let hi = r.dirichlet(100.0, 10);
        let max_lo = lo.iter().cloned().fold(0.0, f64::max);
        let max_hi = hi.iter().cloned().fold(0.0, f64::max);
        assert!(max_lo > max_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn gamma_positive() {
        let mut r = Rng::seed_from(17);
        for &a in &[0.3, 0.5, 1.0, 2.5] {
            for _ in 0..100 {
                assert!(r.gamma(a) > 0.0);
            }
        }
    }
}
