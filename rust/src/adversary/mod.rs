//! Adversarial scenario runtime: turns the declarative `adversary:` /
//! `faults:` config sections into concrete per-run state — the compromised
//! node set and the materialized [`FaultPlan`] (explicit schedules plus
//! seed-derived churn draws).
//!
//! Everything here is deterministic in the job seed: attacker assignment
//! draws from `root.derive("adversary", 0)` and each node's churn stream is
//! `seed.derive("churn", name_index(node))`, so a scenario replays
//! bit-for-bit at any parallelism. Inactive configs touch no RNG stream at
//! all (the zero-adversary identity contract).

use std::collections::BTreeSet;

use anyhow::{bail, Result};

use crate::config::adversary::AdversaryConfig;
use crate::config::job::JobConfig;
use crate::controller::sync::{ChurnSpec, FaultPlan};
use crate::orchestrator::name_index;
use crate::orchestrator::population::Population;
use crate::util::rng::Rng;

/// Resolve which clients are compromised: the explicit `nodes` list unioned
/// with a seed-derived draw of `attack_fraction · n` clients. Inactive
/// configs return an empty set without touching any RNG stream.
pub fn select_adversaries(
    adv: &AdversaryConfig,
    root: &Rng,
    client_names: &[String],
) -> Result<BTreeSet<String>> {
    let mut out = BTreeSet::new();
    if !adv.is_active() {
        return Ok(out);
    }
    for n in &adv.nodes {
        if !client_names.iter().any(|c| c == n) {
            bail!(
                "adversary node '{n}' is not in the client fleet ({} clients)",
                client_names.len()
            );
        }
        out.insert(n.clone());
    }
    if adv.attack_fraction > 0.0 {
        let n = client_names.len();
        let k = ((adv.attack_fraction * n as f64).round() as usize).min(n);
        if k > 0 {
            let mut rng = root.derive("adversary", 0);
            for i in rng.choose_indices(n, k) {
                out.insert(client_names[i].clone());
            }
        }
    }
    Ok(out)
}

/// Index-based variant of [`select_adversaries`] for virtual populations:
/// the RNG stream and selection are **identical** (the eager fleet's
/// `client_names` list is sorted, and rank-order iteration over the
/// [`Population`] yields exactly that list), but no fleet-wide name vector
/// is ever allocated — only the `k` chosen names materialize.
pub fn select_adversaries_virtual(
    adv: &AdversaryConfig,
    root: &Rng,
    pop: &Population,
) -> Result<BTreeSet<String>> {
    let mut out = BTreeSet::new();
    if !adv.is_active() {
        return Ok(out);
    }
    for n in &adv.nodes {
        if pop.rank_of_name(n).is_none() {
            bail!(
                "adversary node '{n}' is not in the client fleet ({} clients)",
                pop.len()
            );
        }
        out.insert(n.clone());
    }
    if adv.attack_fraction > 0.0 {
        let n = pop.len();
        let k = ((adv.attack_fraction * n as f64).round() as usize).min(n);
        if k > 0 {
            let mut rng = root.derive("adversary", 0);
            for i in rng.choose_indices(n, k) {
                out.insert(pop.name_at_rank(i));
            }
        }
    }
    Ok(out)
}

/// Materialize the `faults:` section into a [`FaultPlan`]: explicit
/// drop/crash events verbatim, plus — when churn is active — one
/// seed-derived availability draw per (client, round), any failed draw
/// becoming a single-round drop. Per-node streams keyed by `name_index`
/// make the plan independent of fleet iteration order.
pub fn materialize_faults(job: &JobConfig, client_names: &[String]) -> FaultPlan {
    let mut plan = FaultPlan::none();
    for (node, round) in &job.faults.drops {
        plan = plan.drop_in_round(node, *round);
    }
    for (node, round) in &job.faults.crashes {
        plan = plan.crash_from(node, *round);
    }
    if let Some(churn) = job.faults.churn {
        if churn.availability < 1.0 {
            let seed_rng = Rng::seed_from(job.seed);
            for name in client_names {
                let mut rng = seed_rng.derive("churn", name_index(name));
                for round in churn.from_round..=job.rounds {
                    if rng.next_f64() >= churn.availability {
                        plan = plan.drop_in_round(name, round);
                    }
                }
            }
        }
    }
    plan
}

/// Virtual-population variant of [`materialize_faults`]: explicit events
/// verbatim, churn attached as a lazily-replayed [`ChurnSpec`] instead of a
/// dense per-(client, round) drop table. `FaultPlan::is_down` answers
/// identically to the eager plan for every fleet client and round
/// (test-enforced), at O(1) resident state for any fleet size.
pub fn materialize_faults_virtual(job: &JobConfig) -> FaultPlan {
    let mut plan = FaultPlan::none();
    for (node, round) in &job.faults.drops {
        plan = plan.drop_in_round(node, *round);
    }
    for (node, round) in &job.faults.crashes {
        plan = plan.crash_from(node, *round);
    }
    if let Some(churn) = job.faults.churn {
        if churn.availability < 1.0 {
            plan = plan.with_churn(ChurnSpec {
                seed: job.seed,
                availability: churn.availability,
                from_round: churn.from_round,
                rounds: job.rounds,
                n_clients: job.n_clients as u64,
            });
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::adversary::{AttackKind, ChurnConfig};

    fn fleet(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("client_{i}")).collect()
    }

    #[test]
    fn inactive_config_selects_nobody() {
        let root = Rng::seed_from(42);
        let adv = AdversaryConfig::default();
        assert!(select_adversaries(&adv, &root, &fleet(10)).unwrap().is_empty());
    }

    #[test]
    fn fraction_draw_is_deterministic_and_sized() {
        let root = Rng::seed_from(42);
        let adv = AdversaryConfig {
            attack: AttackKind::Scale,
            attack_fraction: 0.3,
            scale: 10.0,
            nodes: vec![],
        };
        let a = select_adversaries(&adv, &root, &fleet(10)).unwrap();
        let b = select_adversaries(&adv, &root, &fleet(10)).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        // A different seed draws a different cohort (w.h.p. for this seed
        // pair — pinned, not flaky).
        let other = select_adversaries(&adv, &Rng::seed_from(43), &fleet(10)).unwrap();
        assert_ne!(a, other);
    }

    #[test]
    fn explicit_nodes_union_with_draw_and_validate() {
        let root = Rng::seed_from(42);
        let adv = AdversaryConfig {
            attack: AttackKind::SignFlip,
            attack_fraction: 0.0,
            scale: 10.0,
            nodes: vec!["client_2".into(), "client_5".into()],
        };
        let a = select_adversaries(&adv, &root, &fleet(10)).unwrap();
        assert_eq!(a, ["client_2", "client_5"].iter().map(|s| s.to_string()).collect());
        let bad = AdversaryConfig {
            nodes: vec!["client_99".into()],
            ..adv
        };
        assert!(select_adversaries(&bad, &root, &fleet(10)).is_err());
    }

    #[test]
    fn churn_materializes_deterministically() {
        let mut job = JobConfig::default_cnn("fedavg");
        job.rounds = 20;
        job.faults.churn = Some(ChurnConfig {
            availability: 0.7,
            from_round: 3,
        });
        let names = fleet(5);
        let a = materialize_faults(&job, &names);
        let b = materialize_faults(&job, &names);
        for name in &names {
            for round in 1..=job.rounds {
                assert_eq!(a.is_down(name, round), b.is_down(name, round));
                if round < 3 {
                    assert!(!a.is_down(name, round), "churn before from_round");
                }
            }
        }
        // At 30% unavailability over 5×18 draws, some drop must occur.
        let any_down = names
            .iter()
            .any(|n| (3..=job.rounds).any(|r| a.is_down(n, r)));
        assert!(any_down);
        // availability 1.0 is a no-op plan.
        job.faults.churn = Some(ChurnConfig {
            availability: 1.0,
            from_round: 1,
        });
        assert!(materialize_faults(&job, &names).is_empty());
    }

    #[test]
    fn virtual_adversary_selection_matches_eager() {
        for (seed, n, frac) in [(42u64, 10usize, 0.3), (7, 13, 0.5), (99, 101, 0.07)] {
            let root = Rng::seed_from(seed);
            let adv = AdversaryConfig {
                attack: AttackKind::Scale,
                attack_fraction: frac,
                scale: 10.0,
                nodes: vec!["client_2".into()],
            };
            // Eager draws over the sorted name list; virtual over ranks.
            let mut names = fleet(n);
            names.sort();
            let eager = select_adversaries(&adv, &root, &names).unwrap();
            let pop = Population::new(n).unwrap();
            let virt = select_adversaries_virtual(&adv, &root, &pop).unwrap();
            assert_eq!(eager, virt, "seed={seed} n={n} frac={frac}");
        }
        // Out-of-fleet explicit nodes are rejected in both paths.
        let adv = AdversaryConfig {
            attack: AttackKind::SignFlip,
            attack_fraction: 0.0,
            scale: 10.0,
            nodes: vec!["client_99".into()],
        };
        let pop = Population::new(10).unwrap();
        assert!(select_adversaries_virtual(&adv, &Rng::seed_from(1), &pop).is_err());
    }

    #[test]
    fn virtual_fault_plan_matches_dense_plan() {
        for (seed, n_clients, availability, from_round) in
            [(42u64, 5usize, 0.7, 3u64), (7, 12, 0.9, 1), (1234, 50, 0.5, 6)]
        {
            let mut job = JobConfig::default_cnn("fedavg");
            job.seed = seed;
            job.rounds = 15;
            job.n_clients = n_clients;
            job.faults.churn = Some(ChurnConfig {
                availability,
                from_round,
            });
            job.faults.drops.push(("client_1".into(), 2));
            job.faults.crashes.push(("client_0".into(), 9));
            let names = fleet(n_clients);
            let dense = materialize_faults(&job, &names);
            let lazy = materialize_faults_virtual(&job);
            for name in &names {
                for round in 0..=job.rounds + 2 {
                    assert_eq!(
                        dense.is_down(name, round),
                        lazy.is_down(name, round),
                        "seed={seed} node={name} round={round}"
                    );
                }
            }
            // Workers are untouched by churn in both plans.
            assert!(!lazy.is_down("worker_0", from_round));
        }
    }

    #[test]
    fn explicit_schedule_materializes_verbatim() {
        let mut job = JobConfig::default_cnn("fedavg");
        job.faults.drops.push(("client_1".into(), 3));
        job.faults.crashes.push(("client_2".into(), 5));
        let plan = materialize_faults(&job, &fleet(10));
        assert!(plan.is_down("client_1", 3));
        assert!(!plan.is_down("client_1", 4));
        assert!(plan.is_down("client_2", 5) && plan.is_down("client_2", 9));
        assert!(!plan.is_down("client_2", 4));
    }
}
