//! Score-vote consensus: each proposal is scored by its distance to the
//! coordinate-median of all proposals; the proposal closest to the robust
//! center wins. A quorum-free alternative to majority-hash that also
//! survives a 1:1 malicious split when the poison is far from the median.

use anyhow::{bail, Result};

use crate::aggregate::robust::coordinate_median;
use crate::consensus::{Consensus, Decision, Proposal};
use crate::util::rng::Rng;
use crate::util::stats;

#[derive(Default)]
pub struct ScoreVote;

impl Consensus for ScoreVote {
    fn name(&self) -> &'static str {
        "score_vote"
    }

    fn decide(&self, proposals: &[Proposal], _rng: &mut Rng) -> Result<Decision> {
        if proposals.is_empty() {
            bail!("consensus over zero proposals");
        }
        let refs: Vec<&[f32]> = proposals.iter().map(|p| p.params.as_slice()).collect();
        let center = coordinate_median(&refs)?;
        let mut best = (f64::INFINITY, 0usize);
        let mut votes = vec![0usize; proposals.len()];
        for (i, p) in proposals.iter().enumerate() {
            let d = stats::l2_dist(&p.params, &center);
            if d < best.0 {
                best = (d, i);
            }
        }
        votes[best.1] = proposals.len();
        Ok(Decision {
            winner: best.1,
            votes,
            decisive: true,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_proposal_nearest_median() {
        let proposals = vec![
            Proposal::new("mal", vec![50.0; 4]),
            Proposal::new("h1", vec![1.0; 4]),
            Proposal::new("h2", vec![1.2; 4]),
        ];
        let d = ScoreVote.decide(&proposals, &mut Rng::seed_from(0)).unwrap();
        assert_ne!(d.winner, 0);
    }

    #[test]
    fn two_proposals_prefers_less_extreme_is_stable() {
        let proposals = vec![
            Proposal::new("a", vec![0.0, 0.0]),
            Proposal::new("b", vec![1.0, 1.0]),
        ];
        let d1 = ScoreVote.decide(&proposals, &mut Rng::seed_from(1)).unwrap();
        let d2 = ScoreVote.decide(&proposals, &mut Rng::seed_from(2)).unwrap();
        assert_eq!(d1.winner, d2.winner); // rng-independent
    }

    #[test]
    fn empty_is_error() {
        assert!(ScoreVote.decide(&[], &mut Rng::seed_from(0)).is_err());
    }
}
