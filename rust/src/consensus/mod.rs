//! Multi-worker aggregation consensus (paper §2.5 / RQ3).
//!
//! After every worker aggregates the round's client models, the workers vote
//! on which aggregate becomes the next global model. The paper's Fig 5
//! interface is a single function `consensus(aggregated_models, extra) ->
//! model`; here it is the [`Consensus`] trait plus a registry so jobs can
//! select an algorithm by name from the YAML config — or delegate to a
//! blockchain contract (see [`crate::chain::contracts::consensus_contract`]).

pub mod majority;
pub mod score;

use anyhow::Result;

use crate::util::rng::Rng;

/// One worker's proposal for the round.
#[derive(Clone, Debug)]
pub struct Proposal {
    pub worker: String,
    pub params: Vec<f32>,
    /// SHA-256 of `params` — what actually goes on the wire in phase 2
    /// ("Aggregated Parameter Voting") of the paper's consensus pipeline.
    pub hash: String,
}

impl Proposal {
    pub fn new(worker: impl Into<String>, params: Vec<f32>) -> Proposal {
        let hash = crate::util::hash::hash_params(&params);
        Proposal {
            worker: worker.into(),
            params,
            hash,
        }
    }
}

/// Outcome of a consensus round.
#[derive(Clone, Debug)]
pub struct Decision {
    /// Index into the proposal list that won.
    pub winner: usize,
    /// Votes per proposal index (same order as input).
    pub votes: Vec<usize>,
    /// True when the vote was decisive (strict majority of workers).
    pub decisive: bool,
}

/// Pluggable consensus algorithm (the paper's `MyConsensus` outline).
// `Send` is part of the contract: campaign schedulers park a paused
// `JobState` (which owns the consensus object) between rungs and may resume
// it on a different job-pool worker thread.
pub trait Consensus: Send {
    fn name(&self) -> &'static str;

    /// Select the next global model among worker proposals. `rng` is the
    /// round-derived deterministic stream (tie-breaks must be reproducible).
    fn decide(&self, proposals: &[Proposal], rng: &mut Rng) -> Result<Decision>;
}

/// Look up a consensus algorithm by config name.
pub fn by_name(name: &str) -> Result<Box<dyn Consensus>> {
    match name {
        "majority_hash" | "fedrlchain" => Ok(Box::new(majority::MajorityHash)),
        "score_vote" => Ok(Box::new(score::ScoreVote::default())),
        "first" => Ok(Box::new(majority::FirstProposal)),
        _ => anyhow::bail!("unknown consensus '{name}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves() {
        assert_eq!(by_name("majority_hash").unwrap().name(), "majority_hash");
        assert_eq!(by_name("fedrlchain").unwrap().name(), "majority_hash");
        assert_eq!(by_name("score_vote").unwrap().name(), "score_vote");
        assert!(by_name("paxos").is_err());
    }

    #[test]
    fn proposal_hash_matches_params() {
        let p = Proposal::new("w0", vec![1.0, 2.0]);
        assert_eq!(p.hash, crate::util::hash::hash_params(&[1.0, 2.0]));
    }
}
