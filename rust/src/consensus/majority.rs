//! Majority-hash consensus — the scheme of Chowdhury et al. [13]
//! (FedRLchain) the paper uses for its Fig 10 malicious-worker experiment.
//!
//! Honest workers aggregating the same client models deterministically
//! produce bitwise-identical parameters, hence identical hashes; a poisoned
//! aggregate hashes differently. Workers vote with their hashes and the
//! plurality hash wins. With honest workers > 50% the poisoned model can
//! never win; at 1:1 the tie-break is a coin flip from the round's seed
//! stream, producing exactly the fluctuating trajectory of Fig 10.

use anyhow::{bail, Result};

use crate::consensus::{Consensus, Decision, Proposal};
use crate::util::rng::Rng;

pub struct MajorityHash;

impl Consensus for MajorityHash {
    fn name(&self) -> &'static str {
        "majority_hash"
    }

    fn decide(&self, proposals: &[Proposal], rng: &mut Rng) -> Result<Decision> {
        if proposals.is_empty() {
            bail!("consensus over zero proposals");
        }
        // Count votes per distinct hash (each worker votes for its own
        // aggregate; phase-2 of the paper's pipeline).
        let mut votes = vec![0usize; proposals.len()];
        for (i, p) in proposals.iter().enumerate() {
            for q in proposals {
                if p.hash == q.hash {
                    votes[i] += 1;
                }
            }
        }
        let max_votes = *votes.iter().max().unwrap();
        // Candidates = distinct hashes holding the plurality.
        let mut winners: Vec<usize> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for (i, p) in proposals.iter().enumerate() {
            if votes[i] == max_votes && seen.insert(p.hash.clone()) {
                winners.push(i);
            }
        }
        let decisive = winners.len() == 1 && 2 * max_votes > proposals.len();
        let winner = if winners.len() == 1 {
            winners[0]
        } else {
            // Deterministic tie-break from the round stream.
            winners[rng.below(winners.len())]
        };
        Ok(Decision {
            winner,
            votes,
            decisive,
        })
    }
}

/// Degenerate single-aggregator "consensus": take the first proposal.
/// (What a 1-worker FedAvg deployment effectively runs.)
pub struct FirstProposal;

impl Consensus for FirstProposal {
    fn name(&self) -> &'static str {
        "first"
    }

    fn decide(&self, proposals: &[Proposal], _rng: &mut Rng) -> Result<Decision> {
        if proposals.is_empty() {
            bail!("consensus over zero proposals");
        }
        Ok(Decision {
            winner: 0,
            votes: vec![1; proposals.len()],
            decisive: proposals.len() == 1,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prop(worker: &str, v: f32) -> Proposal {
        Proposal::new(worker, vec![v; 8])
    }

    #[test]
    fn honest_majority_defeats_poison() {
        // 1 malicious (different params) vs 2 honest (identical params).
        let proposals = vec![prop("mal", 99.0), prop("h1", 1.0), prop("h2", 1.0)];
        let d = MajorityHash
            .decide(&proposals, &mut Rng::seed_from(1))
            .unwrap();
        assert!(d.decisive);
        assert_ne!(d.winner, 0);
        assert_eq!(proposals[d.winner].params[0], 1.0);
    }

    #[test]
    fn one_to_one_tie_is_coin_flip_but_deterministic() {
        let proposals = vec![prop("mal", 99.0), prop("h1", 1.0)];
        let d1 = MajorityHash
            .decide(&proposals, &mut Rng::seed_from(7))
            .unwrap();
        let d2 = MajorityHash
            .decide(&proposals, &mut Rng::seed_from(7))
            .unwrap();
        assert!(!d1.decisive);
        assert_eq!(d1.winner, d2.winner);
        // Across different round seeds both sides win sometimes.
        let mut saw = std::collections::BTreeSet::new();
        for seed in 0..32 {
            let d = MajorityHash
                .decide(&proposals, &mut Rng::seed_from(seed))
                .unwrap();
            saw.insert(d.winner);
        }
        assert_eq!(saw.len(), 2, "tie-break never flips");
    }

    #[test]
    fn single_worker_trivially_wins() {
        let proposals = vec![prop("only", 5.0)];
        let d = MajorityHash
            .decide(&proposals, &mut Rng::seed_from(0))
            .unwrap();
        assert_eq!(d.winner, 0);
        assert!(d.decisive);
    }

    #[test]
    fn four_workers_one_malicious() {
        // Fig 10's 1M-3H case: decisive honest win.
        let proposals = vec![
            prop("mal", 9.0),
            prop("h1", 1.0),
            prop("h2", 1.0),
            prop("h3", 1.0),
        ];
        let d = MajorityHash
            .decide(&proposals, &mut Rng::seed_from(3))
            .unwrap();
        assert!(d.decisive);
        assert_eq!(proposals[d.winner].params[0], 1.0);
        assert_eq!(d.votes, vec![1, 3, 3, 3]);
    }

    #[test]
    fn empty_is_error() {
        assert!(MajorityHash.decide(&[], &mut Rng::seed_from(0)).is_err());
        assert!(FirstProposal.decide(&[], &mut Rng::seed_from(0)).is_err());
    }
}
