//! Cooperative cell leases: the coordination layer that lets N worker
//! processes drain one campaign store with no coordinator.
//!
//! One lease file per cell key lives under `<store>/leases/<key>.lease`,
//! a single-line JSON doc (`{"schema":"flsim-lease-v1", key, owner, beat,
//! pid}`). The protocol rests on three filesystem atomics:
//!
//! * **Acquire** — `O_CREAT|O_EXCL` (`create_new`): exactly one process
//!   creates the canonical path, so at most one holder exists at a time.
//! * **Heartbeat** — the holder periodically rewrites the doc (temp file +
//!   rename) with an incremented `beat` counter, refreshing the file's
//!   mtime.
//! * **Reclaim** — a lease is *stale* when its heartbeat stopped: the file
//!   mtime is older than the expiry, or this process has watched the same
//!   `beat` for longer than the expiry on its own monotonic clock (the
//!   skew-proof fallback for shared filesystems with drifting clocks).
//!   Reclaiming renames the stale file *away* — rename is atomic, so
//!   exactly one contender wins — and then races `create_new` like
//!   everyone else.
//!
//! Leases are an **efficiency** mechanism, not a correctness one: results
//! are content-addressed and committed atomically, so even the worst case
//! (a holder paused longer than the expiry, its lease stolen, both
//! finishing) produces duplicate *work*, never wrong bits. Pick the expiry
//! well above the longest round plus clock skew; see the README's
//! "Distributed campaigns" section.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant, SystemTime};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Schema tag of one lease doc.
pub const LEASE_SCHEMA: &str = "flsim-lease-v1";

/// Subdirectory of the result store holding lease files.
pub const LEASE_DIR: &str = "leases";

/// Heartbeat / expiry knobs (CLI: `--heartbeat-secs`, `--expiry-secs`).
#[derive(Clone, Copy, Debug)]
pub struct LeaseConfig {
    /// How often a holder rewrites its lease while executing.
    pub heartbeat: Duration,
    /// A lease whose heartbeat has been silent this long is stale and may
    /// be reclaimed. Must comfortably exceed the heartbeat plus any clock
    /// skew between hosts sharing the store.
    pub expiry: Duration,
}

impl Default for LeaseConfig {
    fn default() -> LeaseConfig {
        LeaseConfig {
            heartbeat: Duration::from_secs(2),
            expiry: Duration::from_secs(20),
        }
    }
}

/// What's known about a lease file on disk (for `campaign list` and gc).
#[derive(Clone, Debug)]
pub struct LeaseInfo {
    pub key: String,
    pub owner: String,
    pub beat: u64,
    /// Time since the last heartbeat (file mtime).
    pub age: Duration,
}

/// Outcome of [`LeaseManager::try_acquire`].
pub enum Acquire {
    /// This process now holds the cell. Dropping the [`Lease`] releases it.
    Acquired(Lease),
    /// A live holder exists; try again later (or work on another cell).
    Held { owner: String },
}

/// A held lease. [`Lease::beat`] refreshes it; dropping it releases the
/// cell (owner-checked, so a stolen lease is never deleted out from under
/// its new holder).
pub struct Lease {
    path: PathBuf,
    key: String,
    owner: String,
    pid: u32,
    beat: u64,
}

impl Lease {
    fn doc(&self) -> String {
        let d = Json::obj(vec![
            ("schema", Json::from(LEASE_SCHEMA)),
            ("key", Json::from(self.key.as_str())),
            ("owner", Json::from(self.owner.as_str())),
            ("beat", Json::from(self.beat as f64)),
            ("pid", Json::from(self.pid as usize)),
        ]);
        format!("{d}\n")
    }

    /// Refresh the lease: atomically rewrite the doc with `beat + 1`.
    /// Errors if the lease was stolen (we expired and someone reclaimed) —
    /// the caller should stop heartbeating; its eventual commit is still
    /// safe (atomic, content-addressed), just possibly duplicated work.
    pub fn beat(&mut self) -> Result<()> {
        match read_doc(&self.path) {
            Some(info) if info.owner == self.owner => {}
            _ => anyhow::bail!(
                "lease on {} lost (expired and reclaimed?)",
                &self.key[..12.min(self.key.len())]
            ),
        }
        self.beat += 1;
        let tmp = self
            .path
            .with_file_name(format!(".{}.{}.beat.tmp", self.key, self.pid));
        std::fs::write(&tmp, self.doc()).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("heartbeating {:?}", self.path))?;
        Ok(())
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        // Owner-checked release: if the lease expired and was reclaimed,
        // the file now belongs to someone else — leave it alone.
        if let Some(info) = read_doc(&self.path) {
            if info.owner == self.owner {
                let _ = std::fs::remove_file(&self.path);
            }
        }
    }
}

/// One worker's view of a store's lease directory.
pub struct LeaseManager {
    dir: PathBuf,
    owner: String,
    cfg: LeaseConfig,
    /// key → (last beat seen, when that beat was first seen) — the local
    /// monotonic observation window behind the skew-proof staleness test.
    observed: Mutex<BTreeMap<String, (u64, Instant)>>,
}

impl LeaseManager {
    pub fn open(store_dir: &Path, owner: &str, cfg: LeaseConfig) -> Result<LeaseManager> {
        let dir = store_dir.join(LEASE_DIR);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating lease dir {dir:?}"))?;
        Ok(LeaseManager {
            dir,
            owner: owner.to_string(),
            cfg,
            observed: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn owner(&self) -> &str {
        &self.owner
    }

    fn path_of(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.lease"))
    }

    /// Try to lease `key`. Never blocks: returns [`Acquire::Held`] when a
    /// live holder exists, reclaiming stale leases along the way.
    pub fn try_acquire(&self, key: &str) -> Result<Acquire> {
        let path = self.path_of(key);
        // Bounded retries: each loop either creates the lease, observes a
        // live holder, or reclaims a stale one and races again.
        for _ in 0..8 {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(mut f) => {
                    let lease = Lease {
                        path: path.clone(),
                        key: key.to_string(),
                        owner: self.owner.clone(),
                        pid: std::process::id(),
                        beat: 0,
                    };
                    f.write_all(lease.doc().as_bytes())
                        .with_context(|| format!("writing lease {path:?}"))?;
                    self.observed.lock().unwrap().remove(key);
                    return Ok(Acquire::Acquired(lease));
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    if self.is_stale(key, &path) {
                        self.reclaim(key, &path)?;
                        continue;
                    }
                    let owner = read_doc(&path)
                        .map(|i| i.owner)
                        .unwrap_or_else(|| "unknown".to_string());
                    return Ok(Acquire::Held { owner });
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("creating lease {path:?}"));
                }
            }
        }
        Ok(Acquire::Held {
            owner: "contended".to_string(),
        })
    }

    /// Stale = heartbeat silent past the expiry, judged two ways (either
    /// suffices): the file mtime is old (prompt recovery, same-host
    /// clocks), or this process has watched an unchanged beat for the
    /// expiry on its own monotonic clock (immune to cross-host skew).
    fn is_stale(&self, key: &str, path: &Path) -> bool {
        let mtime_age = std::fs::metadata(path)
            .and_then(|m| m.modified())
            .ok()
            .and_then(|m| SystemTime::now().duration_since(m).ok());
        if let Some(age) = mtime_age {
            if age > self.cfg.expiry {
                return true;
            }
        }
        // An unreadable/torn doc still heartbeats via mtime; watch it under
        // a sentinel beat so a permanently torn file eventually expires.
        let beat = read_doc(path).map(|i| i.beat).unwrap_or(u64::MAX);
        let mut observed = self.observed.lock().unwrap();
        let now = Instant::now();
        match observed.get(key) {
            Some(&(seen_beat, since)) if seen_beat == beat => {
                now.duration_since(since) > self.cfg.expiry
            }
            _ => {
                observed.insert(key.to_string(), (beat, now));
                false
            }
        }
    }

    /// Rename the stale lease away (exactly one contender's rename wins)
    /// and delete the moved file. A `NotFound` means another contender —
    /// or a release — got there first; both are success.
    fn reclaim(&self, key: &str, path: &Path) -> Result<()> {
        let grave = self.dir.join(format!(
            ".{key}.{}.reclaimed.tmp",
            std::process::id()
        ));
        match std::fs::rename(path, &grave) {
            Ok(()) => {
                let _ = std::fs::remove_file(&grave);
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(e).with_context(|| format!("reclaiming lease {path:?}"));
            }
        }
        self.observed.lock().unwrap().remove(key);
        Ok(())
    }
}

fn read_doc(path: &Path) -> Option<LeaseInfo> {
    let src = std::fs::read_to_string(path).ok()?;
    let doc = Json::parse(&src).ok()?;
    if doc.get("schema").and_then(Json::as_str) != Some(LEASE_SCHEMA) {
        return None;
    }
    let age = std::fs::metadata(path)
        .and_then(|m| m.modified())
        .ok()
        .and_then(|m| SystemTime::now().duration_since(m).ok())
        .unwrap_or(Duration::ZERO);
    Some(LeaseInfo {
        key: doc.get("key")?.as_str()?.to_string(),
        owner: doc.get("owner")?.as_str()?.to_string(),
        beat: doc.get("beat")?.as_f64()? as u64,
        age,
    })
}

/// The lease (live or stale) on `key`, if any.
pub fn info(store_dir: &Path, key: &str) -> Option<LeaseInfo> {
    read_doc(&store_dir.join(LEASE_DIR).join(format!("{key}.lease")))
}

/// All leases whose heartbeat is younger than `expiry`, keyed by cell key
/// — the set gc must protect (judged by mtime alone: gc is conservative,
/// an about-to-expire lease is still protected this pass and collectable
/// the next).
pub fn live(store_dir: &Path, expiry: Duration) -> BTreeMap<String, LeaseInfo> {
    let mut out = BTreeMap::new();
    let dir = store_dir.join(LEASE_DIR);
    let Ok(files) = std::fs::read_dir(&dir) else { return out };
    for f in files.flatten() {
        let path = f.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        let Some(key) = name.strip_suffix(".lease") else { continue };
        if key.len() != 64 || !key.chars().all(|c| c.is_ascii_hexdigit()) {
            continue;
        }
        if let Some(info) = read_doc(&path) {
            if info.age <= expiry {
                out.insert(key.to_string(), info);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "flsim_lease_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn key(n: u8) -> String {
        format!("{n:02x}").repeat(32)
    }

    #[test]
    fn acquire_is_exclusive_and_release_frees() {
        let dir = tmp_dir("exclusive");
        let cfg = LeaseConfig::default();
        let a = LeaseManager::open(&dir, "a", cfg).unwrap();
        let b = LeaseManager::open(&dir, "b", cfg).unwrap();

        let lease = match a.try_acquire(&key(1)).unwrap() {
            Acquire::Acquired(l) => l,
            Acquire::Held { .. } => panic!("fresh key must acquire"),
        };
        match b.try_acquire(&key(1)).unwrap() {
            Acquire::Held { owner } => assert_eq!(owner, "a"),
            Acquire::Acquired(_) => panic!("held lease must not double-acquire"),
        }
        // A different key is independent.
        assert!(matches!(b.try_acquire(&key(2)).unwrap(), Acquire::Acquired(_)));

        drop(lease);
        assert!(matches!(b.try_acquire(&key(1)).unwrap(), Acquire::Acquired(_)));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stale_leases_are_reclaimed_after_expiry() {
        let dir = tmp_dir("reclaim");
        let cfg = LeaseConfig {
            heartbeat: Duration::from_millis(10),
            expiry: Duration::from_millis(120),
        };
        let a = LeaseManager::open(&dir, "a", cfg).unwrap();
        let b = LeaseManager::open(&dir, "b", cfg).unwrap();

        // "a" acquires and then crashes (we just never beat or drop it).
        let dead = match a.try_acquire(&key(3)).unwrap() {
            Acquire::Acquired(l) => l,
            _ => panic!(),
        };
        std::mem::forget(dead);

        // Immediately: held. After the expiry with no heartbeat: stolen.
        assert!(matches!(b.try_acquire(&key(3)).unwrap(), Acquire::Held { .. }));
        std::thread::sleep(Duration::from_millis(200));
        let stolen = match b.try_acquire(&key(3)).unwrap() {
            Acquire::Acquired(l) => l,
            Acquire::Held { .. } => panic!("expired lease must be reclaimable"),
        };
        assert_eq!(info(&dir, &key(3)).unwrap().owner, "b");
        drop(stolen);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn heartbeat_keeps_a_lease_live() {
        let dir = tmp_dir("beat");
        let cfg = LeaseConfig {
            heartbeat: Duration::from_millis(10),
            expiry: Duration::from_millis(150),
        };
        let a = LeaseManager::open(&dir, "a", cfg).unwrap();
        let b = LeaseManager::open(&dir, "b", cfg).unwrap();
        let mut lease = match a.try_acquire(&key(4)).unwrap() {
            Acquire::Acquired(l) => l,
            _ => panic!(),
        };
        // Beat past the expiry window; the lease must stay held.
        for _ in 0..6 {
            std::thread::sleep(Duration::from_millis(50));
            lease.beat().unwrap();
            assert!(
                matches!(b.try_acquire(&key(4)).unwrap(), Acquire::Held { .. }),
                "a heartbeating lease must not be stolen"
            );
        }
        assert!(info(&dir, &key(4)).unwrap().beat >= 6);
        drop(lease);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn live_listing_filters_by_age() {
        let dir = tmp_dir("live");
        let a = LeaseManager::open(&dir, "a", LeaseConfig::default()).unwrap();
        let lease = match a.try_acquire(&key(5)).unwrap() {
            Acquire::Acquired(l) => l,
            _ => panic!(),
        };
        let fresh = live(&dir, Duration::from_secs(60));
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh.get(&key(5)).unwrap().owner, "a");
        // With a zero expiry every lease reads as already-dead.
        std::thread::sleep(Duration::from_millis(20));
        assert!(live(&dir, Duration::ZERO).is_empty());
        drop(lease);
        assert!(live(&dir, Duration::from_secs(60)).is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
