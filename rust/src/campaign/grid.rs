//! Grid expansion: resolve a [`CampaignSpec`] into the deterministic list
//! of concrete cells.
//!
//! * Axis names expand in sorted order, axis values in listed order; the
//!   grid enumerates with the **last axis fastest** (mixed-radix decode of
//!   the cell index), so the cell list is a pure function of the spec.
//! * Explicit cells are appended after the grid.
//! * Cells whose resolved configs hash identically are deduplicated
//!   (first occurrence wins); two *different* configs under one name are a
//!   spec error (their reports would overwrite each other).

use anyhow::{anyhow, bail, Result};

use crate::campaign::cache;
use crate::campaign::spec::{apply_axis, name_part, CampaignSpec};
use crate::config::job::JobConfig;

/// One concrete campaign cell: a named, validated job plus its
/// content-addressed result-store key.
#[derive(Clone, Debug)]
pub struct Cell {
    pub name: String,
    pub job: JobConfig,
    /// SHA-256 of the canonical job config + engine version
    /// ([`cache::cell_key`]).
    pub key: String,
}

/// Expand a spec into its deterministic cell list.
pub fn expand(spec: &CampaignSpec) -> Result<Vec<Cell>> {
    let mut cells: Vec<Cell> = Vec::new();
    let mut seen_keys = std::collections::BTreeSet::new();
    let mut name_keys: std::collections::BTreeMap<String, String> = Default::default();

    let mut push = |cell: Cell| -> Result<()> {
        if let Some(prev) = name_keys.get(&cell.name) {
            if *prev != cell.key {
                bail!(
                    "campaign '{}': two different cells share the name '{}' — \
                     their reports would overwrite each other",
                    spec.name,
                    cell.name
                );
            }
        } else {
            name_keys.insert(cell.name.clone(), cell.key.clone());
        }
        if seen_keys.insert(cell.key.clone()) {
            cells.push(cell);
        }
        Ok(())
    };

    // The cartesian grid over the axes.
    if !spec.axes.is_empty() {
        for (axis, vals) in &spec.axes {
            if vals.is_empty() {
                // Mirror the YAML-path validation: a zero-value axis would
                // silently expand to a zero-cell "successful" campaign.
                bail!("campaign '{}': axis '{axis}' has no values", spec.name);
            }
        }
        let axes: Vec<(&String, &Vec<crate::util::yaml::Yaml>)> = spec.axes.iter().collect();
        let total: usize = axes.iter().map(|(_, vals)| vals.len()).product();
        let topology_swept = spec.axes.contains_key("topology");
        for cell_index in 0..total {
            let mut rem = cell_index;
            let mut picks = vec![0usize; axes.len()];
            for ai in (0..axes.len()).rev() {
                let len = axes[ai].1.len();
                picks[ai] = rem % len;
                rem /= len;
            }
            let mut job = spec.base.clone();
            let mut parts = Vec::with_capacity(axes.len());
            for (ai, &pick) in picks.iter().enumerate() {
                let (axis, vals) = axes[ai];
                let value = &vals[pick];
                apply_axis(&mut job, axis, value)
                    .map_err(|e| anyhow!("campaign '{}': {e}", spec.name))?;
                parts.push(name_part(axis, value));
            }
            let name = parts.join("_");
            if topology_swept && crate::orchestrator::check_topology(&job).is_err() {
                // A swept topology axis pairs every strategy with every
                // topology; incompatible grid points (decentralized strategy
                // × server topology) are skipped rather than failing the
                // whole campaign. Explicitly pinned cells still error below.
                crate::warnlog!(
                    "campaign",
                    "{}: skipping incompatible grid cell '{name}' ({} × {})",
                    spec.name,
                    job.strategy.name(),
                    job.topology.name()
                );
                continue;
            }
            push(make_cell(spec, name, job, topology_swept)?)?;
        }
    }

    // Explicit cells.
    for (i, cs) in spec.cells.iter().enumerate() {
        let mut job = spec.base.clone();
        let mut parts = Vec::with_capacity(cs.overrides.len());
        let mut topology_pinned = false;
        for (axis, value) in &cs.overrides {
            apply_axis(&mut job, axis, value)
                .map_err(|e| anyhow!("campaign '{}' cell {i}: {e}", spec.name))?;
            topology_pinned |= axis == "topology";
            parts.push(name_part(axis, value));
        }
        let name = match &cs.name {
            Some(n) => n.clone(),
            None if parts.is_empty() => spec.base.name.clone(),
            None => parts.join("_"),
        };
        push(make_cell(spec, name, job, topology_pinned)?)?;
    }

    // A spec with no axes and no cells is the degenerate single-cell
    // campaign: the base job itself.
    if spec.axes.is_empty() && spec.cells.is_empty() {
        let job = spec.base.clone();
        let name = spec.base.name.clone();
        push(make_cell(spec, name, job, false)?)?;
    }

    if cells.is_empty() {
        // Only reachable when every grid point was skipped as incompatible —
        // a zero-cell campaign "succeeding" would hide a misconfigured spec.
        bail!(
            "campaign '{}': expansion produced no runnable cells \
             (every grid point was skipped as strategy/topology-incompatible)",
            spec.name
        );
    }

    Ok(cells)
}

/// Finalize one cell: stamp the name, reconcile strategy mode with the
/// topology, validate, and compute the content-addressed key.
fn make_cell(
    spec: &CampaignSpec,
    name: String,
    mut job: JobConfig,
    topology_pinned: bool,
) -> Result<Cell> {
    job.name = name.clone();
    if let Err(e) = crate::orchestrator::check_topology(&job) {
        if topology_pinned {
            // The spec explicitly asked for an incompatible combination —
            // surface the orchestrator's error at expand time.
            return Err(anyhow!("campaign '{}' cell '{name}': {e}", spec.name));
        }
        // Mirror the preset constructors: decentralized strategies default
        // onto a fully-connected overlay.
        job.topology = crate::topology::TopologyKind::FullyConnected;
    }
    job.validate()
        .map_err(|e| anyhow!("campaign '{}' cell '{name}': {e}", spec.name))?;
    let key = cache::cell_key(&job);
    Ok(Cell { name, job, key })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::yaml::Yaml;

    fn tiny_base() -> JobConfig {
        let mut j = JobConfig::default_cnn("fedavg");
        j.rounds = 2;
        j.dataset.n = 600;
        j.n_clients = 4;
        j
    }

    #[test]
    fn grid_is_sorted_axes_last_fastest() {
        let spec = CampaignSpec::builder("g", tiny_base())
            .axis_strs("strategy", &["fedavg", "fedprox"])
            .axis_ints("seed", &[1, 2])
            .build();
        let cells = expand(&spec).unwrap();
        // Axis order is sorted ("seed" < "strategy"); the last axis
        // (strategy) spins fastest.
        let names: Vec<&str> = cells.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, ["seed1_fedavg", "seed1_fedprox", "seed2_fedavg", "seed2_fedprox"]);
        assert_eq!(cells[0].job.seed, 1);
        assert_eq!(cells[3].job.seed, 2);
        assert_eq!(cells[3].job.strategy.name(), "fedprox");
        // Expansion is a pure function of the spec.
        let again = expand(&spec).unwrap();
        for (a, b) in cells.iter().zip(&again) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.key, b.key);
        }
    }

    #[test]
    fn duplicate_cells_dedup() {
        let spec = CampaignSpec::builder("d", tiny_base())
            .axis_strs("strategy", &["fedavg", "fedavg"])
            .build();
        let cells = expand(&spec).unwrap();
        assert_eq!(cells.len(), 1, "identical cells must deduplicate");

        // An explicit cell identical to a grid cell dedups too.
        let spec = CampaignSpec::builder("d2", tiny_base())
            .axis_strs("strategy", &["fedavg"])
            .cell("fedavg", vec![("strategy", "fedavg".into())])
            .build();
        assert_eq!(expand(&spec).unwrap().len(), 1);
    }

    #[test]
    fn same_name_different_config_is_an_error() {
        let spec = CampaignSpec::builder("n", tiny_base())
            .cell("x", vec![("seed", Yaml::Int(1))])
            .cell("x", vec![("seed", Yaml::Int(2))])
            .build();
        assert!(expand(&spec).is_err());
    }

    #[test]
    fn empty_spec_is_the_base_job() {
        let spec = CampaignSpec::builder("solo", tiny_base()).build();
        let cells = expand(&spec).unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].name, tiny_base().name);
    }

    #[test]
    fn decentralized_cells_reconcile_topology() {
        let spec = CampaignSpec::builder("t", tiny_base())
            .axis_strs("strategy", &["fedavg", "fedstellar"])
            .build();
        let cells = expand(&spec).unwrap();
        let mesh = cells.iter().find(|c| c.name == "fedstellar").unwrap();
        assert_eq!(mesh.job.topology, crate::topology::TopologyKind::FullyConnected);
        // ... but an explicitly pinned incompatible topology is an error.
        let bad = CampaignSpec::builder("t2", tiny_base())
            .cell(
                "bad",
                vec![
                    ("strategy", "fedstellar".into()),
                    ("topology", "client_server".into()),
                ],
            )
            .build();
        assert!(expand(&bad).is_err());
    }

    #[test]
    fn swept_topology_skips_incompatible_grid_points() {
        // The flagship strategies × topologies grid: the decentralized ×
        // server-topology point is skipped, everything else expands.
        let spec = CampaignSpec::builder("sxt", tiny_base())
            .axis_strs("strategy", &["fedavg", "fedstellar"])
            .axis_strs("topology", &["client_server", "ring"])
            .build();
        let cells = expand(&spec).unwrap();
        let names: Vec<&str> = cells.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(
            names,
            ["fedavg_client_server", "fedavg_ring", "fedstellar_ring"],
            "fedstellar × client_server must be skipped, not fatal"
        );
    }

    #[test]
    fn empty_axis_is_an_error() {
        let spec = CampaignSpec::builder("e", tiny_base())
            .axis("seed", Vec::new())
            .build();
        assert!(expand(&spec).is_err());
    }

    #[test]
    fn all_points_skipped_is_an_error() {
        // Every grid point incompatible → zero runnable cells must not
        // masquerade as a successful (empty) campaign.
        let spec = CampaignSpec::builder("allskip", tiny_base())
            .axis_strs("strategy", &["fedstellar"])
            .axis_strs("topology", &["client_server", "hierarchical"])
            .build();
        assert!(expand(&spec).is_err());
    }

    #[test]
    fn cell_keys_are_schedule_invariant_and_name_sensitive() {
        let spec = CampaignSpec::builder("k", tiny_base())
            .axis_ints("seed", &[1])
            .build();
        let a = expand(&spec).unwrap();
        let mut par = spec.clone();
        par.base.parallelism = 8;
        let b = expand(&par).unwrap();
        assert_eq!(a[0].key, b[0].key, "parallelism must not change cell keys");
        let mut renamed = spec.clone();
        renamed.base.rounds = 3;
        let c = expand(&renamed).unwrap();
        assert_ne!(a[0].key, c[0].key);
    }
}
