//! The job-level scheduler: execute a campaign's cells on a scoped worker
//! pool (outer job-parallelism × the round engine's inner
//! client-parallelism), resuming cached cells from the result store.
//!
//! Scheduling never affects results: every cell's outcome is a pure
//! function of its `JobConfig` (the round engine's determinism contract),
//! cells share no mutable state, and the outcome list is assembled in
//! expansion order regardless of which worker finished first. A failing
//! cell is recorded and the rest of the campaign keeps running — every
//! completed cell is persisted to the store as soon as it finishes, so
//! nothing is lost to one bad cell (the CLI turns recorded failures into a
//! non-zero exit).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::campaign::cache::{CellOutcome, ResultStore};
use crate::campaign::grid::{self, Cell};
use crate::campaign::spec::{CampaignSpec, SchedulerKind};
use crate::metrics::report::RunReport;
use crate::orchestrator::{Orchestrator, RunControl, RunHandle, RunOptions, RunStatus};
use crate::runtime::pjrt::Runtime;

/// What happened to one cell. (Named `CellRun` since the write-side commit
/// payload took the `CellOutcome` name — this is the *read* side: the cell,
/// where its report came from, and how it ended.)
#[derive(Clone, Debug)]
pub struct CellRun {
    pub cell: Cell,
    /// The report came from the result store (no execution happened).
    pub cached: bool,
    /// Present iff the cell completed (fresh or cached).
    pub report: Option<RunReport>,
    /// Present iff the cell failed.
    pub error: Option<String>,
}

/// A finished campaign: one outcome per expanded cell, in expansion order.
#[derive(Clone, Debug)]
pub struct CampaignOutcome {
    pub name: String,
    pub cells: Vec<CellRun>,
}

impl CampaignOutcome {
    /// Cells that completed *and* persisted (a cell whose store-commit
    /// failed counts as failed: it will re-run on retry, so treating it as
    /// done would break the byte-identical-resume contract).
    pub fn completed(&self) -> Vec<&CellRun> {
        self.cells
            .iter()
            .filter(|c| c.report.is_some() && c.error.is_none())
            .collect()
    }

    pub fn failed(&self) -> Vec<&CellRun> {
        self.cells.iter().filter(|c| c.error.is_some()).collect()
    }

    /// Completed (persisted) cells' reports, in expansion order.
    pub fn reports(&self) -> Vec<RunReport> {
        self.completed()
            .into_iter()
            .filter_map(|c| c.report.clone())
            .collect()
    }

    /// True iff every cell resolved from the result store.
    pub fn all_cached(&self) -> bool {
        !self.cells.is_empty() && self.cells.iter().all(|c| c.cached)
    }

    /// Cells the scheduler stopped before their full round budget.
    pub fn stopped_early(&self) -> Vec<&CellRun> {
        self.cells
            .iter()
            .filter(|c| c.report.as_ref().map(|r| r.stopped_early).unwrap_or(false))
            .collect()
    }

    /// Total FL rounds represented across all cell reports. On a fresh
    /// (uncached) campaign this equals the rounds the engine actually
    /// executed — the ASHA-vs-grid savings measure.
    pub fn total_rounds(&self) -> u64 {
        self.cells
            .iter()
            .filter_map(|c| c.report.as_ref())
            .map(|r| r.rounds_completed())
            .sum()
    }

    /// `"<cell>: <error>"` lines for every failed cell, in expansion order
    /// (shared by the CLI's exit message and the experiment runner).
    pub fn failure_lines(&self) -> Vec<String> {
        self.failed()
            .iter()
            .map(|c| {
                format!(
                    "{}: {}",
                    c.cell.name,
                    c.error.as_deref().unwrap_or("unknown error")
                )
            })
            .collect()
    }

    /// One-line summary (the CI smoke jobs grep this). The `stopped early`
    /// clause only appears when a scheduler actually stopped cells, so grid
    /// campaigns keep their historical summary byte-for-byte.
    pub fn summary(&self) -> String {
        let cached = self.cells.iter().filter(|c| c.cached).count();
        let failed = self.failed().len();
        let ran = self.cells.len() - cached - failed;
        let stopped = self.stopped_early().len();
        let mut line = format!(
            "campaign '{}': {} cells — {} cached, {} run, {} failed",
            self.name,
            self.cells.len(),
            cached,
            ran,
            failed
        );
        if stopped > 0 {
            line.push_str(&format!(", {stopped} stopped early"));
        }
        line
    }
}

/// Expand and execute a campaign against a result store, dispatching on
/// `campaign.scheduler` (grid runs everything; asha stops the bottom
/// quantile at each rung — see [`crate::campaign::asha`]).
pub fn run(rt: Arc<Runtime>, spec: &CampaignSpec, store: &ResultStore) -> Result<CampaignOutcome> {
    run_with_options(rt, spec, store, false)
}

/// Like [`run`], but with `refresh = true` every cell re-executes and
/// overwrites its store entry even when cached — for measurement contexts
/// (the figure benches) where serving a stored first-run wall clock would
/// report stale performance numbers. Refresh is a grid-only notion: an
/// adaptive scheduler re-measuring stopped cells is a contradiction.
pub fn run_with_options(
    rt: Arc<Runtime>,
    spec: &CampaignSpec,
    store: &ResultStore,
    refresh: bool,
) -> Result<CampaignOutcome> {
    if spec.scheduler.kind == SchedulerKind::Asha {
        if refresh {
            anyhow::bail!(
                "campaign '{}': refresh (FLSIM_REFRESH) requires the grid scheduler",
                spec.name
            );
        }
        return crate::campaign::asha::run_asha(rt, spec, store);
    }
    let cells = grid::expand(spec)?;

    // Resolve cache hits up front (serial — cheap file probes), collecting
    // the misses for the scheduler.
    let mut slots: Vec<Option<CellRun>> = vec![None; cells.len()];
    let mut misses: Vec<usize> = Vec::new();
    for (i, cell) in cells.iter().enumerate() {
        match if refresh { None } else { store.get(&cell.key) } {
            Some(report) => {
                slots[i] = Some(CellRun {
                    cell: cell.clone(),
                    cached: true,
                    report: Some(report),
                    error: None,
                });
            }
            None => misses.push(i),
        }
    }

    if !misses.is_empty() {
        let workers = spec.effective_jobs().min(misses.len()).max(1);
        let next = AtomicUsize::new(0);
        let done: Mutex<Vec<(usize, CellOutcome)>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..workers {
                let rt = rt.clone();
                let next = &next;
                let done = &done;
                let misses = &misses;
                let cells = &cells;
                s.spawn(move || loop {
                    let slot = next.fetch_add(1, Ordering::Relaxed);
                    if slot >= misses.len() {
                        break;
                    }
                    let i = misses[slot];
                    let cell = &cells[i];
                    println!(
                        "campaign[{}]: run  {} ({})",
                        spec.name,
                        cell.name,
                        &cell.key[..12]
                    );
                    let t0 = std::time::Instant::now();
                    let outcome = match run_cell_resumable(&rt, cell, store, &spec.name) {
                        Ok(report) => match store.commit(
                            &cell.key,
                            CellOutcome::new(&cell.job, &report)
                                .cell(&cell.name)
                                .campaign(&spec.name),
                        ) {
                            Ok(_) => {
                                println!(
                                    "campaign[{}]: done {} in {:.1}s (acc {:.3})",
                                    spec.name,
                                    cell.name,
                                    t0.elapsed().as_secs_f64(),
                                    report.final_accuracy()
                                );
                                CellRun {
                                    cell: cell.clone(),
                                    cached: false,
                                    report: Some(report),
                                    error: None,
                                }
                            }
                            Err(e) => CellRun {
                                cell: cell.clone(),
                                cached: false,
                                report: Some(report),
                                error: Some(format!("persisting result: {e:#}")),
                            },
                        },
                        Err(e) => {
                            println!("campaign[{}]: FAIL {} — {e:#}", spec.name, cell.name);
                            CellRun {
                                cell: cell.clone(),
                                cached: false,
                                report: None,
                                error: Some(format!("{e:#}")),
                            }
                        }
                    };
                    done.lock().unwrap().push((i, outcome));
                });
            }
        });
        for (i, outcome) in done.into_inner().unwrap() {
            slots[i] = Some(outcome);
        }
    }

    Ok(CampaignOutcome {
        name: spec.name.clone(),
        cells: slots
            .into_iter()
            .map(|s| s.expect("every cell resolves to an outcome"))
            .collect(),
    })
}

/// Execute one cell to its full round budget, resuming from a stored
/// rung-stopped prefix + checkpoint blob when the job is checkpointable
/// (see [`RunHandle::checkpointable`]) instead of replaying from round 1.
/// Any defect in the stored state — missing blob, depth mismatch, resume
/// error — falls back to a scratch run: slower, never wrong. Shared by the
/// grid runner and the worker drain.
pub(crate) fn run_cell_resumable(
    rt: &Arc<Runtime>,
    cell: &Cell,
    store: &ResultStore,
    campaign: &str,
) -> Result<RunReport> {
    match resume_handle(rt, cell, store, cell.job.rounds, campaign) {
        Ok(Some(mut handle)) => {
            let status = handle.advance(&RunControl::unbounded())?;
            debug_assert_eq!(status, RunStatus::Completed);
            return handle.finish();
        }
        Ok(None) => {}
        Err(e) => {
            // A broken checkpoint never fails the cell — scratch re-run.
            println!(
                "campaign[{campaign}]: checkpoint for {} unusable ({e:#}), running from scratch",
                cell.name
            );
        }
    }
    Orchestrator::new(rt.clone()).run(&cell.job, RunOptions::default())
}

/// Try to reconstruct a paused run of `cell` from the store (partial entry
/// + matching checkpoint, strictly shallower than `target`). `Ok(None)`
/// means "no usable checkpoint — run from scratch"; only resuming itself
/// can error, and callers may treat even that as a scratch fallback.
pub(crate) fn resume_handle(
    rt: &Arc<Runtime>,
    cell: &Cell,
    store: &ResultStore,
    target: u64,
    campaign: &str,
) -> Result<Option<crate::orchestrator::RunHandle>> {
    if !RunHandle::checkpointable(&cell.job) {
        return Ok(None);
    }
    let Some(prefix) = store.get_at_least(&cell.key, 1) else {
        return Ok(None);
    };
    if !prefix.stopped_early || prefix.rounds_completed() >= target {
        return Ok(None);
    }
    let Some(ckpt) = store.get_checkpoint(&cell.key) else {
        return Ok(None);
    };
    if ckpt.rounds != prefix.rounds_completed() {
        // Blob and entry disagree (e.g. a torn pair of generations):
        // scratch is the safe path.
        return Ok(None);
    }
    let handle = RunHandle::resume(
        rt.clone(),
        &cell.job,
        crate::controller::sync::FaultPlan::none(),
        &prefix,
        &ckpt.params,
    )?;
    println!(
        "campaign[{campaign}]: resume {} from round {} (checkpointed rung)",
        cell.name,
        ckpt.rounds + 1
    );
    Ok(Some(handle))
}
