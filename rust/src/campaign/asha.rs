//! Successive-halving campaign scheduling (the ASHA family) over the
//! cancellable round loop.
//!
//! Cells climb a rung ladder of round budgets `min_rounds · eta^k` (capped
//! at the job's full budget). At every rung the scheduler ranks the still-
//! running cells by the configured metric **at the rung round** and stops
//! the bottom quantile — only `max(1, n/eta)` cells are promoted to the
//! next rung. A stopped cell returns a valid partial [`RunReport`] (marked
//! `stopped_early`, `rounds_completed` recorded) that is persisted as a
//! rung-level cache entry.
//!
//! Three properties are contractual (test-enforced by
//! `rust/tests/campaign.rs` and the `asha-smoke` CI job):
//!
//! * **Determinism.** Rung decisions are *synchronous*: every surviving
//!   cell reaches the rung round before any cell is stopped, metrics are
//!   ranked with ties broken by expansion order, and per-round metrics are
//!   bitwise-reproducible — so the promoted cell set is a pure function of
//!   `(spec, seed)`, independent of the `campaign.jobs` worker count.
//!   (A fully asynchronous ASHA promotes on completion order; that breaks
//!   the determinism contract, so FLsim runs the synchronous variant.)
//! * **No recomputation within a run.** Promoted cells keep their paused
//!   [`RunHandle`] between rungs; deepening a cell resumes its live state
//!   rather than replaying earlier rounds.
//! * **Rung-level caching.** A stopped cell's prefix report is stored under
//!   the cell's (full-config) key — with a checkpoint blob (the global
//!   model at the stop round) when the job is checkpointable. Re-running
//!   the campaign replays every rung decision from the store — zero engine
//!   executions — and a later campaign that promotes the cell deeper
//!   resumes it from the checkpointed rung (scratch replay when no sound
//!   checkpoint exists) and *upgrades* the entry (never downgrades; see
//!   [`ResultStore::commit`]).
//!
//! Per-round metrics stream from the round loop to the scheduler over an
//! mpsc channel (the orchestrator's `RunControl::on_round` sink), so rung
//! decisions read live metrics as rounds commit rather than waiting on
//! finished reports.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::campaign::cache::{CellOutcome, ResultStore};
use crate::campaign::checkpoint::Checkpoint;
use crate::campaign::grid;
use crate::campaign::runner::{self, CampaignOutcome, CellRun};
use crate::campaign::spec::CampaignSpec;
use crate::controller::sync::FaultPlan;
use crate::metrics::report::RunReport;
use crate::orchestrator::{RunControl, RunHandle};
use crate::runtime::pjrt::Runtime;

/// What the scheduler knows about one cell while the campaign runs.
struct CellState {
    /// The engine executed at least one round for this cell this process
    /// (`false` = every rung was served from the result store).
    executed: bool,
    /// Paused live run (present only while the cell is being deepened).
    handle: Option<RunHandle>,
    /// Deepest stored report serving this cell from the cache.
    cached: Option<RunReport>,
    /// Set once the cell leaves the ladder: its final (possibly partial)
    /// report.
    report: Option<RunReport>,
    error: Option<String>,
}

impl CellState {
    fn new() -> CellState {
        CellState {
            executed: false,
            handle: None,
            cached: None,
            report: None,
            error: None,
        }
    }

    /// Still climbing the ladder (not failed, not stopped, not complete).
    fn alive(&self) -> bool {
        self.error.is_none() && self.report.is_none()
    }
}

/// Execute a campaign under the ASHA scheduler. The outcome mirrors the
/// grid runner's: one [`CellRun`] per expanded cell, in expansion order;
/// stopped cells carry `stopped_early` partial reports.
pub fn run_asha(
    rt: Arc<Runtime>,
    spec: &CampaignSpec,
    store: &ResultStore,
) -> Result<CampaignOutcome> {
    let cells = grid::expand(spec)?;
    let sched = spec.scheduler;
    let max_rounds = cells.iter().map(|c| c.job.rounds).max().unwrap_or(1);
    let ladder = sched.ladder(max_rounds);

    let mut states: Vec<CellState> = cells.iter().map(|_| CellState::new()).collect();
    // Live metric table: (cell index, round) -> decision metric, fed by the
    // per-round streaming channel (fresh rounds) and the result store
    // (replayed rounds).
    let mut metrics: BTreeMap<(usize, u64), f64> = BTreeMap::new();

    for (rung, &budget) in ladder.iter().enumerate() {
        // ------------------------------------------------------------------
        // 1. Resolve this rung from the cache where possible; collect the
        //    cells that must execute.
        // ------------------------------------------------------------------
        let mut work: Vec<(usize, u64)> = Vec::new(); // (cell, target rounds)
        for (i, cell) in cells.iter().enumerate() {
            if !states[i].alive() {
                continue;
            }
            let target = budget.min(cell.job.rounds);
            if states[i].handle.is_some() {
                work.push((i, target));
                continue;
            }
            // The report cached at an earlier rung may already be deep
            // enough — no need to re-read and re-parse the store entry.
            let deep_enough = |r: &RunReport| {
                if target == cell.job.rounds {
                    !r.stopped_early
                } else {
                    !r.stopped_early || r.rounds_completed() >= target
                }
            };
            if states[i].cached.as_ref().map(&deep_enough).unwrap_or(false) {
                continue;
            }
            let hit = if target == cell.job.rounds {
                store.get(&cell.key)
            } else {
                store.get_at_least(&cell.key, target)
            };
            match hit {
                Some(rep) => {
                    // Backfill the whole stored series (not just this rung):
                    // every round is prefix-deterministic, and a deeper
                    // entry then serves later rung decisions without
                    // re-reading the store.
                    for r in 1..=rep.rounds_completed() {
                        if let Some(v) = rep.metric_at(r, |m| sched.metric_of(m)) {
                            metrics.insert((i, r), v);
                        }
                    }
                    states[i].cached = Some(rep);
                }
                None => {
                    // Promoted past its stored depth (or never stored): run
                    // from scratch to the deeper budget — determinism makes
                    // the replayed prefix bitwise-identical.
                    states[i].cached = None;
                    work.push((i, target));
                }
            }
        }

        // ------------------------------------------------------------------
        // 2. Advance the executing cells on the job-level worker pool,
        //    streaming per-round metrics back over the channel.
        // ------------------------------------------------------------------
        if !work.is_empty() {
            println!(
                "campaign[{}]: rung {} (budget {} rounds) — {} cells to run",
                spec.name,
                rung + 1,
                budget,
                work.len()
            );
            let (tx, rx) = mpsc::channel::<(usize, u64, f64)>();
            let slots: Vec<Mutex<CellSlot>> = states
                .iter_mut()
                .map(|s| {
                    Mutex::new(CellSlot { handle: s.handle.take(), error: None, executed: false })
                })
                .collect();
            let workers = spec.effective_jobs().min(work.len()).max(1);
            let next = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    let rt = rt.clone();
                    let tx = tx.clone();
                    let next = &next;
                    let work = &work;
                    let slots = &slots;
                    let cells = &cells;
                    scope.spawn(move || loop {
                        let slot = next.fetch_add(1, Ordering::Relaxed);
                        if slot >= work.len() {
                            break;
                        }
                        let (i, target) = work[slot];
                        let cell = &cells[i];
                        let mut guard = slots[i].lock().unwrap();
                        let mut handle = guard.handle.take();
                        guard.executed = true;
                        drop(guard);
                        let result = (|| -> Result<RunHandle> {
                            let mut h = match handle.take() {
                                Some(h) => h,
                                // No live handle: prefer the checkpointed
                                // rung from a previous campaign/worker over
                                // a scratch replay (a broken checkpoint
                                // just falls back).
                                None => match runner::resume_handle(
                                    &rt, cell, store, target, &spec.name,
                                ) {
                                    Ok(Some(h)) => h,
                                    _ => RunHandle::start(
                                        rt.clone(),
                                        &cell.job,
                                        FaultPlan::none(),
                                    )?,
                                },
                            };
                            let sink_tx = Mutex::new(tx.clone());
                            let ctl = RunControl {
                                round_budget: Some(target),
                                on_round: Some(Box::new(move |m| {
                                    let v = sched.metric_of(m);
                                    let _ = sink_tx.lock().unwrap().send((i, m.round, v));
                                })),
                                ..RunControl::default()
                            };
                            h.advance(&ctl)?;
                            Ok(h)
                        })();
                        let mut guard = slots[i].lock().unwrap();
                        match result {
                            Ok(h) => guard.handle = Some(h),
                            Err(e) => {
                                println!("campaign[{}]: FAIL {} — {e:#}", spec.name, cell.name);
                                guard.error = Some(format!("{e:#}"));
                            }
                        }
                    });
                }
            });
            drop(tx);
            for (i, round, value) in rx.try_iter() {
                metrics.insert((i, round), value);
            }
            for (i, slot) in slots.into_iter().enumerate() {
                let slot = slot.into_inner().unwrap();
                if slot.executed {
                    states[i].executed = true;
                }
                states[i].handle = slot.handle;
                if let Some(e) = slot.error {
                    states[i].error = Some(e);
                }
            }
        }

        // ------------------------------------------------------------------
        // 3. Finalize cells whose full budget this rung reached.
        // ------------------------------------------------------------------
        for (i, cell) in cells.iter().enumerate() {
            if !states[i].alive() || budget < cell.job.rounds {
                continue;
            }
            let st = &mut states[i];
            if let Some(handle) = st.handle.take() {
                match handle.finish() {
                    Ok(report) => match store.commit(
                        &cell.key,
                        CellOutcome::new(&cell.job, &report)
                            .cell(&cell.name)
                            .campaign(&spec.name),
                    ) {
                        Ok(_) => {
                            println!(
                                "campaign[{}]: done {} ({} rounds, acc {:.3})",
                                spec.name,
                                cell.name,
                                report.rounds_completed(),
                                report.final_accuracy()
                            );
                            st.report = Some(report);
                        }
                        Err(e) => {
                            st.report = Some(report);
                            st.error = Some(format!("persisting result: {e:#}"));
                        }
                    },
                    Err(e) => st.error = Some(format!("{e:#}")),
                }
            } else if let Some(rep) = st.cached.clone() {
                st.report = Some(rep);
            } else {
                st.error = Some("internal: cell left rung with neither handle nor cache".into());
            }
        }

        // ------------------------------------------------------------------
        // 4. Rung decision: rank the continuing cells by their metric at
        //    the rung round and stop the bottom quantile.
        // ------------------------------------------------------------------
        let continuing: Vec<usize> = (0..cells.len())
            .filter(|&i| states[i].alive() && budget < cells[i].job.rounds)
            .collect();
        if continuing.is_empty() || rung + 1 >= ladder.len() {
            continue;
        }
        let mut ranked: Vec<(usize, f64)> = Vec::with_capacity(continuing.len());
        for &i in &continuing {
            let v = metrics.get(&(i, budget)).copied().ok_or_else(|| {
                anyhow!(
                    "campaign '{}': cell '{}' reached rung budget {budget} without a \
                     recorded metric",
                    spec.name,
                    cells[i].name
                )
            })?;
            ranked.push((i, sched.score(v)));
        }
        // Descending score with a *total* order: a NaN metric (diverged
        // cell) always ranks worst, and ties break by expansion order — so
        // the sort is deterministic and never promotes a diverged cell over
        // a healthy one.
        ranked.sort_by(|a, b| {
            match (a.1.is_nan(), b.1.is_nan()) {
                (true, true) => std::cmp::Ordering::Equal,
                (true, false) => std::cmp::Ordering::Greater, // a after b
                (false, true) => std::cmp::Ordering::Less,
                (false, false) => b.1.partial_cmp(&a.1).expect("both finite-or-inf"),
            }
            .then(a.0.cmp(&b.0))
        });
        let keep = sched.survivors(ranked.len());
        for &(i, score) in &ranked[keep..] {
            let cell = &cells[i];
            let st = &mut states[i];
            let partial = match st.handle.take() {
                Some(handle) => {
                    let report = handle.partial_report();
                    // Persist the model alongside the prefix (checkpointable
                    // jobs only) so a later campaign deepens this cell from
                    // its rung instead of round 1.
                    let ckpt = handle.checkpoint_params().map(|p| {
                        Checkpoint::new(&cell.key, report.rounds_completed(), p.to_vec())
                    });
                    let mut outcome = CellOutcome::new(&cell.job, &report)
                        .cell(&cell.name)
                        .campaign(&spec.name);
                    if let Some(c) = &ckpt {
                        outcome = outcome.checkpoint(c);
                    }
                    if let Err(e) = store.commit(&cell.key, outcome) {
                        st.error = Some(format!("persisting partial result: {e:#}"));
                        continue;
                    }
                    report
                }
                None => match &st.cached {
                    Some(rep) => rep.truncated(budget),
                    None => {
                        st.error =
                            Some("internal: stopped cell with neither handle nor cache".into());
                        continue;
                    }
                },
            };
            println!(
                "campaign[{}]: stop {} at rung {} ({} rounds, score {:.4})",
                spec.name,
                cell.name,
                rung + 1,
                partial.rounds_completed(),
                score
            );
            st.report = Some(partial);
        }
    }

    Ok(CampaignOutcome {
        name: spec.name.clone(),
        cells: cells
            .into_iter()
            .zip(states)
            .map(|(cell, st)| {
                let cached = !st.executed && st.error.is_none() && st.report.is_some();
                CellRun {
                    cell,
                    cached,
                    report: st.report,
                    error: st.error,
                }
            })
            .collect(),
    })
}

/// Per-cell slot shared with the rung worker pool.
struct CellSlot {
    handle: Option<RunHandle>,
    error: Option<String>,
    executed: bool,
}
