//! The campaign engine: declarative experiment sweeps with job-level
//! scheduling and a content-addressed result cache (the paper's
//! "streamlined benchmarking" promise, industrialized).
//!
//! A campaign is a base [`crate::config::job::JobConfig`] plus sweep axes
//! and/or explicit cells ([`spec`]), expanded into a deterministic grid of
//! concrete jobs ([`grid`]), executed on a scoped job-level worker pool
//! ([`runner`]) that resumes completed cells from a content-addressed
//! on-disk store ([`cache`]), and aggregated into one CSV/JSON report
//! ([`report`]). The `campaign.scheduler:` section picks how cells spend
//! their round budgets: `grid` (default — every cell runs to completion)
//! or `asha` (successive halving — the bottom quantile is stopped at each
//! rung, [`asha`]).
//!
//! Pipeline: **spec → grid → schedule (cache-aware) → store → report.**
//!
//! Guarantees (all test-enforced by `rust/tests/campaign.rs`):
//! * expansion is a pure function of the spec (sorted axes, listed value
//!   order, duplicate cells deduplicated);
//! * results are bitwise-identical at any schedule (`campaign.jobs` ×
//!   `job.parallelism` move only the wall clock);
//! * re-running an unchanged campaign is all cache hits, and the resumed
//!   report is byte-identical to the first run's;
//! * one failing cell never discards the others — completed cells persist
//!   as they finish and the CLI exits non-zero with the failure list.

//! Multi-process: N `flsim campaign worker` processes pointed at one
//! shared store drain a campaign cooperatively with no coordinator —
//! lease-based cell claiming ([`lease`]), checkpointed rung promotion
//! ([`checkpoint`]), and store-replayed (elastic-deterministic) ASHA
//! decisions ([`worker`]).

pub mod asha;
pub mod cache;
pub mod checkpoint;
pub mod grid;
pub mod lease;
pub mod report;
pub mod runner;
pub mod spec;
pub mod worker;

pub use cache::{cell_key, CellOutcome, GcOptions, GcStats, ResultStore, ENGINE_VERSION};
pub use checkpoint::Checkpoint;
pub use grid::{expand, Cell};
pub use lease::{LeaseConfig, LeaseInfo};
pub use report::{CampaignReport, FrontierReport};
pub use runner::{run, run_with_options, CampaignOutcome, CellRun};
pub use spec::{
    CampaignBuilder, CampaignSpec, CellSpec, RungMetric, RungMode, SchedulerKind, SchedulerSpec,
};
pub use worker::{drain, WorkerOptions};
