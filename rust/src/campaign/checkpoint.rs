//! Resumable model state for rung-stopped cells (store schema v3).
//!
//! For jobs whose only cross-round mutable state is the global model —
//! central aggregation (fedavg / fedprox / dpfl) on the client-server
//! flow, eager population, no blockchain (see
//! [`crate::orchestrator::RunHandle::checkpointable`]) — a partial report
//! plus the global parameter vector at the stop round is a *complete*
//! resume point: everything else each round (client sampling, per-node RNG
//! streams, fault and churn draws, DP accounting, network metering) is
//! re-derived deterministically from the config. A checkpoint blob stored
//! alongside a rung-stopped entry therefore lets a later campaign — or
//! another worker process — deepen the cell from its rung instead of
//! replaying it from round 1.
//!
//! Parameters are serialized as the raw IEEE-754 bit patterns (8 lowercase
//! hex digits per `f32`), not decimal floats: resume must restore the
//! model **bit-exactly** or the deepened rounds would diverge from the
//! determinism contract. A corrupt, truncated, or stale-engine blob reads
//! as a miss — the cell just re-runs from scratch, never wrong.

use anyhow::{bail, Result};

use crate::campaign::cache::ENGINE_VERSION;
use crate::util::json::Json;

/// Schema tag of one checkpoint blob (`<shard>/<key>.ckpt`).
pub const CHECKPOINT_SCHEMA: &str = "flsim-ckpt-v1";

/// A rung-stopped cell's resumable state: the global model exactly as it
/// stood after `rounds` completed rounds of the run keyed by `key`.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// The cell's content-addressed store key.
    pub key: String,
    /// Rounds completed when the snapshot was taken — must equal the
    /// companion partial report's depth.
    pub rounds: u64,
    /// Global model parameters, bit-exact.
    pub params: Vec<f32>,
}

impl Checkpoint {
    pub fn new(key: &str, rounds: u64, params: Vec<f32>) -> Checkpoint {
        Checkpoint {
            key: key.to_string(),
            rounds,
            params,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::from(CHECKPOINT_SCHEMA)),
            ("key", Json::from(self.key.as_str())),
            ("engine", Json::from(ENGINE_VERSION)),
            ("rounds", Json::from(self.rounds as f64)),
            ("n_params", Json::from(self.params.len())),
            ("params_hex", Json::from(encode_params(&self.params).as_str())),
        ])
    }

    /// Strict parse: schema, engine, and length mismatches are all errors
    /// (callers treat any error as a cache miss).
    pub fn from_json(doc: &Json) -> Result<Checkpoint> {
        let field = |k: &str| -> Result<&Json> {
            doc.get(k)
                .ok_or_else(|| anyhow::anyhow!("checkpoint: missing field '{k}'"))
        };
        if field("schema")?.as_str() != Some(CHECKPOINT_SCHEMA) {
            bail!("checkpoint: unknown schema");
        }
        if field("engine")?.as_str() != Some(ENGINE_VERSION) {
            bail!("checkpoint: stale engine version");
        }
        let key = field("key")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("checkpoint: key is not a string"))?
            .to_string();
        let rounds = field("rounds")?
            .as_f64()
            .filter(|r| r.fract() == 0.0 && *r >= 0.0)
            .ok_or_else(|| anyhow::anyhow!("checkpoint: bad rounds"))? as u64;
        let n = field("n_params")?
            .as_f64()
            .filter(|n| n.fract() == 0.0 && *n >= 0.0)
            .ok_or_else(|| anyhow::anyhow!("checkpoint: bad n_params"))? as usize;
        let hex = field("params_hex")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("checkpoint: params_hex is not a string"))?;
        let params = decode_params(hex)?;
        if params.len() != n {
            bail!(
                "checkpoint: params_hex holds {} values, n_params says {n}",
                params.len()
            );
        }
        Ok(Checkpoint {
            key,
            rounds,
            params,
        })
    }
}

/// 8 lowercase hex digits per parameter: the `f32`'s big-endian bits.
fn encode_params(params: &[f32]) -> String {
    let mut s = String::with_capacity(params.len() * 8);
    for p in params {
        s.push_str(&format!("{:08x}", p.to_bits()));
    }
    s
}

fn decode_params(hex: &str) -> Result<Vec<f32>> {
    if hex.len() % 8 != 0 {
        bail!("checkpoint: params_hex length {} is not a multiple of 8", hex.len());
    }
    let bytes = hex.as_bytes();
    let mut out = Vec::with_capacity(hex.len() / 8);
    for chunk in bytes.chunks_exact(8) {
        let mut bits: u32 = 0;
        for &b in chunk {
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| anyhow::anyhow!("checkpoint: non-hex digit in params_hex"))?;
            bits = (bits << 4) | d;
        }
        out.push(f32::from_bits(bits));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_roundtrip_bit_exactly() {
        // Include values a decimal codec would mangle: subnormals, NaN with
        // payload, negative zero, infinities.
        let params = vec![
            0.1f32,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::from_bits(0x7fc0_dead), // NaN payload
            f32::from_bits(1),           // smallest subnormal
            1.0e-38,
            3.141_592_7,
        ];
        let ckpt = Checkpoint::new(&"ab".repeat(32), 3, params.clone());
        let back = Checkpoint::from_json(&ckpt.to_json()).unwrap();
        assert_eq!(back.key, ckpt.key);
        assert_eq!(back.rounds, 3);
        assert_eq!(back.params.len(), params.len());
        for (a, b) in params.iter().zip(&back.params) {
            assert_eq!(a.to_bits(), b.to_bits(), "bit-exact round trip");
        }
    }

    #[test]
    fn corrupt_blobs_are_errors() {
        let ckpt = Checkpoint::new(&"cd".repeat(32), 2, vec![1.0, 2.0]);
        let mut doc = ckpt.to_json();
        assert!(Checkpoint::from_json(&doc).is_ok());
        // Truncated hex.
        doc = Json::parse(
            &doc.to_string()
                .replace(&encode_params(&[1.0f32, 2.0]), "3f80"),
        )
        .unwrap();
        assert!(Checkpoint::from_json(&doc).is_err());
        // Wrong schema.
        let other = Json::parse(&ckpt.to_json().to_string().replace(CHECKPOINT_SCHEMA, "x"))
            .unwrap();
        assert!(Checkpoint::from_json(&other).is_err());
    }
}
