//! The declarative campaign specification: a complete base [`JobConfig`]
//! plus sweep *axes* (expanded as a cartesian grid) and/or explicit *cells*
//! (named per-cell override sets, for sweeps that are not a pure grid —
//! e.g. Fig 11's paired strategy/topology cells).
//!
//! A spec loads from YAML — the regular job-config document with two extra
//! sections — or is built programmatically through [`CampaignSpec::builder`]:
//!
//! ```yaml
//! campaign:
//!   name: smoke
//!   jobs: 2                     # outer job-level parallelism (0 = auto)
//! axes:
//!   strategy: [fedavg, fedprox]
//!   seed: [1, 2]
//! cells:                        # optional explicit cells (appended after
//!   - name: mesh                # the grid; keys other than `name` are
//!     strategy: fedstellar      # axis overrides)
//! # ... followed by a complete base job config (job/dataset/strategy/
//! # topology/...) exactly as `flsim run --config` takes it.
//! ```
//!
//! Axis *names* expand in sorted order and axis *values* in listed order,
//! so the cell list is deterministic no matter how the YAML is formatted.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::config::job::JobConfig;
use crate::data::dataset::Distribution;
use crate::strategy::StrategyKind;
use crate::topology::TopologyKind;
use crate::util::yaml::Yaml;

/// Which campaign scheduler drives the cells.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Every cell runs to its full round budget (the default; byte-for-byte
    /// the pre-scheduler behaviour).
    Grid,
    /// Successive halving over rung budgets: cells run to
    /// `min_rounds · eta^k` rounds and the bottom quantile is stopped at
    /// each rung (see [`crate::campaign::asha`]).
    Asha,
}

/// The per-round series rung decisions rank cells by.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RungMetric {
    Accuracy,
    Loss,
}

/// Whether a larger or smaller metric value survives a rung.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RungMode {
    Max,
    Min,
}

/// The `campaign.scheduler:` section.
///
/// ```yaml
/// campaign:
///   scheduler:
///     kind: asha        # grid (default) | asha
///     eta: 2            # rung growth & survival factor (>= 2)
///     min_rounds: 1     # first rung budget (>= 1)
///     metric: accuracy  # accuracy | loss
///     mode: max         # max | min (defaults to the metric's direction)
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedulerSpec {
    pub kind: SchedulerKind,
    /// Rung growth factor: budgets are `min_rounds · eta^k`, and
    /// `max(1, n/eta)` of `n` surviving cells are promoted at each rung.
    pub eta: u64,
    /// First rung budget (rounds every cell runs before any cell is
    /// stopped).
    pub min_rounds: u64,
    pub metric: RungMetric,
    pub mode: RungMode,
}

impl Default for SchedulerSpec {
    fn default() -> Self {
        SchedulerSpec {
            kind: SchedulerKind::Grid,
            eta: 2,
            min_rounds: 1,
            metric: RungMetric::Accuracy,
            mode: RungMode::Max,
        }
    }
}

impl SchedulerSpec {
    pub fn from_yaml(y: &Yaml) -> Result<SchedulerSpec> {
        // A present-but-wrong-typed field must error like any other bad
        // value, not silently fall back to the default.
        let str_field = |name: &str| -> Result<Option<&str>> {
            match y.get(name) {
                None => Ok(None),
                Some(v) => v.as_str().map(Some).ok_or_else(|| {
                    anyhow!("campaign.scheduler.{name}: expected a string, got {v:?}")
                }),
            }
        };
        let int_field = |name: &str| -> Result<Option<i64>> {
            match y.get(name) {
                None => Ok(None),
                Some(v) => v.as_i64().map(Some).ok_or_else(|| {
                    anyhow!("campaign.scheduler.{name}: expected an integer, got {v:?}")
                }),
            }
        };
        let kind = match str_field("kind")?.unwrap_or("grid") {
            "grid" => SchedulerKind::Grid,
            "asha" | "sha" | "successive_halving" => SchedulerKind::Asha,
            other => bail!("campaign.scheduler.kind: unknown scheduler '{other}' (grid|asha)"),
        };
        let eta = int_field("eta")?.unwrap_or(2);
        let min_rounds = int_field("min_rounds")?.unwrap_or(1);
        let metric = match str_field("metric")?.unwrap_or("accuracy") {
            "accuracy" | "test_accuracy" => RungMetric::Accuracy,
            "loss" | "test_loss" => RungMetric::Loss,
            other => bail!("campaign.scheduler.metric: unknown metric '{other}' (accuracy|loss)"),
        };
        let mode = match str_field("mode")? {
            None => match metric {
                RungMetric::Accuracy => RungMode::Max,
                RungMetric::Loss => RungMode::Min,
            },
            Some("max") => RungMode::Max,
            Some("min") => RungMode::Min,
            Some(other) => bail!("campaign.scheduler.mode: unknown mode '{other}' (max|min)"),
        };
        let spec = SchedulerSpec {
            kind,
            eta: eta.max(0) as u64,
            min_rounds: min_rounds.max(0) as u64,
            metric,
            mode,
        };
        if eta < 2 {
            bail!("campaign.scheduler.eta must be >= 2, got {eta}");
        }
        if min_rounds < 1 {
            bail!("campaign.scheduler.min_rounds must be >= 1, got {min_rounds}");
        }
        Ok(spec)
    }

    /// The rung budget ladder for a job of `total` rounds: strictly
    /// increasing `min_rounds · eta^k`, capped at — and always ending on —
    /// `total`. `min_rounds >= total` degenerates to a single full-budget
    /// rung (no cell is ever stopped).
    pub fn ladder(&self, total: u64) -> Vec<u64> {
        // Defensive: a programmatically-built spec with eta < 2 must not
        // hang the ladder (the YAML/CLI paths already reject it).
        let eta = self.eta.max(2);
        let mut out = Vec::new();
        let mut b = self.min_rounds.min(total).max(1);
        loop {
            out.push(b);
            if b >= total {
                return out;
            }
            b = b.saturating_mul(eta).min(total);
        }
    }

    /// Sign-adjusted rung score: sorting *descending* by this ranks the
    /// survivors first under either mode.
    pub fn score(&self, value: f64) -> f64 {
        match self.mode {
            RungMode::Max => value,
            RungMode::Min => -value,
        }
    }

    /// Read this scheduler's decision metric out of one round's metrics.
    pub fn metric_of(&self, m: &crate::metrics::report::RoundMetrics) -> f64 {
        match self.metric {
            RungMetric::Accuracy => m.test_accuracy,
            RungMetric::Loss => m.test_loss,
        }
    }

    /// How many of `alive` cells survive a rung decision.
    pub fn survivors(&self, alive: usize) -> usize {
        (alive / (self.eta.max(2) as usize)).max(1)
    }
}

/// An explicit cell: an optional name plus axis overrides applied to the
/// base job. YAML cells apply overrides in sorted key order (they come out
/// of a `BTreeMap`); builder cells apply them in listed order. Either way
/// the result is order-independent: every axis touches a disjoint knob, and
/// strategy↔topology reconciliation happens once per cell after all
/// overrides (see [`crate::campaign::grid::expand`]).
#[derive(Clone, Debug)]
pub struct CellSpec {
    pub name: Option<String>,
    pub overrides: Vec<(String, Yaml)>,
}

/// A declarative experiment sweep.
#[derive(Clone, Debug)]
pub struct CampaignSpec {
    pub name: String,
    /// The fully-resolved job every cell starts from.
    pub base: JobConfig,
    /// Sweep axes: axis name → values (BTreeMap ⇒ sorted axis order).
    pub axes: BTreeMap<String, Vec<Yaml>>,
    /// Explicit cells, appended after the grid.
    pub cells: Vec<CellSpec>,
    /// Job-level scheduler width: how many cells run concurrently
    /// (`0` = one per available core, `1` = serial — the default).
    pub jobs: usize,
    /// Which scheduler drives the cells (grid = run everything, asha =
    /// successive halving with rung-level early stopping).
    pub scheduler: SchedulerSpec,
}

impl CampaignSpec {
    pub fn builder(name: &str, base: JobConfig) -> CampaignBuilder {
        CampaignBuilder {
            spec: CampaignSpec {
                name: name.to_string(),
                base,
                axes: BTreeMap::new(),
                cells: Vec::new(),
                jobs: 1,
                scheduler: SchedulerSpec::default(),
            },
        }
    }

    pub fn from_yaml_str(src: &str) -> Result<CampaignSpec> {
        let y = Yaml::parse(src).map_err(|e| anyhow!("campaign spec: {e}"))?;
        Self::from_yaml(&y)
    }

    pub fn from_yaml_file(path: &str) -> Result<CampaignSpec> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading campaign spec {path}: {e}"))?;
        Self::from_yaml_str(&src)
    }

    pub fn from_yaml(y: &Yaml) -> Result<CampaignSpec> {
        // The base is the same document's regular job config — `campaign:`,
        // `axes:` and `cells:` are simply extra top-level sections.
        let base = JobConfig::from_yaml(y)?;

        let c = y.get("campaign").unwrap_or(&Yaml::Null);
        let name = c
            .get("name")
            .and_then(Yaml::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| base.name.clone());
        let jobs = match c.get("jobs").and_then(Yaml::as_i64).unwrap_or(1) {
            n if n < 0 => bail!("campaign.jobs must be >= 0 (0 = auto), got {n}"),
            n => n as usize,
        };
        let scheduler = match c.get("scheduler") {
            Some(s) => SchedulerSpec::from_yaml(s)?,
            None => SchedulerSpec::default(),
        };

        let mut axes = BTreeMap::new();
        if let Some(a) = y.get("axes") {
            let m = a
                .as_map()
                .ok_or_else(|| anyhow!("campaign spec: 'axes' must be a mapping"))?;
            for (axis, vals) in m {
                let vals = vals
                    .as_seq()
                    .ok_or_else(|| anyhow!("axis '{axis}': values must be a list"))?;
                if vals.is_empty() {
                    bail!("axis '{axis}': empty value list");
                }
                axes.insert(axis.clone(), vals.to_vec());
            }
        }

        let mut cells = Vec::new();
        if let Some(cs) = y.get("cells") {
            let seq = cs
                .as_seq()
                .ok_or_else(|| anyhow!("campaign spec: 'cells' must be a list"))?;
            for cy in seq {
                let m = cy
                    .as_map()
                    .ok_or_else(|| anyhow!("campaign spec: each cell must be a mapping"))?;
                let mut name = None;
                let mut overrides = Vec::new();
                for (k, v) in m {
                    if k == "name" {
                        name = v.as_str().map(str::to_string);
                    } else {
                        overrides.push((k.clone(), v.clone()));
                    }
                }
                cells.push(CellSpec { name, overrides });
            }
        }

        Ok(CampaignSpec {
            name,
            base,
            axes,
            cells,
            jobs,
            scheduler,
        })
    }

    /// The job scheduler's worker count: `jobs`, with `0` resolved to the
    /// number of available cores.
    pub fn effective_jobs(&self) -> usize {
        match self.jobs {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            n => n,
        }
    }
}

/// Fluent construction of a [`CampaignSpec`] from code (the experiment
/// ports and examples use this instead of YAML).
pub struct CampaignBuilder {
    spec: CampaignSpec,
}

impl CampaignBuilder {
    /// Add a sweep axis (replaces any previous axis of the same name).
    pub fn axis(mut self, name: &str, values: Vec<Yaml>) -> CampaignBuilder {
        self.spec.axes.insert(name.to_string(), values);
        self
    }

    /// Add a string-valued sweep axis.
    pub fn axis_strs(self, name: &str, values: &[&str]) -> CampaignBuilder {
        self.axis(name, values.iter().map(|v| Yaml::from(*v)).collect())
    }

    /// Add an integer-valued sweep axis.
    pub fn axis_ints(self, name: &str, values: &[i64]) -> CampaignBuilder {
        self.axis(name, values.iter().map(|v| Yaml::from(*v)).collect())
    }

    /// Add an explicit named cell with axis overrides.
    pub fn cell(mut self, name: &str, overrides: Vec<(&str, Yaml)>) -> CampaignBuilder {
        self.spec.cells.push(CellSpec {
            name: Some(name.to_string()),
            overrides: overrides
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        });
        self
    }

    /// Set the job-level scheduler width (0 = auto).
    pub fn jobs(mut self, jobs: usize) -> CampaignBuilder {
        self.spec.jobs = jobs;
        self
    }

    /// Select the campaign scheduler (grid / asha rung parameters).
    pub fn scheduler(mut self, scheduler: SchedulerSpec) -> CampaignBuilder {
        self.spec.scheduler = scheduler;
        self
    }

    /// Shorthand: ASHA with the given growth factor and first-rung budget,
    /// ranking by test accuracy (max).
    pub fn asha(self, eta: u64, min_rounds: u64) -> CampaignBuilder {
        self.scheduler(SchedulerSpec {
            kind: SchedulerKind::Asha,
            eta,
            min_rounds,
            ..SchedulerSpec::default()
        })
    }

    pub fn build(self) -> CampaignSpec {
        self.spec
    }
}

/// Apply one axis override to a job. The supported axis names are the
/// knobs the paper's evaluation grid sweeps (strategy × topology ×
/// partition × heterogeneity × seed) plus the obvious scale/training knobs.
pub fn apply_axis(job: &mut JobConfig, axis: &str, value: &Yaml) -> Result<()> {
    let want_str = || {
        value
            .as_str()
            .ok_or_else(|| anyhow!("axis '{axis}': expected a string, got {value:?}"))
    };
    let want_i64 = || {
        value
            .as_i64()
            .ok_or_else(|| anyhow!("axis '{axis}': expected an integer, got {value:?}"))
    };
    // Counts and seeds: a negative value must not wrap through `as u64`
    // (`rounds: [-1]` would otherwise loop for u64::MAX rounds).
    let want_nonneg = || -> Result<i64> {
        let v = want_i64()?;
        if v < 0 {
            return Err(anyhow!(
                "axis '{axis}': expected a non-negative integer, got {v}"
            ));
        }
        Ok(v)
    };
    let want_f64 = || {
        value
            .as_f64()
            .ok_or_else(|| anyhow!("axis '{axis}': expected a number, got {value:?}"))
    };
    match axis {
        "strategy" => {
            let name = want_str()?;
            // Re-selecting the base strategy keeps its configured
            // hyper-parameters (mu, sigma, ...); a *different* strategy has
            // no base hyper-params to inherit and parses with its defaults.
            // Strategy-mode ↔ topology reconciliation happens once per cell
            // in grid expansion, after all overrides — not here — so cell
            // behavior never depends on override order.
            if name != job.strategy.name() {
                job.strategy = StrategyKind::parse(name, &Yaml::Null)?;
            }
        }
        "topology" => job.topology = TopologyKind::parse(want_str()?)?,
        "backend" => job.backend = want_str()?.to_string(),
        "partition" => job.dataset.distribution = parse_partition(value)?,
        "seed" => job.seed = want_nonneg()? as u64,
        "rounds" => job.rounds = want_nonneg()? as u64,
        "clients" => job.n_clients = want_nonneg()? as usize,
        "workers" => job.n_workers = want_nonneg()? as usize,
        "dataset_n" => job.dataset.n = want_nonneg()? as usize,
        "heterogeneity" => job.heterogeneity = want_f64()?,
        "client_fraction" => job.client_fraction = want_f64()?,
        "learning_rate" => job.train.learning_rate = want_f64()? as f32,
        "local_epochs" => job.train.local_epochs = want_nonneg()? as usize,
        "hw_profile" | "hardware_profile" => {
            job.hw_profile = crate::aggregate::mean::ReductionOrder::parse(want_str()?)?;
        }
        "parallelism" => job.parallelism = want_nonneg()? as usize,
        "attack" => {
            job.adversary.attack = crate::config::adversary::AttackKind::parse(want_str()?)?;
        }
        "attack_fraction" => job.adversary.attack_fraction = want_f64()?,
        "attack_scale" => job.adversary.scale = want_f64()?,
        "robust_agg" => {
            job.robust_agg = crate::config::adversary::RobustAggConfig::parse_axis(want_str()?)?;
        }
        "churn" => {
            // Per-round availability: 1.0 (or anything above) turns churn
            // off; lower values keep the base config's `from_round` if one
            // was set, else start churning from round 1.
            let availability = want_f64()?;
            job.faults.churn = if availability >= 1.0 {
                None
            } else {
                Some(crate::config::adversary::ChurnConfig {
                    availability,
                    from_round: job.faults.churn.map(|c| c.from_round).unwrap_or(1),
                })
            };
        }
        "compress" => {
            job.channel.compress =
                crate::config::channel::ChannelConfig::parse_compress_axis(want_str()?)?;
        }
        "compress_bits" => {
            // Integer shorthand for quantization sweeps: 0 turns the stage
            // off, 1..=16 selects `quantize:<bits>`.
            let bits = want_nonneg()?;
            job.channel.compress = match bits {
                0 => crate::config::channel::CompressConfig::default(),
                b => crate::config::channel::ChannelConfig::parse_compress_axis(&format!(
                    "quantize:{b}"
                ))?,
            };
        }
        "dp_sigma" => {
            // Noise multiplier: 0.0 turns the dp stage off (the channel
            // identity); positive values keep the base config's clip/delta
            // if a dp section was set, else fill the documented defaults.
            let sigma = want_f64()?;
            job.channel.dp = if sigma <= 0.0 {
                None
            } else {
                let base = job.channel.dp.unwrap_or(crate::config::channel::DpConfig {
                    clip: crate::config::channel::DpConfig::DEFAULT_CLIP,
                    sigma,
                    delta: crate::config::channel::DpConfig::DEFAULT_DELTA,
                });
                Some(crate::config::channel::DpConfig { sigma, ..base })
            };
        }
        "secure_agg" => {
            // Unmasking threshold: 0 turns the stage off.
            let threshold = want_nonneg()?;
            job.channel.secure_agg = match threshold {
                0 => None,
                t => Some(crate::config::channel::SecureAggConfig {
                    threshold: t as usize,
                }),
            };
        }
        _ => bail!(
            "unknown campaign axis '{axis}' (supported: strategy topology backend partition \
             seed rounds clients workers dataset_n heterogeneity client_fraction \
             learning_rate local_epochs hw_profile parallelism attack attack_fraction \
             attack_scale robust_agg churn compress compress_bits dp_sigma secure_agg)"
        ),
    }
    Ok(())
}

/// Partition axis values: `iid`, `dirichlet`/`dirichlet:<alpha>`,
/// `shards`/`shards:<k>`, or the mapping form `{kind: dirichlet, alpha: x}`.
fn parse_partition(value: &Yaml) -> Result<Distribution> {
    if let Some(s) = value.as_str() {
        let (kind, param) = match s.split_once(':') {
            Some((k, p)) => (k, Some(p)),
            None => (s, None),
        };
        return Ok(match kind {
            "iid" | "uniform" => Distribution::Iid,
            "dirichlet" => Distribution::Dirichlet {
                alpha: match param {
                    Some(p) => p
                        .parse()
                        .map_err(|_| anyhow!("partition: bad dirichlet alpha '{p}'"))?,
                    None => 0.5,
                },
            },
            "shards" => Distribution::Shards {
                shards_per_client: match param {
                    Some(p) => p
                        .parse()
                        .map_err(|_| anyhow!("partition: bad shard count '{p}'"))?,
                    None => 2,
                },
            },
            other => bail!("unknown partition kind '{other}'"),
        });
    }
    if value.as_map().is_some() {
        let kind = value
            .get("kind")
            .and_then(Yaml::as_str)
            .ok_or_else(|| anyhow!("partition mapping: missing 'kind'"))?;
        return Ok(match kind {
            "iid" | "uniform" => Distribution::Iid,
            "dirichlet" => Distribution::Dirichlet {
                alpha: value.get("alpha").and_then(Yaml::as_f64).unwrap_or(0.5),
            },
            "shards" => Distribution::Shards {
                shards_per_client: value
                    .get("shards_per_client")
                    .and_then(Yaml::as_i64)
                    .unwrap_or(2) as usize,
            },
            other => bail!("unknown partition kind '{other}'"),
        });
    }
    bail!("partition axis: expected a string or mapping, got {value:?}")
}

/// Human-readable form of an axis value, used in auto-generated cell names.
pub fn value_label(value: &Yaml) -> String {
    match value {
        Yaml::Str(s) => s.clone(),
        Yaml::Int(i) => i.to_string(),
        Yaml::Float(f) => format!("{f}"),
        Yaml::Bool(b) => b.to_string(),
        other => format!("{other:?}"),
    }
}

/// One auto-name fragment for `axis=value`: string values stand alone
/// (`fedavg`), everything else is prefixed with the axis (`seed1`).
pub fn name_part(axis: &str, value: &Yaml) -> String {
    match value {
        Yaml::Str(s) => s.clone(),
        other => format!("{axis}{}", value_label(other)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"
campaign:
  name: demo
  jobs: 2
axes:
  strategy: [fedavg, fedprox]
  seed: [1, 2]
cells:
  - name: mesh
    strategy: fedstellar
job:
  name: demo_base
  rounds: 2
dataset:
  name: cifar10_synth
  n: 600
strategy:
  name: fedavg
  backend: cnn
topology:
  kind: client_server
  clients: 4
  workers: 1
"#;

    #[test]
    fn parses_spec_sections() {
        let s = CampaignSpec::from_yaml_str(SPEC).unwrap();
        assert_eq!(s.name, "demo");
        assert_eq!(s.jobs, 2);
        assert_eq!(s.effective_jobs(), 2);
        assert_eq!(s.base.rounds, 2);
        assert_eq!(s.base.n_clients, 4);
        let axes: Vec<&String> = s.axes.keys().collect();
        assert_eq!(axes, ["seed", "strategy"]); // sorted axis order
        assert_eq!(s.axes["strategy"].len(), 2);
        assert_eq!(s.cells.len(), 1);
        assert_eq!(s.cells[0].name.as_deref(), Some("mesh"));
        assert_eq!(s.cells[0].overrides.len(), 1);
    }

    #[test]
    fn campaign_name_defaults_to_base_job_name() {
        let src = SPEC.replace("  name: demo\n", "");
        let s = CampaignSpec::from_yaml_str(&src).unwrap();
        assert_eq!(s.name, "demo_base");
    }

    #[test]
    fn axis_application() {
        let mut j = JobConfig::default_cnn("fedavg");
        apply_axis(&mut j, "seed", &Yaml::Int(7)).unwrap();
        assert_eq!(j.seed, 7);
        apply_axis(&mut j, "partition", &Yaml::from("dirichlet:0.1")).unwrap();
        assert_eq!(j.dataset.distribution, Distribution::Dirichlet { alpha: 0.1 });
        apply_axis(&mut j, "partition", &Yaml::from("iid")).unwrap();
        assert_eq!(j.dataset.distribution, Distribution::Iid);
        apply_axis(&mut j, "heterogeneity", &Yaml::Float(0.5)).unwrap();
        assert_eq!(j.heterogeneity, 0.5);
        apply_axis(&mut j, "strategy", &Yaml::from("fedstellar")).unwrap();
        assert_eq!(j.strategy.name(), "fedstellar");
        assert!(apply_axis(&mut j, "nonsense", &Yaml::Int(1)).is_err());
        assert!(apply_axis(&mut j, "seed", &Yaml::from("not_an_int")).is_err());
        // Negative counts must error, not wrap through `as u64`.
        assert!(apply_axis(&mut j, "rounds", &Yaml::Int(-1)).is_err());
        assert!(apply_axis(&mut j, "local_epochs", &Yaml::Int(-2)).is_err());
        assert!(apply_axis(&mut j, "seed", &Yaml::Int(-3)).is_err());
    }

    #[test]
    fn adversary_axes_apply() {
        use crate::config::adversary::{AttackKind, RobustAggKind};
        let mut j = JobConfig::default_cnn("fedavg");
        apply_axis(&mut j, "attack", &Yaml::from("sign_flip")).unwrap();
        assert_eq!(j.adversary.attack, AttackKind::SignFlip);
        apply_axis(&mut j, "attack_fraction", &Yaml::Float(0.3)).unwrap();
        assert_eq!(j.adversary.attack_fraction, 0.3);
        apply_axis(&mut j, "attack_scale", &Yaml::Float(5.0)).unwrap();
        assert_eq!(j.adversary.scale, 5.0);
        apply_axis(&mut j, "robust_agg", &Yaml::from("krum:2")).unwrap();
        assert_eq!(j.robust_agg.kind, RobustAggKind::Krum);
        assert_eq!(j.robust_agg.f, Some(2));
        apply_axis(&mut j, "robust_agg", &Yaml::from("none")).unwrap();
        assert_eq!(j.robust_agg.kind, RobustAggKind::None);
        // Churn: a sub-1.0 availability turns churn on from round 1 ...
        apply_axis(&mut j, "churn", &Yaml::Float(0.8)).unwrap();
        let churn = j.faults.churn.unwrap();
        assert_eq!(churn.availability, 0.8);
        assert_eq!(churn.from_round, 1);
        // ... and 1.0 turns it back off.
        apply_axis(&mut j, "churn", &Yaml::Float(1.0)).unwrap();
        assert!(j.faults.churn.is_none());
        assert!(apply_axis(&mut j, "attack", &Yaml::from("nonsense")).is_err());
        assert!(apply_axis(&mut j, "robust_agg", &Yaml::from("nonsense")).is_err());
    }

    #[test]
    fn channel_axes_apply() {
        use crate::config::channel::{CompressKind, DpConfig};
        let mut j = JobConfig::default_cnn("fedavg");
        apply_axis(&mut j, "compress", &Yaml::from("top_k:8000")).unwrap();
        assert_eq!(j.channel.compress.kind, CompressKind::TopK);
        assert_eq!(j.channel.compress.k, 8000);
        apply_axis(&mut j, "compress", &Yaml::from("none")).unwrap();
        assert!(!j.channel.compress.is_active());
        apply_axis(&mut j, "compress_bits", &Yaml::Int(4)).unwrap();
        assert_eq!(j.channel.compress.kind, CompressKind::Quantize);
        assert_eq!(j.channel.compress.bits, 4);
        apply_axis(&mut j, "compress_bits", &Yaml::Int(0)).unwrap();
        assert!(!j.channel.compress.is_active());
        // dp_sigma: 0.0 is the identity; positive keeps base clip/delta.
        apply_axis(&mut j, "dp_sigma", &Yaml::Float(0.01)).unwrap();
        let dp = j.channel.dp.unwrap();
        assert_eq!(dp.sigma, 0.01);
        assert_eq!(dp.clip, DpConfig::DEFAULT_CLIP);
        j.channel.dp = Some(DpConfig { clip: 3.0, sigma: 0.5, delta: 1e-6 });
        apply_axis(&mut j, "dp_sigma", &Yaml::Float(0.02)).unwrap();
        let dp = j.channel.dp.unwrap();
        assert_eq!(dp.sigma, 0.02);
        assert_eq!(dp.clip, 3.0);
        assert_eq!(dp.delta, 1e-6);
        apply_axis(&mut j, "dp_sigma", &Yaml::Float(0.0)).unwrap();
        assert!(j.channel.dp.is_none());
        apply_axis(&mut j, "secure_agg", &Yaml::Int(5)).unwrap();
        assert_eq!(j.channel.secure_agg.unwrap().threshold, 5);
        apply_axis(&mut j, "secure_agg", &Yaml::Int(0)).unwrap();
        assert!(j.channel.secure_agg.is_none());
        assert!(apply_axis(&mut j, "compress", &Yaml::from("top_k")).is_err());
        assert!(apply_axis(&mut j, "compress_bits", &Yaml::Int(17)).is_err());
        assert!(apply_axis(&mut j, "secure_agg", &Yaml::Int(-1)).is_err());
    }

    #[test]
    fn strategy_axis_keeps_base_hyper_params() {
        let extra = Yaml::parse("mu: 0.1\n").unwrap();
        let mut j = JobConfig::default_cnn("fedavg");
        j.strategy = StrategyKind::parse("fedprox", &extra).unwrap();
        // Re-selecting the base strategy keeps its configured mu ...
        apply_axis(&mut j, "strategy", &Yaml::from("fedprox")).unwrap();
        assert_eq!(j.strategy, StrategyKind::FedProx { mu: 0.1 });
        // ... while a different strategy parses with its own defaults.
        apply_axis(&mut j, "strategy", &Yaml::from("moon")).unwrap();
        assert_eq!(j.strategy, StrategyKind::Moon { mu: 1.0, tau: 0.5 });
    }

    #[test]
    fn name_parts() {
        assert_eq!(name_part("strategy", &Yaml::from("fedavg")), "fedavg");
        assert_eq!(name_part("seed", &Yaml::Int(3)), "seed3");
        assert_eq!(name_part("heterogeneity", &Yaml::Float(0.5)), "heterogeneity0.5");
    }

    #[test]
    fn scheduler_section_parses_and_defaults() {
        // No scheduler section = grid.
        let s = CampaignSpec::from_yaml_str(SPEC).unwrap();
        assert_eq!(s.scheduler, SchedulerSpec::default());
        assert_eq!(s.scheduler.kind, SchedulerKind::Grid);

        let src = SPEC.replace(
            "  jobs: 2\n",
            "  jobs: 2\n  scheduler:\n    kind: asha\n    eta: 3\n    min_rounds: 2\n    metric: loss\n",
        );
        let s = CampaignSpec::from_yaml_str(&src).unwrap();
        assert_eq!(s.scheduler.kind, SchedulerKind::Asha);
        assert_eq!(s.scheduler.eta, 3);
        assert_eq!(s.scheduler.min_rounds, 2);
        assert_eq!(s.scheduler.metric, RungMetric::Loss);
        // Mode defaults to the metric's natural direction.
        assert_eq!(s.scheduler.mode, RungMode::Min);

        // Explicit mode override wins.
        let src2 = src.replace("    metric: loss\n", "    metric: loss\n    mode: max\n");
        let s2 = CampaignSpec::from_yaml_str(&src2).unwrap();
        assert_eq!(s2.scheduler.mode, RungMode::Max);

        // Bad values are spec errors — including present-but-wrong-typed
        // fields, which must not silently fall back to defaults.
        for bad in [
            "  scheduler:\n    kind: nonsense\n",
            "  scheduler:\n    kind: asha\n    eta: 1\n",
            "  scheduler:\n    kind: asha\n    min_rounds: 0\n",
            "  scheduler:\n    metric: f1\n",
            "  scheduler:\n    mode: sideways\n",
            "  scheduler:\n    kind: asha\n    eta: not_a_number\n",
            "  scheduler:\n    kind: 0\n",
        ] {
            let src = SPEC.replace("  jobs: 2\n", &format!("  jobs: 2\n{bad}"));
            assert!(CampaignSpec::from_yaml_str(&src).is_err(), "{bad}");
        }
    }

    #[test]
    fn rung_ladder_math() {
        let sched = SchedulerSpec {
            kind: SchedulerKind::Asha,
            eta: 2,
            min_rounds: 1,
            ..SchedulerSpec::default()
        };
        assert_eq!(sched.ladder(16), vec![1, 2, 4, 8, 16]);
        assert_eq!(sched.ladder(10), vec![1, 2, 4, 8, 10]); // capped at total
        assert_eq!(sched.ladder(1), vec![1]);
        let s3 = SchedulerSpec { eta: 3, min_rounds: 2, ..sched };
        assert_eq!(s3.ladder(20), vec![2, 6, 18, 20]);
        // min_rounds >= total degenerates to a single full rung.
        let deep = SchedulerSpec { min_rounds: 30, ..sched };
        assert_eq!(deep.ladder(10), vec![10]);
        // Survivor count: floor(n/eta), never below 1.
        assert_eq!(sched.survivors(8), 4);
        assert_eq!(sched.survivors(3), 1);
        assert_eq!(sched.survivors(1), 1);
        assert_eq!(s3.survivors(8), 2);
        // Score sign-adjusts for minimization.
        assert_eq!(sched.score(0.75), 0.75);
        let min_mode = SchedulerSpec { mode: RungMode::Min, ..sched };
        assert_eq!(min_mode.score(0.75), -0.75);
    }

    #[test]
    fn builder_roundtrip() {
        let spec = CampaignSpec::builder("b", JobConfig::default_cnn("fedavg"))
            .axis_strs("strategy", &["fedavg", "fedprox"])
            .axis_ints("seed", &[1, 2])
            .cell("mesh", vec![("strategy", "fedstellar".into())])
            .jobs(0)
            .build();
        assert_eq!(spec.axes.len(), 2);
        assert_eq!(spec.cells.len(), 1);
        assert!(spec.effective_jobs() >= 1);
    }
}
