//! The declarative campaign specification: a complete base [`JobConfig`]
//! plus sweep *axes* (expanded as a cartesian grid) and/or explicit *cells*
//! (named per-cell override sets, for sweeps that are not a pure grid —
//! e.g. Fig 11's paired strategy/topology cells).
//!
//! A spec loads from YAML — the regular job-config document with two extra
//! sections — or is built programmatically through [`CampaignSpec::builder`]:
//!
//! ```yaml
//! campaign:
//!   name: smoke
//!   jobs: 2                     # outer job-level parallelism (0 = auto)
//! axes:
//!   strategy: [fedavg, fedprox]
//!   seed: [1, 2]
//! cells:                        # optional explicit cells (appended after
//!   - name: mesh                # the grid; keys other than `name` are
//!     strategy: fedstellar      # axis overrides)
//! # ... followed by a complete base job config (job/dataset/strategy/
//! # topology/...) exactly as `flsim run --config` takes it.
//! ```
//!
//! Axis *names* expand in sorted order and axis *values* in listed order,
//! so the cell list is deterministic no matter how the YAML is formatted.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::config::job::JobConfig;
use crate::data::dataset::Distribution;
use crate::strategy::StrategyKind;
use crate::topology::TopologyKind;
use crate::util::yaml::Yaml;

/// An explicit cell: an optional name plus axis overrides applied to the
/// base job. YAML cells apply overrides in sorted key order (they come out
/// of a `BTreeMap`); builder cells apply them in listed order. Either way
/// the result is order-independent: every axis touches a disjoint knob, and
/// strategy↔topology reconciliation happens once per cell after all
/// overrides (see [`crate::campaign::grid::expand`]).
#[derive(Clone, Debug)]
pub struct CellSpec {
    pub name: Option<String>,
    pub overrides: Vec<(String, Yaml)>,
}

/// A declarative experiment sweep.
#[derive(Clone, Debug)]
pub struct CampaignSpec {
    pub name: String,
    /// The fully-resolved job every cell starts from.
    pub base: JobConfig,
    /// Sweep axes: axis name → values (BTreeMap ⇒ sorted axis order).
    pub axes: BTreeMap<String, Vec<Yaml>>,
    /// Explicit cells, appended after the grid.
    pub cells: Vec<CellSpec>,
    /// Job-level scheduler width: how many cells run concurrently
    /// (`0` = one per available core, `1` = serial — the default).
    pub jobs: usize,
}

impl CampaignSpec {
    pub fn builder(name: &str, base: JobConfig) -> CampaignBuilder {
        CampaignBuilder {
            spec: CampaignSpec {
                name: name.to_string(),
                base,
                axes: BTreeMap::new(),
                cells: Vec::new(),
                jobs: 1,
            },
        }
    }

    pub fn from_yaml_str(src: &str) -> Result<CampaignSpec> {
        let y = Yaml::parse(src).map_err(|e| anyhow!("campaign spec: {e}"))?;
        Self::from_yaml(&y)
    }

    pub fn from_yaml_file(path: &str) -> Result<CampaignSpec> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading campaign spec {path}: {e}"))?;
        Self::from_yaml_str(&src)
    }

    pub fn from_yaml(y: &Yaml) -> Result<CampaignSpec> {
        // The base is the same document's regular job config — `campaign:`,
        // `axes:` and `cells:` are simply extra top-level sections.
        let base = JobConfig::from_yaml(y)?;

        let c = y.get("campaign").unwrap_or(&Yaml::Null);
        let name = c
            .get("name")
            .and_then(Yaml::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| base.name.clone());
        let jobs = match c.get("jobs").and_then(Yaml::as_i64).unwrap_or(1) {
            n if n < 0 => bail!("campaign.jobs must be >= 0 (0 = auto), got {n}"),
            n => n as usize,
        };

        let mut axes = BTreeMap::new();
        if let Some(a) = y.get("axes") {
            let m = a
                .as_map()
                .ok_or_else(|| anyhow!("campaign spec: 'axes' must be a mapping"))?;
            for (axis, vals) in m {
                let vals = vals
                    .as_seq()
                    .ok_or_else(|| anyhow!("axis '{axis}': values must be a list"))?;
                if vals.is_empty() {
                    bail!("axis '{axis}': empty value list");
                }
                axes.insert(axis.clone(), vals.to_vec());
            }
        }

        let mut cells = Vec::new();
        if let Some(cs) = y.get("cells") {
            let seq = cs
                .as_seq()
                .ok_or_else(|| anyhow!("campaign spec: 'cells' must be a list"))?;
            for cy in seq {
                let m = cy
                    .as_map()
                    .ok_or_else(|| anyhow!("campaign spec: each cell must be a mapping"))?;
                let mut name = None;
                let mut overrides = Vec::new();
                for (k, v) in m {
                    if k == "name" {
                        name = v.as_str().map(str::to_string);
                    } else {
                        overrides.push((k.clone(), v.clone()));
                    }
                }
                cells.push(CellSpec { name, overrides });
            }
        }

        Ok(CampaignSpec {
            name,
            base,
            axes,
            cells,
            jobs,
        })
    }

    /// The job scheduler's worker count: `jobs`, with `0` resolved to the
    /// number of available cores.
    pub fn effective_jobs(&self) -> usize {
        match self.jobs {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            n => n,
        }
    }
}

/// Fluent construction of a [`CampaignSpec`] from code (the experiment
/// ports and examples use this instead of YAML).
pub struct CampaignBuilder {
    spec: CampaignSpec,
}

impl CampaignBuilder {
    /// Add a sweep axis (replaces any previous axis of the same name).
    pub fn axis(mut self, name: &str, values: Vec<Yaml>) -> CampaignBuilder {
        self.spec.axes.insert(name.to_string(), values);
        self
    }

    /// Add a string-valued sweep axis.
    pub fn axis_strs(self, name: &str, values: &[&str]) -> CampaignBuilder {
        self.axis(name, values.iter().map(|v| Yaml::from(*v)).collect())
    }

    /// Add an integer-valued sweep axis.
    pub fn axis_ints(self, name: &str, values: &[i64]) -> CampaignBuilder {
        self.axis(name, values.iter().map(|v| Yaml::from(*v)).collect())
    }

    /// Add an explicit named cell with axis overrides.
    pub fn cell(mut self, name: &str, overrides: Vec<(&str, Yaml)>) -> CampaignBuilder {
        self.spec.cells.push(CellSpec {
            name: Some(name.to_string()),
            overrides: overrides
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        });
        self
    }

    /// Set the job-level scheduler width (0 = auto).
    pub fn jobs(mut self, jobs: usize) -> CampaignBuilder {
        self.spec.jobs = jobs;
        self
    }

    pub fn build(self) -> CampaignSpec {
        self.spec
    }
}

/// Apply one axis override to a job. The supported axis names are the
/// knobs the paper's evaluation grid sweeps (strategy × topology ×
/// partition × heterogeneity × seed) plus the obvious scale/training knobs.
pub fn apply_axis(job: &mut JobConfig, axis: &str, value: &Yaml) -> Result<()> {
    let want_str = || {
        value
            .as_str()
            .ok_or_else(|| anyhow!("axis '{axis}': expected a string, got {value:?}"))
    };
    let want_i64 = || {
        value
            .as_i64()
            .ok_or_else(|| anyhow!("axis '{axis}': expected an integer, got {value:?}"))
    };
    // Counts and seeds: a negative value must not wrap through `as u64`
    // (`rounds: [-1]` would otherwise loop for u64::MAX rounds).
    let want_nonneg = || -> Result<i64> {
        let v = want_i64()?;
        if v < 0 {
            return Err(anyhow!(
                "axis '{axis}': expected a non-negative integer, got {v}"
            ));
        }
        Ok(v)
    };
    let want_f64 = || {
        value
            .as_f64()
            .ok_or_else(|| anyhow!("axis '{axis}': expected a number, got {value:?}"))
    };
    match axis {
        "strategy" => {
            let name = want_str()?;
            // Re-selecting the base strategy keeps its configured
            // hyper-parameters (mu, sigma, ...); a *different* strategy has
            // no base hyper-params to inherit and parses with its defaults.
            // Strategy-mode ↔ topology reconciliation happens once per cell
            // in grid expansion, after all overrides — not here — so cell
            // behavior never depends on override order.
            if name != job.strategy.name() {
                job.strategy = StrategyKind::parse(name, &Yaml::Null)?;
            }
        }
        "topology" => job.topology = TopologyKind::parse(want_str()?)?,
        "backend" => job.backend = want_str()?.to_string(),
        "partition" => job.dataset.distribution = parse_partition(value)?,
        "seed" => job.seed = want_nonneg()? as u64,
        "rounds" => job.rounds = want_nonneg()? as u64,
        "clients" => job.n_clients = want_nonneg()? as usize,
        "workers" => job.n_workers = want_nonneg()? as usize,
        "dataset_n" => job.dataset.n = want_nonneg()? as usize,
        "heterogeneity" => job.heterogeneity = want_f64()?,
        "client_fraction" => job.client_fraction = want_f64()?,
        "learning_rate" => job.train.learning_rate = want_f64()? as f32,
        "local_epochs" => job.train.local_epochs = want_nonneg()? as usize,
        "hw_profile" | "hardware_profile" => {
            job.hw_profile = crate::aggregate::mean::ReductionOrder::parse(want_str()?)?;
        }
        "parallelism" => job.parallelism = want_nonneg()? as usize,
        _ => bail!(
            "unknown campaign axis '{axis}' (supported: strategy topology backend partition \
             seed rounds clients workers dataset_n heterogeneity client_fraction \
             learning_rate local_epochs hw_profile parallelism)"
        ),
    }
    Ok(())
}

/// Partition axis values: `iid`, `dirichlet`/`dirichlet:<alpha>`,
/// `shards`/`shards:<k>`, or the mapping form `{kind: dirichlet, alpha: x}`.
fn parse_partition(value: &Yaml) -> Result<Distribution> {
    if let Some(s) = value.as_str() {
        let (kind, param) = match s.split_once(':') {
            Some((k, p)) => (k, Some(p)),
            None => (s, None),
        };
        return Ok(match kind {
            "iid" | "uniform" => Distribution::Iid,
            "dirichlet" => Distribution::Dirichlet {
                alpha: match param {
                    Some(p) => p
                        .parse()
                        .map_err(|_| anyhow!("partition: bad dirichlet alpha '{p}'"))?,
                    None => 0.5,
                },
            },
            "shards" => Distribution::Shards {
                shards_per_client: match param {
                    Some(p) => p
                        .parse()
                        .map_err(|_| anyhow!("partition: bad shard count '{p}'"))?,
                    None => 2,
                },
            },
            other => bail!("unknown partition kind '{other}'"),
        });
    }
    if value.as_map().is_some() {
        let kind = value
            .get("kind")
            .and_then(Yaml::as_str)
            .ok_or_else(|| anyhow!("partition mapping: missing 'kind'"))?;
        return Ok(match kind {
            "iid" | "uniform" => Distribution::Iid,
            "dirichlet" => Distribution::Dirichlet {
                alpha: value.get("alpha").and_then(Yaml::as_f64).unwrap_or(0.5),
            },
            "shards" => Distribution::Shards {
                shards_per_client: value
                    .get("shards_per_client")
                    .and_then(Yaml::as_i64)
                    .unwrap_or(2) as usize,
            },
            other => bail!("unknown partition kind '{other}'"),
        });
    }
    bail!("partition axis: expected a string or mapping, got {value:?}")
}

/// Human-readable form of an axis value, used in auto-generated cell names.
pub fn value_label(value: &Yaml) -> String {
    match value {
        Yaml::Str(s) => s.clone(),
        Yaml::Int(i) => i.to_string(),
        Yaml::Float(f) => format!("{f}"),
        Yaml::Bool(b) => b.to_string(),
        other => format!("{other:?}"),
    }
}

/// One auto-name fragment for `axis=value`: string values stand alone
/// (`fedavg`), everything else is prefixed with the axis (`seed1`).
pub fn name_part(axis: &str, value: &Yaml) -> String {
    match value {
        Yaml::Str(s) => s.clone(),
        other => format!("{axis}{}", value_label(other)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"
campaign:
  name: demo
  jobs: 2
axes:
  strategy: [fedavg, fedprox]
  seed: [1, 2]
cells:
  - name: mesh
    strategy: fedstellar
job:
  name: demo_base
  rounds: 2
dataset:
  name: cifar10_synth
  n: 600
strategy:
  name: fedavg
  backend: cnn
topology:
  kind: client_server
  clients: 4
  workers: 1
"#;

    #[test]
    fn parses_spec_sections() {
        let s = CampaignSpec::from_yaml_str(SPEC).unwrap();
        assert_eq!(s.name, "demo");
        assert_eq!(s.jobs, 2);
        assert_eq!(s.effective_jobs(), 2);
        assert_eq!(s.base.rounds, 2);
        assert_eq!(s.base.n_clients, 4);
        let axes: Vec<&String> = s.axes.keys().collect();
        assert_eq!(axes, ["seed", "strategy"]); // sorted axis order
        assert_eq!(s.axes["strategy"].len(), 2);
        assert_eq!(s.cells.len(), 1);
        assert_eq!(s.cells[0].name.as_deref(), Some("mesh"));
        assert_eq!(s.cells[0].overrides.len(), 1);
    }

    #[test]
    fn campaign_name_defaults_to_base_job_name() {
        let src = SPEC.replace("  name: demo\n", "");
        let s = CampaignSpec::from_yaml_str(&src).unwrap();
        assert_eq!(s.name, "demo_base");
    }

    #[test]
    fn axis_application() {
        let mut j = JobConfig::default_cnn("fedavg");
        apply_axis(&mut j, "seed", &Yaml::Int(7)).unwrap();
        assert_eq!(j.seed, 7);
        apply_axis(&mut j, "partition", &Yaml::from("dirichlet:0.1")).unwrap();
        assert_eq!(j.dataset.distribution, Distribution::Dirichlet { alpha: 0.1 });
        apply_axis(&mut j, "partition", &Yaml::from("iid")).unwrap();
        assert_eq!(j.dataset.distribution, Distribution::Iid);
        apply_axis(&mut j, "heterogeneity", &Yaml::Float(0.5)).unwrap();
        assert_eq!(j.heterogeneity, 0.5);
        apply_axis(&mut j, "strategy", &Yaml::from("fedstellar")).unwrap();
        assert_eq!(j.strategy.name(), "fedstellar");
        assert!(apply_axis(&mut j, "nonsense", &Yaml::Int(1)).is_err());
        assert!(apply_axis(&mut j, "seed", &Yaml::from("not_an_int")).is_err());
        // Negative counts must error, not wrap through `as u64`.
        assert!(apply_axis(&mut j, "rounds", &Yaml::Int(-1)).is_err());
        assert!(apply_axis(&mut j, "local_epochs", &Yaml::Int(-2)).is_err());
        assert!(apply_axis(&mut j, "seed", &Yaml::Int(-3)).is_err());
    }

    #[test]
    fn strategy_axis_keeps_base_hyper_params() {
        let extra = Yaml::parse("mu: 0.1\n").unwrap();
        let mut j = JobConfig::default_cnn("fedavg");
        j.strategy = StrategyKind::parse("fedprox", &extra).unwrap();
        // Re-selecting the base strategy keeps its configured mu ...
        apply_axis(&mut j, "strategy", &Yaml::from("fedprox")).unwrap();
        assert_eq!(j.strategy, StrategyKind::FedProx { mu: 0.1 });
        // ... while a different strategy parses with its own defaults.
        apply_axis(&mut j, "strategy", &Yaml::from("moon")).unwrap();
        assert_eq!(j.strategy, StrategyKind::Moon { mu: 1.0, tau: 0.5 });
    }

    #[test]
    fn name_parts() {
        assert_eq!(name_part("strategy", &Yaml::from("fedavg")), "fedavg");
        assert_eq!(name_part("seed", &Yaml::Int(3)), "seed3");
        assert_eq!(name_part("heterogeneity", &Yaml::Float(0.5)), "heterogeneity0.5");
    }

    #[test]
    fn builder_roundtrip() {
        let spec = CampaignSpec::builder("b", JobConfig::default_cnn("fedavg"))
            .axis_strs("strategy", &["fedavg", "fedprox"])
            .axis_ints("seed", &[1, 2])
            .cell("mesh", vec![("strategy", "fedstellar".into())])
            .jobs(0)
            .build();
        assert_eq!(spec.axes.len(), 2);
        assert_eq!(spec.cells.len(), 1);
        assert!(spec.effective_jobs() >= 1);
    }
}
