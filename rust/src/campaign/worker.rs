//! Coordinator-free campaign workers: `flsim campaign worker <store>
//! <spec>` runs one of these. N worker processes pointed at the same spec
//! and the same (shared-filesystem) result store cooperatively drain the
//! campaign: each worker leases a cell ([`crate::campaign::lease`]),
//! executes it through the cancellable round loop while a heartbeat thread
//! keeps the lease fresh, and commits the result atomically. A worker that
//! dies mid-cell simply stops heartbeating; after the expiry any survivor
//! reclaims the lease and re-runs the cell (losing only that cell's
//! in-flight rounds — committed work is never lost, and determinism makes
//! the re-run bitwise identical).
//!
//! **Elastic-deterministic ASHA.** Under the ASHA scheduler the workers
//! must agree on rung promotions without a coordinator. The drain makes
//! promotion a pure function of `(spec, seed)` — invariant to worker
//! count, arrival order, and mid-rung crashes (test-enforced by
//! `rust/tests/campaign_worker.rs`) — by splitting each rung in two:
//!
//! 1. **Fill.** Every still-alive cell must reach the rung budget *in the
//!    store*: each worker leases unfilled cells and deepens them (resuming
//!    from the cell's checkpoint blob when one exists, scratch otherwise),
//!    committing the partial report + checkpoint at the rung. Workers that
//!    find every cell leased **block** at the rung barrier, polling — and
//!    steal expired leases, so a crashed worker's cell is picked up by a
//!    survivor. A failed cell leaves a failure marker
//!    ([`ResultStore::record_failure`]) so every worker's barrier unblocks
//!    on it rather than waiting forever.
//! 2. **Promote.** Promotion decisions are **replayed from the store**,
//!    never improvised: every worker reads the same stored reports, ranks
//!    them with the exact sort `run_asha` uses (NaN-last, ties by
//!    expansion order), and derives the same survivor set. Stopped cells'
//!    outcomes are the stored reports truncated at the rung.
//!
//! Leases are an efficiency mechanism, not a correctness one (results are
//! content-addressed and committed atomically), so the worst case — a
//! paused worker losing its lease and both finishing — duplicates work,
//! never corrupts results.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::campaign::cache::{CellOutcome, ResultStore};
use crate::campaign::checkpoint::Checkpoint;
use crate::campaign::grid::{self, Cell};
use crate::campaign::lease::{Acquire, Lease, LeaseConfig, LeaseManager};
use crate::campaign::runner::{self, CampaignOutcome, CellRun};
use crate::campaign::spec::{CampaignSpec, SchedulerKind};
use crate::controller::sync::FaultPlan;
use crate::metrics::report::RunReport;
use crate::orchestrator::{RunControl, RunHandle};
use crate::runtime::pjrt::Runtime;

/// Worker identity and pacing (CLI: `--owner`, `--heartbeat-secs`,
/// `--expiry-secs`, `--poll-secs`).
#[derive(Clone, Debug)]
pub struct WorkerOptions {
    /// Lease owner id; must be unique among concurrent workers (the CLI
    /// defaults to `worker-<pid>`).
    pub owner: String,
    pub lease: LeaseConfig,
    /// How long to sleep when every remaining cell is leased elsewhere.
    pub poll: Duration,
}

impl WorkerOptions {
    pub fn new(owner: &str) -> WorkerOptions {
        WorkerOptions {
            owner: owner.to_string(),
            lease: LeaseConfig::default(),
            poll: Duration::from_millis(500),
        }
    }
}

/// Cooperatively drain a campaign: lease-execute-commit cells until every
/// cell is resolved (committed by someone, or marked failed). Blocks while
/// other workers hold the remaining cells, reclaiming expired leases.
/// The outcome mirrors [`runner::run`]'s: one [`CellRun`] per expanded
/// cell in expansion order, `cached` meaning "this process executed
/// nothing for it" (served by the store or by another worker).
pub fn drain(
    rt: Arc<Runtime>,
    spec: &CampaignSpec,
    store: &ResultStore,
    opts: &WorkerOptions,
) -> Result<CampaignOutcome> {
    match spec.scheduler.kind {
        SchedulerKind::Grid => drain_grid(rt, spec, store, opts),
        SchedulerKind::Asha => drain_asha(rt, spec, store, opts),
    }
}

/// A held lease kept fresh by a background heartbeat thread while the
/// holder executes rounds. [`Heartbeat::release`] stops the thread and
/// drops the lease (releasing the cell). If the lease is stolen out from
/// under us (we stalled past the expiry), beating fails and the thread
/// just stops — the eventual commit is still safe, merely duplicated.
struct Heartbeat {
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<Lease>,
}

impl Heartbeat {
    fn spawn(mut lease: Lease, every: Duration) -> Heartbeat {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let thread = std::thread::spawn(move || {
            while !flag.load(Ordering::Relaxed) {
                std::thread::park_timeout(every);
                if flag.load(Ordering::Relaxed) || lease.beat().is_err() {
                    break;
                }
            }
            lease
        });
        Heartbeat { stop, thread }
    }

    fn release(self) {
        self.stop.store(true, Ordering::Relaxed);
        self.thread.thread().unpark();
        // Joining hands the lease back and drops it here (owner-checked
        // release). A panicked heartbeat thread already dropped it.
        let _ = self.thread.join();
    }
}

fn drain_grid(
    rt: Arc<Runtime>,
    spec: &CampaignSpec,
    store: &ResultStore,
    opts: &WorkerOptions,
) -> Result<CampaignOutcome> {
    let cells = grid::expand(spec)?;
    let mgr = LeaseManager::open(store.dir(), &opts.owner, opts.lease)?;
    let mut slots: Vec<Option<CellRun>> = vec![None; cells.len()];
    loop {
        let mut progressed = false;
        for (i, cell) in cells.iter().enumerate() {
            if slots[i].is_some() {
                continue;
            }
            // Resolved by anyone (including an earlier pass of ours — our
            // own executions fill the slot directly, so a hit here is a
            // cache/other-worker result).
            if let Some(report) = store.get(&cell.key) {
                slots[i] = Some(resolved(cell, true, Some(report), None));
                progressed = true;
                continue;
            }
            if let Some(err) = store.failure(&cell.key) {
                slots[i] = Some(resolved(cell, false, None, Some(err)));
                progressed = true;
                continue;
            }
            match mgr.try_acquire(&cell.key)? {
                Acquire::Held { .. } => {} // someone else is on it
                Acquire::Acquired(lease) => {
                    // A commit may have landed between the probe and the
                    // acquire — don't re-execute it.
                    if let Some(report) = store.get(&cell.key) {
                        drop(lease);
                        slots[i] = Some(resolved(cell, true, Some(report), None));
                        progressed = true;
                        continue;
                    }
                    println!(
                        "worker[{}]: run  {} ({})",
                        opts.owner,
                        cell.name,
                        &cell.key[..12]
                    );
                    let hb = Heartbeat::spawn(lease, opts.lease.heartbeat);
                    let t0 = std::time::Instant::now();
                    let outcome = match runner::run_cell_resumable(&rt, cell, store, &spec.name)
                        .and_then(|report| {
                            store.commit(
                                &cell.key,
                                CellOutcome::new(&cell.job, &report)
                                    .cell(&cell.name)
                                    .campaign(&spec.name),
                            )?;
                            Ok(report)
                        }) {
                        Ok(report) => {
                            println!(
                                "worker[{}]: done {} in {:.1}s (acc {:.3})",
                                opts.owner,
                                cell.name,
                                t0.elapsed().as_secs_f64(),
                                report.final_accuracy()
                            );
                            resolved(cell, false, Some(report), None)
                        }
                        Err(e) => {
                            let msg = format!("{e:#}");
                            println!("worker[{}]: FAIL {} — {msg}", opts.owner, cell.name);
                            let _ = store.record_failure(&cell.key, &cell.name, &spec.name, &msg);
                            resolved(cell, false, None, Some(msg))
                        }
                    };
                    hb.release();
                    slots[i] = Some(outcome);
                    progressed = true;
                }
            }
        }
        if slots.iter().all(|s| s.is_some()) {
            break;
        }
        if !progressed {
            std::thread::sleep(opts.poll);
        }
    }
    Ok(CampaignOutcome {
        name: spec.name.clone(),
        cells: slots
            .into_iter()
            .map(|s| s.expect("loop exits only when every slot is filled"))
            .collect(),
    })
}

fn resolved(cell: &Cell, cached: bool, report: Option<RunReport>, error: Option<String>) -> CellRun {
    CellRun {
        cell: cell.clone(),
        cached,
        report,
        error,
    }
}

/// Per-cell drain state (worker-side mirror of the scheduler's view, but
/// derived entirely from the store).
struct Slot {
    executed: bool,
    report: Option<RunReport>,
    error: Option<String>,
}

impl Slot {
    fn alive(&self) -> bool {
        self.report.is_none() && self.error.is_none()
    }
}

fn drain_asha(
    rt: Arc<Runtime>,
    spec: &CampaignSpec,
    store: &ResultStore,
    opts: &WorkerOptions,
) -> Result<CampaignOutcome> {
    let cells = grid::expand(spec)?;
    let sched = spec.scheduler;
    let max_rounds = cells.iter().map(|c| c.job.rounds).max().unwrap_or(1);
    let ladder = sched.ladder(max_rounds);
    let mgr = LeaseManager::open(store.dir(), &opts.owner, opts.lease)?;
    let mut slots: Vec<Slot> = cells
        .iter()
        .map(|_| Slot {
            executed: false,
            report: None,
            error: None,
        })
        .collect();

    for (rung, &budget) in ladder.iter().enumerate() {
        // --------------------------------------------------------------
        // 1. Fill: every alive cell must reach this rung's budget in the
        //    store before anyone decides promotions. Block-or-steal at
        //    the barrier.
        // --------------------------------------------------------------
        loop {
            let mut all_filled = true;
            let mut progressed = false;
            for (i, cell) in cells.iter().enumerate() {
                if !slots[i].alive() {
                    continue;
                }
                let target = budget.min(cell.job.rounds);
                if store.get_at_least(&cell.key, target).is_some() {
                    continue; // filled (by us, another worker, or a cache)
                }
                if let Some(err) = store.failure(&cell.key) {
                    // A cross-process failure unblocks the barrier for
                    // everyone instead of hanging it.
                    slots[i].error = Some(err);
                    progressed = true;
                    continue;
                }
                all_filled = false;
                match mgr.try_acquire(&cell.key)? {
                    Acquire::Held { .. } => {}
                    Acquire::Acquired(lease) => {
                        if store.get_at_least(&cell.key, target).is_some() {
                            drop(lease); // raced: committed since the probe
                            progressed = true;
                            continue;
                        }
                        let hb = Heartbeat::spawn(lease, opts.lease.heartbeat);
                        let r = deepen_to(&rt, cell, store, spec, opts, target, rung);
                        hb.release();
                        match r {
                            Ok(()) => slots[i].executed = true,
                            Err(e) => {
                                let msg = format!("{e:#}");
                                println!(
                                    "worker[{}]: FAIL {} — {msg}",
                                    opts.owner, cell.name
                                );
                                let _ = store
                                    .record_failure(&cell.key, &cell.name, &spec.name, &msg);
                                slots[i].error = Some(msg);
                            }
                        }
                        progressed = true;
                    }
                }
            }
            if all_filled {
                break;
            }
            if !progressed {
                std::thread::sleep(opts.poll);
            }
        }

        // --------------------------------------------------------------
        // 2. Finalize cells whose full budget this rung reached.
        // --------------------------------------------------------------
        for (i, cell) in cells.iter().enumerate() {
            if !slots[i].alive() || budget < cell.job.rounds {
                continue;
            }
            match store.get(&cell.key) {
                Some(report) => slots[i].report = Some(report),
                None => {
                    slots[i].error = Some(
                        "internal: cell reached its full budget without a complete store entry"
                            .into(),
                    )
                }
            }
        }

        // --------------------------------------------------------------
        // 3. Promote: replay the rung decision purely from the store —
        //    same metric, same NaN-last ties-by-expansion-order sort as
        //    `run_asha`, so every worker (and a single-process run)
        //    derives the identical survivor set.
        // --------------------------------------------------------------
        let continuing: Vec<usize> = (0..cells.len())
            .filter(|&i| slots[i].alive() && budget < cells[i].job.rounds)
            .collect();
        if continuing.is_empty() || rung + 1 >= ladder.len() {
            continue;
        }
        let mut ranked: Vec<(usize, f64)> = Vec::with_capacity(continuing.len());
        for &i in &continuing {
            let stored = store.get_at_least(&cells[i].key, budget).ok_or_else(|| {
                anyhow!(
                    "campaign '{}': cell '{}' passed the rung barrier but its stored \
                     entry is gone (store gc'd mid-drain?)",
                    spec.name,
                    cells[i].name
                )
            })?;
            let v = stored
                .metric_at(budget, |m| sched.metric_of(m))
                .ok_or_else(|| {
                    anyhow!(
                        "campaign '{}': cell '{}' has no stored metric at rung budget {budget}",
                        spec.name,
                        cells[i].name
                    )
                })?;
            ranked.push((i, sched.score(v)));
        }
        ranked.sort_by(|a, b| {
            match (a.1.is_nan(), b.1.is_nan()) {
                (true, true) => std::cmp::Ordering::Equal,
                (true, false) => std::cmp::Ordering::Greater,
                (false, true) => std::cmp::Ordering::Less,
                (false, false) => b.1.partial_cmp(&a.1).expect("both finite-or-inf"),
            }
            .then(a.0.cmp(&b.0))
        });
        let keep = sched.survivors(ranked.len());
        for &(i, score) in &ranked[keep..] {
            let cell = &cells[i];
            let stored = store
                .get_at_least(&cell.key, budget)
                .expect("ranked cells were just read from the store");
            println!(
                "worker[{}]: stop {} at rung {} ({} rounds, score {:.4})",
                opts.owner,
                cell.name,
                rung + 1,
                budget,
                score
            );
            slots[i].report = Some(stored.truncated(budget));
        }
    }

    Ok(CampaignOutcome {
        name: spec.name.clone(),
        cells: cells
            .into_iter()
            .zip(slots)
            .map(|(cell, slot)| {
                let cached = !slot.executed && slot.error.is_none() && slot.report.is_some();
                CellRun {
                    cell,
                    cached,
                    report: slot.report,
                    error: slot.error,
                }
            })
            .collect(),
    })
}

/// Advance one leased cell to `target` stored rounds: resume from its
/// checkpoint when sound (otherwise scratch), run to the budget, and
/// commit — a complete entry at the full budget, or a partial + checkpoint
/// at a rung.
fn deepen_to(
    rt: &Arc<Runtime>,
    cell: &Cell,
    store: &ResultStore,
    spec: &CampaignSpec,
    opts: &WorkerOptions,
    target: u64,
    rung: usize,
) -> Result<()> {
    let mut handle = match runner::resume_handle(rt, cell, store, target, &spec.name) {
        Ok(Some(h)) => h,
        Ok(None) => RunHandle::start(rt.clone(), &cell.job, FaultPlan::none())?,
        Err(e) => {
            println!(
                "worker[{}]: checkpoint for {} unusable ({e:#}), running from scratch",
                opts.owner, cell.name
            );
            RunHandle::start(rt.clone(), &cell.job, FaultPlan::none())?
        }
    };
    println!(
        "worker[{}]: rung {} — {} to round {} (from {})",
        opts.owner,
        rung + 1,
        cell.name,
        target,
        handle.rounds_done() + 1
    );
    handle.advance(&RunControl::budget(target))?;
    if handle.rounds_done() >= cell.job.rounds {
        let report = handle.finish()?;
        store.commit(
            &cell.key,
            CellOutcome::new(&cell.job, &report)
                .cell(&cell.name)
                .campaign(&spec.name),
        )?;
        println!(
            "worker[{}]: done {} ({} rounds, acc {:.3})",
            opts.owner,
            cell.name,
            report.rounds_completed(),
            report.final_accuracy()
        );
        return Ok(());
    }
    let report = handle.partial_report();
    if report.rounds_completed() < target {
        bail!(
            "cell '{}' stalled at round {} of rung target {target}",
            cell.name,
            report.rounds_completed()
        );
    }
    let ckpt = handle
        .checkpoint_params()
        .map(|p| Checkpoint::new(&cell.key, report.rounds_completed(), p.to_vec()));
    let mut outcome = CellOutcome::new(&cell.job, &report)
        .cell(&cell.name)
        .campaign(&spec.name);
    if let Some(c) = &ckpt {
        outcome = outcome.checkpoint(c);
    }
    store.commit(&cell.key, outcome)?;
    Ok(())
}
