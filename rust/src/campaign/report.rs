//! The campaign report: per-cell summary metrics aggregated into one
//! CSV/JSON artifact (plus the dashboard comparison table rendered by the
//! CLI).
//!
//! Rows are built purely from the stored per-cell [`RunReport`]s, in
//! expansion order — so a campaign resumed entirely from cache reproduces
//! its report byte-for-byte (the stored first-run wall clocks included).

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::campaign::runner::CampaignOutcome;
use crate::config::adversary::RobustAggKind;
use crate::metrics::report::RunReport;
use crate::util::json::Json;

/// One completed cell's summary row.
#[derive(Clone, Debug)]
pub struct CellRow {
    pub cell: String,
    pub key: String,
    pub strategy: String,
    pub topology: String,
    pub backend: String,
    pub n_clients: usize,
    pub n_workers: usize,
    pub seed: u64,
    pub rounds: usize,
    /// The scheduler stopped this cell before its full round budget
    /// (`rounds` is then the rung boundary it reached).
    pub stopped_early: bool,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub final_loss: f64,
    pub wall_secs: f64,
    pub sim_round_secs: f64,
    pub net_bytes: u64,
    /// Final-round global model hash (provenance).
    pub model_hash: String,
    /// Cumulative DP ε spent by the cell's final round (0.0 when the cell
    /// has no `channel.dp` stage).
    pub dp_epsilon: f64,
}

impl CellRow {
    fn new(cell: &str, key: &str, r: &RunReport) -> CellRow {
        CellRow {
            cell: cell.to_string(),
            key: key.to_string(),
            strategy: r.strategy.clone(),
            topology: r.topology.clone(),
            backend: r.backend.clone(),
            n_clients: r.n_clients,
            n_workers: r.n_workers,
            seed: r.seed,
            rounds: r.rounds.len(),
            stopped_early: r.stopped_early,
            final_accuracy: r.final_accuracy(),
            best_accuracy: r.best_accuracy(),
            final_loss: r.final_loss(),
            wall_secs: r.total_wall_secs(),
            sim_round_secs: r.total_sim_round_secs(),
            net_bytes: r.total_net_bytes(),
            model_hash: r
                .rounds
                .last()
                .map(|m| m.model_hash.clone())
                .unwrap_or_default(),
            dp_epsilon: r.rounds.last().map(|m| m.dp_epsilon).unwrap_or(0.0),
        }
    }
}

/// The aggregated campaign report.
#[derive(Clone, Debug)]
pub struct CampaignReport {
    pub name: String,
    pub rows: Vec<CellRow>,
}

impl CampaignReport {
    /// Build from a finished campaign (completed-and-persisted cells only,
    /// in expansion order — failed cells are the CLI's problem, not the
    /// report's; a cell whose store-put failed re-runs on retry, so putting
    /// it in the report would break byte-identical resume).
    pub fn from_outcome(outcome: &CampaignOutcome) -> CampaignReport {
        CampaignReport {
            name: outcome.name.clone(),
            rows: outcome
                .cells
                .iter()
                .filter(|c| c.error.is_none())
                .filter_map(|c| {
                    c.report
                        .as_ref()
                        .map(|r| CellRow::new(&c.cell.name, &c.cell.key, r))
                })
                .collect(),
        }
    }

    /// One row per cell.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "cell,key,strategy,topology,backend,n_clients,n_workers,seed,rounds,stopped_early,\
             final_accuracy,best_accuracy,final_loss,wall_secs,sim_round_secs,net_bytes,model_hash,\
             dp_epsilon\n",
        );
        for r in &self.rows {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{:.4},{:.4},{},{},{:.6}\n",
                r.cell,
                r.key,
                r.strategy,
                r.topology,
                r.backend,
                r.n_clients,
                r.n_workers,
                r.seed,
                r.rounds,
                r.stopped_early,
                r.final_accuracy,
                r.best_accuracy,
                r.final_loss,
                r.wall_secs,
                r.sim_round_secs,
                r.net_bytes,
                r.model_hash,
                r.dp_epsilon
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::from("flsim-campaign-v1")),
            ("campaign", Json::from(self.name.as_str())),
            (
                "cells",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("cell", Json::from(r.cell.as_str())),
                                ("key", Json::from(r.key.as_str())),
                                ("strategy", Json::from(r.strategy.as_str())),
                                ("topology", Json::from(r.topology.as_str())),
                                ("backend", Json::from(r.backend.as_str())),
                                ("n_clients", Json::from(r.n_clients)),
                                ("n_workers", Json::from(r.n_workers)),
                                ("seed", Json::from(r.seed as usize)),
                                ("rounds", Json::from(r.rounds)),
                                ("stopped_early", Json::from(r.stopped_early)),
                                ("final_accuracy", Json::Num(r.final_accuracy)),
                                ("best_accuracy", Json::Num(r.best_accuracy)),
                                ("final_loss", Json::Num(r.final_loss)),
                                ("wall_secs", Json::Num(r.wall_secs)),
                                ("sim_round_secs", Json::Num(r.sim_round_secs)),
                                ("net_bytes", Json::from(r.net_bytes as usize)),
                                ("model_hash", Json::from(r.model_hash.as_str())),
                                ("dp_epsilon", Json::Num(r.dp_epsilon)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `<dir>/<name>.csv` and `<dir>/<name>.json`; returns the paths.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<(PathBuf, PathBuf)> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating report dir {dir:?}"))?;
        let csv = dir.join(format!("{}.csv", self.name));
        let json = dir.join(format!("{}.json", self.name));
        std::fs::write(&csv, self.to_csv()).with_context(|| format!("writing {csv:?}"))?;
        std::fs::write(&json, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing {json:?}"))?;
        Ok((csv, json))
    }
}

/// A campaign frontier: summary metrics pivoted over the sweep surface the
/// campaign actually explored.
///
/// * **Adversary sweeps** pivot mean final accuracy over (attack fraction ×
///   aggregator) — what a one-YAML attack×defense sweep is run for. Rows
///   are the sorted distinct `attack_fraction` values, columns the sorted
///   aggregator labels (`weighted_mean` when no robust aggregator is
///   configured).
/// * **Channel sweeps** (tried when there is no adversary surface) pivot
///   mean final accuracy, cumulative DP ε, and wire gigabytes over
///   (compression × dp σ). Rows are the sorted distinct compression labels
///   (`none` / `top_k:<k>` / `quantize:<bits>`), columns the
///   `accuracy_s<σ>` / `epsilon_s<σ>` / `wire_gb_s<σ>` triple per sorted σ.
///
/// Each value averages every completed cell landing in that combination
/// (NaN = no cell there).
#[derive(Clone, Debug)]
pub struct FrontierReport {
    pub name: String,
    /// Adversary pivot rows (empty for a channel frontier).
    pub fractions: Vec<f64>,
    /// Column labels: aggregators for the adversary pivot, per-σ metric
    /// columns for the channel pivot.
    pub aggregators: Vec<String>,
    /// `values[row][col]`, row-major over rows × `aggregators`.
    pub values: Vec<Vec<f64>>,
    /// Channel pivot rows (empty for an adversary frontier).
    pub compress_labels: Vec<String>,
}

impl FrontierReport {
    /// Pivot a finished campaign into a frontier. The adversary pivot wins
    /// when both surfaces were swept; each pivot returns `None` unless the
    /// campaign genuinely swept it — at least two distinct combinations and
    /// at least one cell with the section active — so plain campaigns never
    /// grow an extra artifact.
    pub fn from_outcome(outcome: &CampaignOutcome) -> Option<FrontierReport> {
        Self::adversary_pivot(outcome).or_else(|| Self::channel_pivot(outcome))
    }

    fn adversary_pivot(outcome: &CampaignOutcome) -> Option<FrontierReport> {
        let mut samples: Vec<(f64, String, f64)> = Vec::new();
        let mut any_active = false;
        for c in &outcome.cells {
            if c.error.is_some() {
                continue;
            }
            let Some(report) = &c.report else { continue };
            let frac = c.cell.job.adversary.attack_fraction;
            let agg = match c.cell.job.robust_agg.kind {
                RobustAggKind::None => "weighted_mean".to_string(),
                kind => kind.name().to_string(),
            };
            any_active |= c.cell.job.adversary.is_active();
            samples.push((frac, agg, report.final_accuracy()));
        }
        let combos: BTreeSet<(u64, &str)> = samples
            .iter()
            .map(|(f, a, _)| (f.to_bits(), a.as_str()))
            .collect();
        if combos.len() < 2 || !any_active {
            return None;
        }
        let mut fractions: Vec<f64> = samples.iter().map(|s| s.0).collect();
        fractions.sort_by(f64::total_cmp);
        fractions.dedup_by(|a, b| a.to_bits() == b.to_bits());
        let aggregators: Vec<String> = samples
            .iter()
            .map(|s| s.1.clone())
            .collect::<BTreeSet<String>>()
            .into_iter()
            .collect();
        let values = fractions
            .iter()
            .map(|f| {
                aggregators
                    .iter()
                    .map(|a| {
                        let hits: Vec<f64> = samples
                            .iter()
                            .filter(|(sf, sa, _)| sf.to_bits() == f.to_bits() && sa == a)
                            .map(|(_, _, acc)| *acc)
                            .collect();
                        if hits.is_empty() {
                            f64::NAN
                        } else {
                            hits.iter().sum::<f64>() / hits.len() as f64
                        }
                    })
                    .collect()
            })
            .collect();
        Some(FrontierReport {
            name: outcome.name.clone(),
            fractions,
            aggregators,
            values,
            compress_labels: Vec::new(),
        })
    }

    fn channel_pivot(outcome: &CampaignOutcome) -> Option<FrontierReport> {
        // (compress label, σ, final accuracy, cumulative ε, wire GB).
        let mut samples: Vec<(String, f64, f64, f64, f64)> = Vec::new();
        let mut any_active = false;
        for c in &outcome.cells {
            if c.error.is_some() {
                continue;
            }
            let Some(report) = &c.report else { continue };
            let label = c.cell.job.channel.compress.label();
            let sigma = c.cell.job.channel.dp.map(|d| d.sigma).unwrap_or(0.0);
            any_active |= c.cell.job.channel.is_active();
            let eps = report.rounds.last().map(|m| m.dp_epsilon).unwrap_or(0.0);
            let wire_gb = report.total_net_bytes() as f64 / 1e9;
            samples.push((label, sigma, report.final_accuracy(), eps, wire_gb));
        }
        let combos: BTreeSet<(&str, u64)> = samples
            .iter()
            .map(|(l, s, ..)| (l.as_str(), s.to_bits()))
            .collect();
        if combos.len() < 2 || !any_active {
            return None;
        }
        let compress_labels: Vec<String> = samples
            .iter()
            .map(|s| s.0.clone())
            .collect::<BTreeSet<String>>()
            .into_iter()
            .collect();
        let mut sigmas: Vec<f64> = samples.iter().map(|s| s.1).collect();
        sigmas.sort_by(f64::total_cmp);
        sigmas.dedup_by(|a, b| a.to_bits() == b.to_bits());
        let mut aggregators = Vec::new();
        for s in &sigmas {
            aggregators.push(format!("accuracy_s{s}"));
            aggregators.push(format!("epsilon_s{s}"));
            aggregators.push(format!("wire_gb_s{s}"));
        }
        let values = compress_labels
            .iter()
            .map(|l| {
                let mut row = Vec::with_capacity(aggregators.len());
                for sg in &sigmas {
                    let hits: Vec<&(String, f64, f64, f64, f64)> = samples
                        .iter()
                        .filter(|(sl, ss, ..)| sl == l && ss.to_bits() == sg.to_bits())
                        .collect();
                    if hits.is_empty() {
                        row.extend([f64::NAN; 3]);
                    } else {
                        let n = hits.len() as f64;
                        row.push(hits.iter().map(|h| h.2).sum::<f64>() / n);
                        row.push(hits.iter().map(|h| h.3).sum::<f64>() / n);
                        row.push(hits.iter().map(|h| h.4).sum::<f64>() / n);
                    }
                }
                row
            })
            .collect();
        Some(FrontierReport {
            name: outcome.name.clone(),
            fractions: Vec::new(),
            aggregators,
            values,
            compress_labels,
        })
    }

    fn is_channel(&self) -> bool {
        !self.compress_labels.is_empty()
    }

    /// First table/CSV column header.
    fn axis_name(&self) -> &'static str {
        if self.is_channel() {
            "compress"
        } else {
            "attack_fraction"
        }
    }

    /// Row label in CSV form (the adversary pivot keeps the raw `f64`
    /// Display it has always written).
    fn row_csv(&self, i: usize) -> String {
        if self.is_channel() {
            self.compress_labels[i].clone()
        } else {
            format!("{}", self.fractions[i])
        }
    }

    fn row_render(&self, i: usize) -> String {
        if self.is_channel() {
            format!("{:>16}", self.compress_labels[i])
        } else {
            format!("{:>16.2}", self.fractions[i])
        }
    }

    fn n_rows(&self) -> usize {
        if self.is_channel() {
            self.compress_labels.len()
        } else {
            self.fractions.len()
        }
    }

    /// Dashboard table (one row per attack fraction / compression label).
    pub fn render(&self) -> String {
        let mut s = if self.is_channel() {
            format!(
                "channel frontier '{}' — mean final accuracy / cumulative ε / wire GB\n",
                self.name
            )
        } else {
            format!("robustness frontier '{}' — mean final accuracy\n", self.name)
        };
        s.push_str(&format!("{:>16}", self.axis_name()));
        for a in &self.aggregators {
            s.push_str(&format!("  {a:>14}"));
        }
        s.push('\n');
        for i in 0..self.n_rows() {
            s.push_str(&self.row_render(i));
            for v in &self.values[i] {
                if v.is_nan() {
                    s.push_str(&format!("  {:>14}", "-"));
                } else {
                    s.push_str(&format!("  {v:>14.4}"));
                }
            }
            s.push('\n');
        }
        s
    }

    /// `attack_fraction,<agg>,...` (or `compress,<metric_sσ>,...`) with one
    /// row per pivot row; empty field = no cell at that combination.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(self.axis_name());
        for a in &self.aggregators {
            s.push(',');
            s.push_str(a);
        }
        s.push('\n');
        for i in 0..self.n_rows() {
            s.push_str(&self.row_csv(i));
            for v in &self.values[i] {
                s.push(',');
                if !v.is_nan() {
                    s.push_str(&format!("{v:.6}"));
                }
            }
            s.push('\n');
        }
        s
    }

    /// Write `<dir>/<name>_frontier.csv`; returns the path.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating report dir {dir:?}"))?;
        let csv = dir.join(format!("{}_frontier.csv", self.name));
        std::fs::write(&csv, self.to_csv()).with_context(|| format!("writing {csv:?}"))?;
        Ok(csv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::grid::Cell;
    use crate::campaign::runner::CellRun;
    use crate::config::job::JobConfig;
    use crate::metrics::report::RoundMetrics;

    fn outcome() -> CampaignOutcome {
        let job = JobConfig::default_cnn("fedavg");
        let report = RunReport {
            label: "a".into(),
            strategy: "fedavg".into(),
            topology: "client_server".into(),
            backend: "cnn".into(),
            n_clients: 4,
            n_workers: 1,
            seed: 1,
            stopped_early: false,
            rounds: vec![RoundMetrics {
                round: 1,
                test_accuracy: 0.5,
                test_loss: 1.1,
                wall_secs: 2.0,
                net_bytes: 2048,
                model_hash: "deadbeef".into(),
                ..Default::default()
            }],
        };
        CampaignOutcome {
            name: "demo".into(),
            cells: vec![
                CellRun {
                    cell: Cell {
                        name: "a".into(),
                        job: job.clone(),
                        key: "k1".into(),
                    },
                    cached: false,
                    report: Some(report),
                    error: None,
                },
                CellRun {
                    cell: Cell {
                        name: "b".into(),
                        job,
                        key: "k2".into(),
                    },
                    cached: false,
                    report: None,
                    error: Some("boom".into()),
                },
            ],
        }
    }

    #[test]
    fn report_covers_completed_cells_only() {
        let rep = CampaignReport::from_outcome(&outcome());
        assert_eq!(rep.rows.len(), 1);
        let csv = rep.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.lines().nth(1).unwrap().starts_with("a,k1,fedavg,client_server,cnn,4,1,1,1,"));
        assert!(csv.contains("deadbeef"));
        let j = rep.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("campaign").and_then(Json::as_str), Some("demo"));
        assert_eq!(parsed.get("cells").and_then(Json::as_arr).unwrap().len(), 1);
    }

    #[test]
    fn report_is_deterministic() {
        let o = outcome();
        let a = CampaignReport::from_outcome(&o);
        let b = CampaignReport::from_outcome(&o);
        assert_eq!(a.to_csv(), b.to_csv());
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    fn frontier_outcome() -> CampaignOutcome {
        let mk = |frac: f64, robust: &str, acc: f64| {
            let mut job = JobConfig::default_cnn("fedavg");
            job.adversary.attack_fraction = frac;
            job.robust_agg = crate::config::adversary::RobustAggConfig::parse_axis(robust).unwrap();
            let report = RunReport {
                label: format!("f{frac}_{robust}"),
                strategy: "fedavg".into(),
                topology: "client_server".into(),
                backend: "cnn".into(),
                n_clients: 4,
                n_workers: 1,
                seed: 1,
                stopped_early: false,
                rounds: vec![RoundMetrics {
                    round: 1,
                    test_accuracy: acc,
                    ..Default::default()
                }],
            };
            CellRun {
                cell: Cell {
                    name: format!("f{frac}_{robust}"),
                    job,
                    key: format!("k_{frac}_{robust}"),
                },
                cached: false,
                report: Some(report),
                error: None,
            }
        };
        CampaignOutcome {
            name: "adv".into(),
            cells: vec![
                mk(0.0, "none", 0.9),
                mk(0.0, "krum", 0.88),
                mk(0.3, "none", 0.2),
                mk(0.3, "krum", 0.8),
            ],
        }
    }

    #[test]
    fn frontier_pivots_fraction_by_aggregator() {
        let f = FrontierReport::from_outcome(&frontier_outcome()).unwrap();
        assert_eq!(f.fractions, vec![0.0, 0.3]);
        assert_eq!(f.aggregators, vec!["krum".to_string(), "weighted_mean".to_string()]);
        // values[row][col]: rows = fractions, cols = sorted aggregators.
        assert_eq!(f.values[0], vec![0.88, 0.9]);
        assert_eq!(f.values[1], vec![0.8, 0.2]);
        let csv = f.to_csv();
        assert!(csv.starts_with("attack_fraction,krum,weighted_mean\n"));
        assert!(csv.contains("0.3,0.800000,0.200000\n"));
        assert!(f.render().contains("robustness frontier 'adv'"));
        // Deterministic.
        let g = FrontierReport::from_outcome(&frontier_outcome()).unwrap();
        assert_eq!(f.to_csv(), g.to_csv());
    }

    #[test]
    fn frontier_absent_for_plain_campaigns() {
        // No adversary axes swept: the smoke outcome has one completed cell
        // with an inactive adversary — no frontier.
        assert!(FrontierReport::from_outcome(&outcome()).is_none());
        // Even several combos without any *active* adversary stay None.
        let mut o = frontier_outcome();
        for c in &mut o.cells {
            c.cell.job.adversary.attack_fraction = 0.0;
        }
        assert!(FrontierReport::from_outcome(&o).is_none());
    }

    fn channel_outcome() -> CampaignOutcome {
        use crate::config::channel::{ChannelConfig, DpConfig};
        let mk = |compress: &str, sigma: f64, acc: f64, eps: f64, bytes: u64| {
            let mut job = JobConfig::default_cnn("fedavg");
            job.channel.compress = ChannelConfig::parse_compress_axis(compress).unwrap();
            job.channel.dp = (sigma > 0.0).then(|| DpConfig {
                clip: 10.0,
                sigma,
                delta: 1e-5,
            });
            let name = format!("{compress}_s{sigma}");
            let report = RunReport {
                label: name.clone(),
                strategy: "fedavg".into(),
                topology: "client_server".into(),
                backend: "cnn".into(),
                n_clients: 4,
                n_workers: 1,
                seed: 1,
                stopped_early: false,
                rounds: vec![RoundMetrics {
                    round: 1,
                    test_accuracy: acc,
                    net_bytes: bytes,
                    dp_epsilon: eps,
                    ..Default::default()
                }],
            };
            CellRun {
                cell: Cell {
                    name: name.clone(),
                    job,
                    key: format!("k_{name}"),
                },
                cached: false,
                report: Some(report),
                error: None,
            }
        };
        CampaignOutcome {
            name: "chan".into(),
            cells: vec![
                mk("none", 0.0, 0.9, 0.0, 3_000_000_000),
                mk("top_k:8000", 0.0, 0.88, 0.0, 700_000_000),
                mk("none", 0.01, 0.85, 12.0, 3_000_000_000),
                mk("top_k:8000", 0.01, 0.83, 12.0, 700_000_000),
            ],
        }
    }

    #[test]
    fn channel_frontier_pivots_compress_by_sigma() {
        let f = FrontierReport::from_outcome(&channel_outcome()).unwrap();
        assert!(f.fractions.is_empty());
        assert_eq!(
            f.compress_labels,
            vec!["none".to_string(), "top_k:8000".to_string()]
        );
        assert_eq!(
            f.aggregators,
            vec![
                "accuracy_s0",
                "epsilon_s0",
                "wire_gb_s0",
                "accuracy_s0.01",
                "epsilon_s0.01",
                "wire_gb_s0.01"
            ]
        );
        // values[row]: (acc, ε, GB) per σ — none row, then top_k row.
        assert_eq!(f.values[0][0], 0.9);
        assert_eq!(f.values[0][1], 0.0);
        assert_eq!(f.values[0][2], 3.0);
        assert_eq!(f.values[0][3], 0.85);
        assert_eq!(f.values[0][4], 12.0);
        assert_eq!(f.values[1][5], 0.7);
        let csv = f.to_csv();
        assert!(csv.starts_with("compress,accuracy_s0,epsilon_s0,wire_gb_s0,"));
        assert!(csv.contains("top_k:8000,0.880000,"));
        assert!(f.render().contains("channel frontier 'chan'"));
        // Deterministic.
        let g = FrontierReport::from_outcome(&channel_outcome()).unwrap();
        assert_eq!(f.to_csv(), g.to_csv());
    }

    #[test]
    fn adversary_pivot_wins_when_both_surfaces_swept() {
        let mut o = frontier_outcome();
        for c in &mut o.cells {
            c.cell.job.channel.compress =
                crate::config::channel::ChannelConfig::parse_compress_axis("quantize:4").unwrap();
        }
        let f = FrontierReport::from_outcome(&o).unwrap();
        assert!(f.compress_labels.is_empty());
        assert!(f.to_csv().starts_with("attack_fraction,"));
    }

    #[test]
    fn channel_frontier_requires_a_genuine_sweep() {
        // A single (compress, σ) combination — even an active one — is not
        // a sweep.
        let mut o = channel_outcome();
        o.cells.truncate(1);
        assert!(FrontierReport::from_outcome(&o).is_none());
    }

    #[test]
    fn cell_rows_carry_dp_epsilon() {
        let rep = CampaignReport::from_outcome(&channel_outcome());
        assert_eq!(rep.rows[2].dp_epsilon, 12.0);
        let csv = rep.to_csv();
        assert!(csv.lines().next().unwrap().ends_with(",model_hash,dp_epsilon"));
        assert!(csv.lines().nth(3).unwrap().ends_with(",12.000000"));
        let j = rep.to_json().to_string();
        assert!(j.contains("\"dp_epsilon\":12"));
    }

    #[test]
    fn outcome_accessors() {
        let o = outcome();
        assert_eq!(o.completed().len(), 1);
        assert_eq!(o.failed().len(), 1);
        assert!(!o.all_cached());
        assert_eq!(o.summary(), "campaign 'demo': 2 cells — 0 cached, 1 run, 1 failed");
    }
}
