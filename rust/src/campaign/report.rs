//! The campaign report: per-cell summary metrics aggregated into one
//! CSV/JSON artifact (plus the dashboard comparison table rendered by the
//! CLI).
//!
//! Rows are built purely from the stored per-cell [`RunReport`]s, in
//! expansion order — so a campaign resumed entirely from cache reproduces
//! its report byte-for-byte (the stored first-run wall clocks included).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::campaign::runner::CampaignOutcome;
use crate::metrics::report::RunReport;
use crate::util::json::Json;

/// One completed cell's summary row.
#[derive(Clone, Debug)]
pub struct CellRow {
    pub cell: String,
    pub key: String,
    pub strategy: String,
    pub topology: String,
    pub backend: String,
    pub n_clients: usize,
    pub n_workers: usize,
    pub seed: u64,
    pub rounds: usize,
    /// The scheduler stopped this cell before its full round budget
    /// (`rounds` is then the rung boundary it reached).
    pub stopped_early: bool,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub final_loss: f64,
    pub wall_secs: f64,
    pub sim_round_secs: f64,
    pub net_bytes: u64,
    /// Final-round global model hash (provenance).
    pub model_hash: String,
}

impl CellRow {
    fn new(cell: &str, key: &str, r: &RunReport) -> CellRow {
        CellRow {
            cell: cell.to_string(),
            key: key.to_string(),
            strategy: r.strategy.clone(),
            topology: r.topology.clone(),
            backend: r.backend.clone(),
            n_clients: r.n_clients,
            n_workers: r.n_workers,
            seed: r.seed,
            rounds: r.rounds.len(),
            stopped_early: r.stopped_early,
            final_accuracy: r.final_accuracy(),
            best_accuracy: r.best_accuracy(),
            final_loss: r.final_loss(),
            wall_secs: r.total_wall_secs(),
            sim_round_secs: r.total_sim_round_secs(),
            net_bytes: r.total_net_bytes(),
            model_hash: r
                .rounds
                .last()
                .map(|m| m.model_hash.clone())
                .unwrap_or_default(),
        }
    }
}

/// The aggregated campaign report.
#[derive(Clone, Debug)]
pub struct CampaignReport {
    pub name: String,
    pub rows: Vec<CellRow>,
}

impl CampaignReport {
    /// Build from a finished campaign (completed-and-persisted cells only,
    /// in expansion order — failed cells are the CLI's problem, not the
    /// report's; a cell whose store-put failed re-runs on retry, so putting
    /// it in the report would break byte-identical resume).
    pub fn from_outcome(outcome: &CampaignOutcome) -> CampaignReport {
        CampaignReport {
            name: outcome.name.clone(),
            rows: outcome
                .cells
                .iter()
                .filter(|c| c.error.is_none())
                .filter_map(|c| {
                    c.report
                        .as_ref()
                        .map(|r| CellRow::new(&c.cell.name, &c.cell.key, r))
                })
                .collect(),
        }
    }

    /// One row per cell.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "cell,key,strategy,topology,backend,n_clients,n_workers,seed,rounds,stopped_early,\
             final_accuracy,best_accuracy,final_loss,wall_secs,sim_round_secs,net_bytes,model_hash\n",
        );
        for r in &self.rows {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{:.4},{:.4},{},{}\n",
                r.cell,
                r.key,
                r.strategy,
                r.topology,
                r.backend,
                r.n_clients,
                r.n_workers,
                r.seed,
                r.rounds,
                r.stopped_early,
                r.final_accuracy,
                r.best_accuracy,
                r.final_loss,
                r.wall_secs,
                r.sim_round_secs,
                r.net_bytes,
                r.model_hash
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::from("flsim-campaign-v1")),
            ("campaign", Json::from(self.name.as_str())),
            (
                "cells",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("cell", Json::from(r.cell.as_str())),
                                ("key", Json::from(r.key.as_str())),
                                ("strategy", Json::from(r.strategy.as_str())),
                                ("topology", Json::from(r.topology.as_str())),
                                ("backend", Json::from(r.backend.as_str())),
                                ("n_clients", Json::from(r.n_clients)),
                                ("n_workers", Json::from(r.n_workers)),
                                ("seed", Json::from(r.seed as usize)),
                                ("rounds", Json::from(r.rounds)),
                                ("stopped_early", Json::from(r.stopped_early)),
                                ("final_accuracy", Json::Num(r.final_accuracy)),
                                ("best_accuracy", Json::Num(r.best_accuracy)),
                                ("final_loss", Json::Num(r.final_loss)),
                                ("wall_secs", Json::Num(r.wall_secs)),
                                ("sim_round_secs", Json::Num(r.sim_round_secs)),
                                ("net_bytes", Json::from(r.net_bytes as usize)),
                                ("model_hash", Json::from(r.model_hash.as_str())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `<dir>/<name>.csv` and `<dir>/<name>.json`; returns the paths.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<(PathBuf, PathBuf)> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating report dir {dir:?}"))?;
        let csv = dir.join(format!("{}.csv", self.name));
        let json = dir.join(format!("{}.json", self.name));
        std::fs::write(&csv, self.to_csv()).with_context(|| format!("writing {csv:?}"))?;
        std::fs::write(&json, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing {json:?}"))?;
        Ok((csv, json))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::grid::Cell;
    use crate::campaign::runner::CellOutcome;
    use crate::config::job::JobConfig;
    use crate::metrics::report::RoundMetrics;

    fn outcome() -> CampaignOutcome {
        let job = JobConfig::default_cnn("fedavg");
        let report = RunReport {
            label: "a".into(),
            strategy: "fedavg".into(),
            topology: "client_server".into(),
            backend: "cnn".into(),
            n_clients: 4,
            n_workers: 1,
            seed: 1,
            stopped_early: false,
            rounds: vec![RoundMetrics {
                round: 1,
                test_accuracy: 0.5,
                test_loss: 1.1,
                wall_secs: 2.0,
                net_bytes: 2048,
                model_hash: "deadbeef".into(),
                ..Default::default()
            }],
        };
        CampaignOutcome {
            name: "demo".into(),
            cells: vec![
                CellOutcome {
                    cell: Cell {
                        name: "a".into(),
                        job: job.clone(),
                        key: "k1".into(),
                    },
                    cached: false,
                    report: Some(report),
                    error: None,
                },
                CellOutcome {
                    cell: Cell {
                        name: "b".into(),
                        job,
                        key: "k2".into(),
                    },
                    cached: false,
                    report: None,
                    error: Some("boom".into()),
                },
            ],
        }
    }

    #[test]
    fn report_covers_completed_cells_only() {
        let rep = CampaignReport::from_outcome(&outcome());
        assert_eq!(rep.rows.len(), 1);
        let csv = rep.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.lines().nth(1).unwrap().starts_with("a,k1,fedavg,client_server,cnn,4,1,1,1,"));
        assert!(csv.contains("deadbeef"));
        let j = rep.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("campaign").and_then(Json::as_str), Some("demo"));
        assert_eq!(parsed.get("cells").and_then(Json::as_arr).unwrap().len(), 1);
    }

    #[test]
    fn report_is_deterministic() {
        let o = outcome();
        let a = CampaignReport::from_outcome(&o);
        let b = CampaignReport::from_outcome(&o);
        assert_eq!(a.to_csv(), b.to_csv());
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn outcome_accessors() {
        let o = outcome();
        assert_eq!(o.completed().len(), 1);
        assert_eq!(o.failed().len(), 1);
        assert!(!o.all_cached());
        assert_eq!(o.summary(), "campaign 'demo': 2 cells — 0 cached, 1 run, 1 failed");
    }
}
