//! The campaign report: per-cell summary metrics aggregated into one
//! CSV/JSON artifact (plus the dashboard comparison table rendered by the
//! CLI).
//!
//! Rows are built purely from the stored per-cell [`RunReport`]s, in
//! expansion order — so a campaign resumed entirely from cache reproduces
//! its report byte-for-byte (the stored first-run wall clocks included).

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::campaign::runner::CampaignOutcome;
use crate::config::adversary::RobustAggKind;
use crate::metrics::report::RunReport;
use crate::util::json::Json;

/// One completed cell's summary row.
#[derive(Clone, Debug)]
pub struct CellRow {
    pub cell: String,
    pub key: String,
    pub strategy: String,
    pub topology: String,
    pub backend: String,
    pub n_clients: usize,
    pub n_workers: usize,
    pub seed: u64,
    pub rounds: usize,
    /// The scheduler stopped this cell before its full round budget
    /// (`rounds` is then the rung boundary it reached).
    pub stopped_early: bool,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub final_loss: f64,
    pub wall_secs: f64,
    pub sim_round_secs: f64,
    pub net_bytes: u64,
    /// Final-round global model hash (provenance).
    pub model_hash: String,
}

impl CellRow {
    fn new(cell: &str, key: &str, r: &RunReport) -> CellRow {
        CellRow {
            cell: cell.to_string(),
            key: key.to_string(),
            strategy: r.strategy.clone(),
            topology: r.topology.clone(),
            backend: r.backend.clone(),
            n_clients: r.n_clients,
            n_workers: r.n_workers,
            seed: r.seed,
            rounds: r.rounds.len(),
            stopped_early: r.stopped_early,
            final_accuracy: r.final_accuracy(),
            best_accuracy: r.best_accuracy(),
            final_loss: r.final_loss(),
            wall_secs: r.total_wall_secs(),
            sim_round_secs: r.total_sim_round_secs(),
            net_bytes: r.total_net_bytes(),
            model_hash: r
                .rounds
                .last()
                .map(|m| m.model_hash.clone())
                .unwrap_or_default(),
        }
    }
}

/// The aggregated campaign report.
#[derive(Clone, Debug)]
pub struct CampaignReport {
    pub name: String,
    pub rows: Vec<CellRow>,
}

impl CampaignReport {
    /// Build from a finished campaign (completed-and-persisted cells only,
    /// in expansion order — failed cells are the CLI's problem, not the
    /// report's; a cell whose store-put failed re-runs on retry, so putting
    /// it in the report would break byte-identical resume).
    pub fn from_outcome(outcome: &CampaignOutcome) -> CampaignReport {
        CampaignReport {
            name: outcome.name.clone(),
            rows: outcome
                .cells
                .iter()
                .filter(|c| c.error.is_none())
                .filter_map(|c| {
                    c.report
                        .as_ref()
                        .map(|r| CellRow::new(&c.cell.name, &c.cell.key, r))
                })
                .collect(),
        }
    }

    /// One row per cell.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "cell,key,strategy,topology,backend,n_clients,n_workers,seed,rounds,stopped_early,\
             final_accuracy,best_accuracy,final_loss,wall_secs,sim_round_secs,net_bytes,model_hash\n",
        );
        for r in &self.rows {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{:.4},{:.4},{},{}\n",
                r.cell,
                r.key,
                r.strategy,
                r.topology,
                r.backend,
                r.n_clients,
                r.n_workers,
                r.seed,
                r.rounds,
                r.stopped_early,
                r.final_accuracy,
                r.best_accuracy,
                r.final_loss,
                r.wall_secs,
                r.sim_round_secs,
                r.net_bytes,
                r.model_hash
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::from("flsim-campaign-v1")),
            ("campaign", Json::from(self.name.as_str())),
            (
                "cells",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("cell", Json::from(r.cell.as_str())),
                                ("key", Json::from(r.key.as_str())),
                                ("strategy", Json::from(r.strategy.as_str())),
                                ("topology", Json::from(r.topology.as_str())),
                                ("backend", Json::from(r.backend.as_str())),
                                ("n_clients", Json::from(r.n_clients)),
                                ("n_workers", Json::from(r.n_workers)),
                                ("seed", Json::from(r.seed as usize)),
                                ("rounds", Json::from(r.rounds)),
                                ("stopped_early", Json::from(r.stopped_early)),
                                ("final_accuracy", Json::Num(r.final_accuracy)),
                                ("best_accuracy", Json::Num(r.best_accuracy)),
                                ("final_loss", Json::Num(r.final_loss)),
                                ("wall_secs", Json::Num(r.wall_secs)),
                                ("sim_round_secs", Json::Num(r.sim_round_secs)),
                                ("net_bytes", Json::from(r.net_bytes as usize)),
                                ("model_hash", Json::from(r.model_hash.as_str())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `<dir>/<name>.csv` and `<dir>/<name>.json`; returns the paths.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<(PathBuf, PathBuf)> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating report dir {dir:?}"))?;
        let csv = dir.join(format!("{}.csv", self.name));
        let json = dir.join(format!("{}.json", self.name));
        std::fs::write(&csv, self.to_csv()).with_context(|| format!("writing {csv:?}"))?;
        std::fs::write(&json, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing {json:?}"))?;
        Ok((csv, json))
    }
}

/// The robustness frontier: mean final accuracy pivoted over (attack
/// fraction × aggregator) — what a one-YAML attack×defense sweep is run
/// for. Rows are the sorted distinct `attack_fraction` values, columns the
/// sorted aggregator labels (`weighted_mean` when no robust aggregator is
/// configured), and each value averages the final accuracy of every
/// completed cell landing in that (fraction, aggregator) combination
/// (NaN = no cell there).
#[derive(Clone, Debug)]
pub struct FrontierReport {
    pub name: String,
    pub fractions: Vec<f64>,
    pub aggregators: Vec<String>,
    /// `values[row][col]`, row-major over `fractions` × `aggregators`.
    pub values: Vec<Vec<f64>>,
}

impl FrontierReport {
    /// Pivot a finished campaign into a frontier. Returns `None` unless the
    /// campaign genuinely swept the adversary surface — at least two
    /// distinct (fraction, aggregator) combinations and at least one cell
    /// with an active adversary — so plain campaigns never grow an extra
    /// artifact.
    pub fn from_outcome(outcome: &CampaignOutcome) -> Option<FrontierReport> {
        let mut samples: Vec<(f64, String, f64)> = Vec::new();
        let mut any_active = false;
        for c in &outcome.cells {
            if c.error.is_some() {
                continue;
            }
            let Some(report) = &c.report else { continue };
            let frac = c.cell.job.adversary.attack_fraction;
            let agg = match c.cell.job.robust_agg.kind {
                RobustAggKind::None => "weighted_mean".to_string(),
                kind => kind.name().to_string(),
            };
            any_active |= c.cell.job.adversary.is_active();
            samples.push((frac, agg, report.final_accuracy()));
        }
        let combos: BTreeSet<(u64, &str)> = samples
            .iter()
            .map(|(f, a, _)| (f.to_bits(), a.as_str()))
            .collect();
        if combos.len() < 2 || !any_active {
            return None;
        }
        let mut fractions: Vec<f64> = samples.iter().map(|s| s.0).collect();
        fractions.sort_by(f64::total_cmp);
        fractions.dedup_by(|a, b| a.to_bits() == b.to_bits());
        let aggregators: Vec<String> = samples
            .iter()
            .map(|s| s.1.clone())
            .collect::<BTreeSet<String>>()
            .into_iter()
            .collect();
        let values = fractions
            .iter()
            .map(|f| {
                aggregators
                    .iter()
                    .map(|a| {
                        let hits: Vec<f64> = samples
                            .iter()
                            .filter(|(sf, sa, _)| sf.to_bits() == f.to_bits() && sa == a)
                            .map(|(_, _, acc)| *acc)
                            .collect();
                        if hits.is_empty() {
                            f64::NAN
                        } else {
                            hits.iter().sum::<f64>() / hits.len() as f64
                        }
                    })
                    .collect()
            })
            .collect();
        Some(FrontierReport {
            name: outcome.name.clone(),
            fractions,
            aggregators,
            values,
        })
    }

    /// Dashboard table (one row per attack fraction).
    pub fn render(&self) -> String {
        let mut s = format!("robustness frontier '{}' — mean final accuracy\n", self.name);
        s.push_str(&format!("{:>16}", "attack_fraction"));
        for a in &self.aggregators {
            s.push_str(&format!("  {a:>14}"));
        }
        s.push('\n');
        for (i, f) in self.fractions.iter().enumerate() {
            s.push_str(&format!("{f:>16.2}"));
            for v in &self.values[i] {
                if v.is_nan() {
                    s.push_str(&format!("  {:>14}", "-"));
                } else {
                    s.push_str(&format!("  {v:>14.4}"));
                }
            }
            s.push('\n');
        }
        s
    }

    /// `attack_fraction,<agg>,...` with one row per fraction; empty field =
    /// no cell at that combination.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("attack_fraction");
        for a in &self.aggregators {
            s.push(',');
            s.push_str(a);
        }
        s.push('\n');
        for (i, f) in self.fractions.iter().enumerate() {
            s.push_str(&format!("{f}"));
            for v in &self.values[i] {
                s.push(',');
                if !v.is_nan() {
                    s.push_str(&format!("{v:.6}"));
                }
            }
            s.push('\n');
        }
        s
    }

    /// Write `<dir>/<name>_frontier.csv`; returns the path.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating report dir {dir:?}"))?;
        let csv = dir.join(format!("{}_frontier.csv", self.name));
        std::fs::write(&csv, self.to_csv()).with_context(|| format!("writing {csv:?}"))?;
        Ok(csv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::grid::Cell;
    use crate::campaign::runner::CellOutcome;
    use crate::config::job::JobConfig;
    use crate::metrics::report::RoundMetrics;

    fn outcome() -> CampaignOutcome {
        let job = JobConfig::default_cnn("fedavg");
        let report = RunReport {
            label: "a".into(),
            strategy: "fedavg".into(),
            topology: "client_server".into(),
            backend: "cnn".into(),
            n_clients: 4,
            n_workers: 1,
            seed: 1,
            stopped_early: false,
            rounds: vec![RoundMetrics {
                round: 1,
                test_accuracy: 0.5,
                test_loss: 1.1,
                wall_secs: 2.0,
                net_bytes: 2048,
                model_hash: "deadbeef".into(),
                ..Default::default()
            }],
        };
        CampaignOutcome {
            name: "demo".into(),
            cells: vec![
                CellOutcome {
                    cell: Cell {
                        name: "a".into(),
                        job: job.clone(),
                        key: "k1".into(),
                    },
                    cached: false,
                    report: Some(report),
                    error: None,
                },
                CellOutcome {
                    cell: Cell {
                        name: "b".into(),
                        job,
                        key: "k2".into(),
                    },
                    cached: false,
                    report: None,
                    error: Some("boom".into()),
                },
            ],
        }
    }

    #[test]
    fn report_covers_completed_cells_only() {
        let rep = CampaignReport::from_outcome(&outcome());
        assert_eq!(rep.rows.len(), 1);
        let csv = rep.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.lines().nth(1).unwrap().starts_with("a,k1,fedavg,client_server,cnn,4,1,1,1,"));
        assert!(csv.contains("deadbeef"));
        let j = rep.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("campaign").and_then(Json::as_str), Some("demo"));
        assert_eq!(parsed.get("cells").and_then(Json::as_arr).unwrap().len(), 1);
    }

    #[test]
    fn report_is_deterministic() {
        let o = outcome();
        let a = CampaignReport::from_outcome(&o);
        let b = CampaignReport::from_outcome(&o);
        assert_eq!(a.to_csv(), b.to_csv());
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    fn frontier_outcome() -> CampaignOutcome {
        let mk = |frac: f64, robust: &str, acc: f64| {
            let mut job = JobConfig::default_cnn("fedavg");
            job.adversary.attack_fraction = frac;
            job.robust_agg = crate::config::adversary::RobustAggConfig::parse_axis(robust).unwrap();
            let report = RunReport {
                label: format!("f{frac}_{robust}"),
                strategy: "fedavg".into(),
                topology: "client_server".into(),
                backend: "cnn".into(),
                n_clients: 4,
                n_workers: 1,
                seed: 1,
                stopped_early: false,
                rounds: vec![RoundMetrics {
                    round: 1,
                    test_accuracy: acc,
                    ..Default::default()
                }],
            };
            CellOutcome {
                cell: Cell {
                    name: format!("f{frac}_{robust}"),
                    job,
                    key: format!("k_{frac}_{robust}"),
                },
                cached: false,
                report: Some(report),
                error: None,
            }
        };
        CampaignOutcome {
            name: "adv".into(),
            cells: vec![
                mk(0.0, "none", 0.9),
                mk(0.0, "krum", 0.88),
                mk(0.3, "none", 0.2),
                mk(0.3, "krum", 0.8),
            ],
        }
    }

    #[test]
    fn frontier_pivots_fraction_by_aggregator() {
        let f = FrontierReport::from_outcome(&frontier_outcome()).unwrap();
        assert_eq!(f.fractions, vec![0.0, 0.3]);
        assert_eq!(f.aggregators, vec!["krum".to_string(), "weighted_mean".to_string()]);
        // values[row][col]: rows = fractions, cols = sorted aggregators.
        assert_eq!(f.values[0], vec![0.88, 0.9]);
        assert_eq!(f.values[1], vec![0.8, 0.2]);
        let csv = f.to_csv();
        assert!(csv.starts_with("attack_fraction,krum,weighted_mean\n"));
        assert!(csv.contains("0.3,0.800000,0.200000\n"));
        assert!(f.render().contains("robustness frontier 'adv'"));
        // Deterministic.
        let g = FrontierReport::from_outcome(&frontier_outcome()).unwrap();
        assert_eq!(f.to_csv(), g.to_csv());
    }

    #[test]
    fn frontier_absent_for_plain_campaigns() {
        // No adversary axes swept: the smoke outcome has one completed cell
        // with an inactive adversary — no frontier.
        assert!(FrontierReport::from_outcome(&outcome()).is_none());
        // Even several combos without any *active* adversary stay None.
        let mut o = frontier_outcome();
        for c in &mut o.cells {
            c.cell.job.adversary.attack_fraction = 0.0;
        }
        assert!(FrontierReport::from_outcome(&o).is_none());
    }

    #[test]
    fn outcome_accessors() {
        let o = outcome();
        assert_eq!(o.completed().len(), 1);
        assert_eq!(o.failed().len(), 1);
        assert!(!o.all_cached());
        assert_eq!(o.summary(), "campaign 'demo': 2 cells — 0 cached, 1 run, 1 failed");
    }
}
