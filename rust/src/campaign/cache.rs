//! Content-addressed on-disk result store.
//!
//! Every completed cell is written to `<store>/<k[0..2]>/<key>.json`, where
//! `key = SHA-256(ENGINE_VERSION ‖ canonical JobConfig JSON)`. Because the
//! key covers every result-relevant config field (and the engine version)
//! but *not* wall-clock knobs like `parallelism`, re-running a campaign
//! resumes instantly: unchanged cells are cache hits at any schedule, and
//! spec edits re-run exactly the cells they touch.
//!
//! **Partial (rung-stopped) entries.** A cell stopped early by the ASHA
//! scheduler stores its prefix report under the *same* key as the full run
//! (rung budgets are runtime limits, not config — the key is the full
//! config's). Lookups are depth-aware:
//! * [`ResultStore::get`] serves **complete** entries only, so a grid
//!   campaign never mistakes a rung-stopped prefix for a finished run;
//! * [`ResultStore::get_at_least`] serves any entry with at least the
//!   requested number of rounds — a partial entry is a cache *hit for its
//!   rung* (the determinism contract makes a stored prefix bitwise equal to
//!   re-running that prefix);
//! * [`ResultStore::put_partial`] only ever deepens an entry (a shallower
//!   rung result never overwrites a deeper or complete one), so promoting a
//!   cell to a deeper rung extends its entry monotonically.
//!
//! A stored cell carries the full [`RunReport`] (including first-run wall
//! times), so a resumed campaign reproduces its report **byte-identically**
//! — enforced by `rust/tests/campaign.rs`.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use crate::config::job::JobConfig;
use crate::metrics::report::RunReport;
use crate::util::hash;
use crate::util::json::Json;

/// Bumped whenever the engine's numeric contract changes (a new reduction
/// semantics, a retrained reference backend, ...) so stale cells re-run
/// instead of being served from cache.
pub const ENGINE_VERSION: &str = concat!("flsim-", env!("CARGO_PKG_VERSION"), "+engine.v4");

/// Schema tag of one stored cell document. v2 added partial (rung-stopped)
/// entries — the report's `stopped_early` flag and prefix depth; v1 entries
/// read as a miss and simply re-run.
const CELL_SCHEMA: &str = "flsim-cell-v2";

/// The content-addressed key of a resolved job config.
pub fn cell_key(job: &JobConfig) -> String {
    let doc = format!("{}\n{}", ENGINE_VERSION, job.canonical_json());
    hash::sha256_hex(doc.as_bytes())
}

/// What `campaign gc` did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcStats {
    pub scanned: usize,
    pub evicted: usize,
    pub kept: usize,
    /// Crash/cancel residue (`.tmp` files) removed alongside.
    pub tmp_removed: usize,
}

/// Eviction policy for [`ResultStore::gc`]. Entries matching *either* bound
/// are evicted (protected keys always survive).
#[derive(Clone, Copy, Debug, Default)]
pub struct GcOptions {
    /// Evict entries whose file is older than this.
    pub max_age: Option<Duration>,
    /// Keep at most this many newest unprotected entries.
    pub keep_last: Option<usize>,
    /// Sweep `.tmp` residue older than this (`None` = one hour). A young
    /// temp file may belong to a *live* writer between its write and
    /// rename — deleting it would fail that writer's atomic commit — so
    /// only residue older than the bound is treated as crash debris.
    pub tmp_max_age: Option<Duration>,
}

/// An on-disk result store rooted at one directory.
pub struct ResultStore {
    dir: PathBuf,
}

impl ResultStore {
    pub fn open(dir: impl AsRef<Path>) -> Result<ResultStore> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating result store {dir:?}"))?;
        Ok(ResultStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn shard(&self, key: &str) -> PathBuf {
        self.dir.join(&key[..2])
    }

    /// Where a cell with this key lives (whether or not it exists yet).
    pub fn path_of(&self, key: &str) -> PathBuf {
        self.shard(key).join(format!("{key}.json"))
    }

    /// Whether a *loadable, complete* entry exists — delegates to
    /// [`ResultStore::get`] so `campaign list`'s cached/pending column
    /// agrees with what `run` will actually do (a corrupt, stale-schema, or
    /// rung-stopped partial file is not "cached" for a full run).
    pub fn contains(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    /// Load the raw stored report regardless of depth. Missing, corrupt, or
    /// stale-schema entries all read as a miss.
    fn get_any(&self, key: &str) -> Option<RunReport> {
        let src = std::fs::read_to_string(self.path_of(key)).ok()?;
        let doc = Json::parse(&src).ok()?;
        if doc.get("schema").and_then(Json::as_str) != Some(CELL_SCHEMA) {
            return None;
        }
        if doc.get("engine").and_then(Json::as_str) != Some(ENGINE_VERSION) {
            return None;
        }
        RunReport::from_json(doc.get("report")?).ok()
    }

    /// Load a cached **complete** cell report. Missing, corrupt,
    /// stale-schema, or partial (rung-stopped) entries all read as a miss
    /// (the cell simply re-runs and overwrites/deepens).
    pub fn get(&self, key: &str) -> Option<RunReport> {
        self.get_any(key).filter(|r| !r.stopped_early)
    }

    /// Load a cached report with at least `rounds` completed rounds — a
    /// complete run, or a partial entry stopped at (or beyond) that depth.
    /// The caller gets the stored report as-is (possibly deeper than
    /// `rounds`); truncate with [`RunReport::truncated`] when a rung-exact
    /// prefix is needed.
    pub fn get_at_least(&self, key: &str, rounds: u64) -> Option<RunReport> {
        self.get_any(key)
            .filter(|r| !r.stopped_early || r.rounds_completed() >= rounds)
    }

    /// Persist one completed cell (atomic: temp file + rename, so a
    /// concurrent or crashed campaign never leaves a half-written entry).
    ///
    /// `campaign` records which campaign first computed the entry —
    /// provenance only, surfaced by `campaign list`'s dedup statistics.
    /// It is *not* part of the key: the whole point of content addressing
    /// is that identically-configured cells of different campaigns share
    /// one entry.
    pub fn put(
        &self,
        key: &str,
        cell: &str,
        campaign: &str,
        job: &JobConfig,
        report: &RunReport,
    ) -> Result<()> {
        let doc = Json::obj(vec![
            ("schema", Json::from(CELL_SCHEMA)),
            ("key", Json::from(key)),
            ("engine", Json::from(ENGINE_VERSION)),
            ("cell", Json::from(cell)),
            ("campaign", Json::from(campaign)),
            ("config", job.canonical_json()),
            ("report", report.to_json()),
        ]);
        let shard = self.shard(key);
        std::fs::create_dir_all(&shard)
            .with_context(|| format!("creating store shard {shard:?}"))?;
        // Per-process temp name: two *processes* sharing a store and racing
        // on the same key must not interleave writes into one temp file
        // (within a process, grid dedup guarantees distinct keys).
        let tmp = shard.join(format!(".{key}.{}.tmp", std::process::id()));
        let path = self.path_of(key);
        std::fs::write(&tmp, format!("{doc}\n"))
            .with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing {path:?}"))?;
        Ok(())
    }

    /// Persist a partial (rung-stopped) cell report, but only if it deepens
    /// what is stored: an existing complete entry, or a partial at least as
    /// deep, is left untouched (so replaying a rung never downgrades the
    /// store). Returns whether a write happened.
    ///
    /// The check-then-rename is atomic only within one process. Two
    /// *processes* racing on the same key can interleave so a partial lands
    /// over a just-committed complete entry — never a torn file, and never
    /// wrong results: the next full-run lookup simply misses and the cell
    /// re-executes (wasted compute, not corruption).
    pub fn put_partial(
        &self,
        key: &str,
        cell: &str,
        campaign: &str,
        job: &JobConfig,
        report: &RunReport,
    ) -> Result<bool> {
        if let Some(existing) = self.get_any(key) {
            if !existing.stopped_early || existing.rounds_completed() >= report.rounds_completed() {
                return Ok(false);
            }
        }
        self.put(key, cell, campaign, job, report)?;
        Ok(true)
    }

    /// Which campaign first computed the stored entry. `None` for misses,
    /// corrupt/stale entries, and entries predating the provenance field
    /// (which still serve as cache hits — provenance is informational).
    pub fn origin(&self, key: &str) -> Option<String> {
        let src = std::fs::read_to_string(self.path_of(key)).ok()?;
        let doc = Json::parse(&src).ok()?;
        if doc.get("schema").and_then(Json::as_str) != Some(CELL_SCHEMA) {
            return None;
        }
        if doc.get("engine").and_then(Json::as_str) != Some(ENGINE_VERSION) {
            return None;
        }
        doc.get("campaign")
            .and_then(Json::as_str)
            .map(str::to_string)
    }

    /// Store-wide provenance census: origin campaign → number of loadable
    /// entries it first computed. Entries without the provenance field are
    /// counted under `"(unattributed)"`. Drives `campaign list`'s
    /// cross-campaign dedup summary.
    pub fn census(&self) -> std::collections::BTreeMap<String, usize> {
        let mut out = std::collections::BTreeMap::new();
        for (key, _, _) in self.entries() {
            if self.get_any(&key).is_none() {
                continue; // corrupt or stale-engine: not servable, not counted
            }
            let origin = self
                .origin(&key)
                .unwrap_or_else(|| "(unattributed)".to_string());
            *out.entry(origin).or_insert(0) += 1;
        }
        out
    }

    /// Every entry in the store: `(key, path, mtime)`, unordered.
    /// Unparseable file names are skipped (they are not store entries).
    pub fn entries(&self) -> Vec<(String, PathBuf, SystemTime)> {
        let mut out = Vec::new();
        let Ok(shards) = std::fs::read_dir(&self.dir) else { return out };
        for shard in shards.flatten() {
            if !shard.path().is_dir() {
                continue;
            }
            let Ok(files) = std::fs::read_dir(shard.path()) else { continue };
            for f in files.flatten() {
                let path = f.path();
                let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
                let Some(key) = name.strip_suffix(".json") else { continue };
                if key.len() != 64 || !key.chars().all(|c| c.is_ascii_hexdigit()) {
                    continue;
                }
                let mtime = f
                    .metadata()
                    .and_then(|m| m.modified())
                    .unwrap_or(UNIX_EPOCH);
                out.push((key.to_string(), path, mtime));
            }
        }
        out
    }

    /// Garbage-collect the store: evict unprotected entries older than
    /// `max_age` and/or beyond the `keep_last` newest, and sweep `.tmp`
    /// residue left by crashed/cancelled writers. Keys in `protect` — the
    /// cells of the campaign(s) being resumed — are **never** evicted
    /// (test-enforced), so a gc'd store still resumes them from cache.
    pub fn gc(&self, opts: &GcOptions, protect: &BTreeSet<String>) -> Result<GcStats> {
        let mut stats = GcStats::default();
        let now = SystemTime::now();

        // Newest-first so `keep_last` keeps the most recent results.
        let mut entries = self.entries();
        entries.sort_by(|a, b| b.2.cmp(&a.2).then_with(|| a.0.cmp(&b.0)));

        let mut kept_unprotected = 0usize;
        for (key, path, mtime) in &entries {
            stats.scanned += 1;
            if protect.contains(key) {
                stats.kept += 1;
                continue;
            }
            let too_old = match opts.max_age {
                Some(max) => now
                    .duration_since(*mtime)
                    .map(|age| age > max)
                    .unwrap_or(false),
                None => false,
            };
            let over_count = match opts.keep_last {
                Some(k) => kept_unprotected >= k,
                None => false,
            };
            if too_old || over_count {
                std::fs::remove_file(path)
                    .with_context(|| format!("evicting {path:?}"))?;
                stats.evicted += 1;
            } else {
                kept_unprotected += 1;
                stats.kept += 1;
            }
        }

        // `.tmp` residue: a crash or hard cancel between write and rename
        // leaves these behind — but a *young* temp file may be a live
        // writer mid-commit, so only sweep past the age bound.
        let tmp_bound = opts.tmp_max_age.unwrap_or(Duration::from_secs(3600));
        if let Ok(shards) = std::fs::read_dir(&self.dir) {
            for shard in shards.flatten() {
                if !shard.path().is_dir() {
                    continue;
                }
                if let Ok(files) = std::fs::read_dir(shard.path()) {
                    for f in files.flatten() {
                        let path = f.path();
                        let is_tmp = path.extension().map(|e| e == "tmp").unwrap_or(false);
                        let stale = f
                            .metadata()
                            .and_then(|m| m.modified())
                            .ok()
                            .and_then(|m| now.duration_since(m).ok())
                            .map(|age| age > tmp_bound)
                            .unwrap_or(false);
                        if is_tmp && stale {
                            std::fs::remove_file(&path)
                                .with_context(|| format!("sweeping {path:?}"))?;
                            stats.tmp_removed += 1;
                        }
                    }
                }
            }
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::report::RoundMetrics;

    fn tmp_store(tag: &str) -> (ResultStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "flsim_cache_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        (ResultStore::open(&dir).unwrap(), dir)
    }

    fn round(n: u64) -> RoundMetrics {
        RoundMetrics {
            round: n,
            test_accuracy: 0.2 + 0.1 * n as f64,
            test_loss: 1.5 - 0.1 * n as f64,
            wall_secs: 0.8,
            net_bytes: 1024,
            model_hash: format!("hash{n}"),
            ..Default::default()
        }
    }

    fn report_of(rounds: u64, stopped_early: bool) -> RunReport {
        RunReport {
            label: "cell_a".into(),
            strategy: "fedavg".into(),
            topology: "client_server".into(),
            backend: "cnn".into(),
            n_clients: 4,
            n_workers: 1,
            seed: 1,
            stopped_early,
            rounds: (1..=rounds).map(round).collect(),
        }
    }

    fn report() -> RunReport {
        report_of(1, false)
    }

    #[test]
    fn put_then_get_roundtrips() {
        let (store, dir) = tmp_store("roundtrip");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        assert!(!store.contains(&key));
        assert!(store.get(&key).is_none());
        store.put(&key, "cell_a", "camp", &job, &report()).unwrap();
        assert!(store.contains(&key));
        let back = store.get(&key).unwrap();
        assert_eq!(back.to_json().to_string(), report().to_json().to_string());
        // Content-addressed layout: two-char shard prefix.
        assert!(store.path_of(&key).starts_with(dir.join(&key[..2])));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_entries_read_as_miss() {
        let (store, dir) = tmp_store("corrupt");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        std::fs::create_dir_all(store.path_of(&key).parent().unwrap()).unwrap();
        std::fs::write(store.path_of(&key), "not json at all").unwrap();
        assert!(store.get(&key).is_none());
        // A wrong-schema document is also a miss.
        std::fs::write(store.path_of(&key), "{\"schema\":\"other\"}").unwrap();
        assert!(store.get(&key).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn keys_are_hex_sha256() {
        let key = cell_key(&JobConfig::default_cnn("fedavg"));
        assert_eq!(key.len(), 64);
        assert!(key.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn partial_entries_hit_their_rung_but_not_full_lookups() {
        let (store, dir) = tmp_store("partial");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);

        store.put(&key, "c", "camp", &job, &report_of(2, true)).unwrap();
        // A rung-stopped prefix is not a complete run ...
        assert!(store.get(&key).is_none());
        assert!(!store.contains(&key));
        // ... but is a hit at (or below) its own depth.
        assert_eq!(store.get_at_least(&key, 2).unwrap().rounds_completed(), 2);
        assert!(store.get_at_least(&key, 1).is_some());
        assert!(store.get_at_least(&key, 3).is_none());

        // A complete entry satisfies every depth.
        store.put(&key, "c", "camp", &job, &report_of(3, false)).unwrap();
        assert!(store.get(&key).is_some());
        assert!(store.get_at_least(&key, 99).is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn put_partial_only_deepens() {
        let (store, dir) = tmp_store("deepen");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);

        assert!(store.put_partial(&key, "c", "camp", &job, &report_of(1, true)).unwrap());
        // Same depth again: no write.
        assert!(!store.put_partial(&key, "c", "camp", &job, &report_of(1, true)).unwrap());
        // Deeper partial: upgrades.
        assert!(store.put_partial(&key, "c", "camp", &job, &report_of(2, true)).unwrap());
        assert_eq!(store.get_at_least(&key, 2).unwrap().rounds_completed(), 2);
        // Shallower partial: refused.
        assert!(!store.put_partial(&key, "c", "camp", &job, &report_of(1, true)).unwrap());
        assert_eq!(store.get_at_least(&key, 2).unwrap().rounds_completed(), 2);
        // A complete entry is never downgraded by any partial.
        store.put(&key, "c", "camp", &job, &report_of(3, false)).unwrap();
        assert!(!store.put_partial(&key, "c", "camp", &job, &report_of(2, true)).unwrap());
        assert!(store.get(&key).is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_respects_protection_and_sweeps_tmp() {
        let (store, dir) = tmp_store("gc");
        let mut keys = Vec::new();
        for seed in 0..4u64 {
            let mut job = JobConfig::default_cnn("fedavg");
            job.seed = seed;
            let key = cell_key(&job);
            store.put(&key, "c", "camp", &job, &report()).unwrap();
            keys.push(key);
        }
        // Fake crash residue.
        let tmp = store.path_of(&keys[0]).with_file_name(".junk.123.tmp");
        std::fs::write(&tmp, "torn").unwrap();

        let protect: BTreeSet<String> = keys[..2].iter().cloned().collect();
        let opts = GcOptions {
            keep_last: Some(0),
            max_age: None,
            // Sweep even fresh residue in the test (production default is
            // an hour, sparing live writers mid-commit).
            tmp_max_age: Some(Duration::ZERO),
        };
        let stats = store.gc(&opts, &protect).unwrap();
        assert_eq!(stats.scanned, 4);
        assert_eq!(stats.evicted, 2, "only unprotected entries evicted");
        assert_eq!(stats.kept, 2);
        assert_eq!(stats.tmp_removed, 1);
        assert!(store.contains(&keys[0]) && store.contains(&keys[1]));
        assert!(!store.contains(&keys[2]) && !store.contains(&keys[3]));
        assert!(!tmp.exists());

        // max_age = 0 evicts everything unprotected regardless of count.
        let opts = GcOptions {
            keep_last: None,
            max_age: Some(Duration::from_secs(0)),
            tmp_max_age: None,
        };
        let stats = store.gc(&opts, &BTreeSet::new()).unwrap();
        assert_eq!(stats.evicted, 2);
        assert!(store.entries().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn origin_and_census_track_provenance() {
        let (store, dir) = tmp_store("provenance");
        let mut keys = Vec::new();
        for (seed, campaign) in [(1u64, "alpha"), (2, "alpha"), (3, "beta")] {
            let mut job = JobConfig::default_cnn("fedavg");
            job.seed = seed;
            let key = cell_key(&job);
            store.put(&key, "c", campaign, &job, &report()).unwrap();
            keys.push(key);
        }
        assert_eq!(store.origin(&keys[0]).as_deref(), Some("alpha"));
        assert_eq!(store.origin(&keys[2]).as_deref(), Some("beta"));
        assert_eq!(store.origin("ff".repeat(32).as_str()), None);

        // An entry predating the provenance field still serves but reads
        // unattributed.
        let mut job = JobConfig::default_cnn("fedavg");
        job.seed = 4;
        let legacy_key = cell_key(&job);
        let doc = Json::obj(vec![
            ("schema", Json::from(CELL_SCHEMA)),
            ("key", Json::from(legacy_key.as_str())),
            ("engine", Json::from(ENGINE_VERSION)),
            ("cell", Json::from("c")),
            ("config", job.canonical_json()),
            ("report", report().to_json()),
        ]);
        std::fs::create_dir_all(store.path_of(&legacy_key).parent().unwrap()).unwrap();
        std::fs::write(store.path_of(&legacy_key), format!("{doc}\n")).unwrap();
        assert!(store.contains(&legacy_key));
        assert_eq!(store.origin(&legacy_key), None);

        let census = store.census();
        assert_eq!(census.get("alpha"), Some(&2));
        assert_eq!(census.get("beta"), Some(&1));
        assert_eq!(census.get("(unattributed)"), Some(&1));
        assert_eq!(census.values().sum::<usize>(), 4);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn entries_lists_keys_with_mtimes() {
        let (store, dir) = tmp_store("entries");
        assert!(store.entries().is_empty());
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        store.put(&key, "c", "camp", &job, &report()).unwrap();
        // A stray non-entry file is ignored.
        std::fs::write(dir.join("README"), "not an entry").unwrap();
        let entries = store.entries();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].0, key);
        assert!(entries[0].2 > UNIX_EPOCH);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
