//! Content-addressed on-disk result store.
//!
//! Every completed cell is written to `<store>/<k[0..2]>/<key>.json`, where
//! `key = SHA-256(ENGINE_VERSION ‖ canonical JobConfig JSON)`. Because the
//! key covers every result-relevant config field (and the engine version)
//! but *not* wall-clock knobs like `parallelism`, re-running a campaign
//! resumes instantly: unchanged cells are cache hits at any schedule, and
//! spec edits re-run exactly the cells they touch.
//!
//! **Partial (rung-stopped) entries.** A cell stopped early by the ASHA
//! scheduler stores its prefix report under the *same* key as the full run
//! (rung budgets are runtime limits, not config — the key is the full
//! config's). Lookups are depth-aware:
//! * [`ResultStore::get`] serves **complete** entries only, so a grid
//!   campaign never mistakes a rung-stopped prefix for a finished run;
//! * [`ResultStore::get_at_least`] serves any entry with at least the
//!   requested number of rounds — a partial entry is a cache *hit for its
//!   rung* (the determinism contract makes a stored prefix bitwise equal to
//!   re-running that prefix);
//! * a partial [`ResultStore::commit`] only ever *deepens* an entry (a
//!   shallower rung result never overwrites a deeper or complete one), so
//!   promoting a cell to a deeper rung extends its entry monotonically.
//!
//! **Schema v3** adds two sidecar file kinds next to the cell docs:
//! * `<shard>/<key>.ckpt` — a [`Checkpoint`] blob (the global model,
//!   bit-exact) stored alongside a rung-stopped entry so a later campaign
//!   or another worker resumes the cell *from its rung* instead of round 1;
//!   removed when the entry completes.
//! * `<store>/leases/<key>.lease` + `<store>/failed/<key>.json` — the
//!   worker-coordination layer (see [`crate::campaign::lease`] and
//!   [`crate::campaign::worker`]). Failure markers let one worker's cell
//!   failure unblock every other worker's rung barrier; they are cleared by
//!   the next successful commit of that key.
//!
//! v2 entries still read as cache hits (the report format is unchanged);
//! they simply have no checkpoint, so deepening them replays from scratch.
//! v1 entries read as a miss and re-run.
//!
//! A stored cell carries the full [`RunReport`] (including first-run wall
//! times), so a resumed campaign reproduces its report **byte-identically**
//! — enforced by `rust/tests/campaign.rs`.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use anyhow::{bail, Context, Result};

use crate::campaign::checkpoint::Checkpoint;
use crate::campaign::lease::{self, LeaseConfig};
use crate::config::job::JobConfig;
use crate::metrics::report::RunReport;
use crate::util::hash;
use crate::util::json::Json;

/// Bumped whenever the engine's numeric contract changes (a new reduction
/// semantics, a retrained reference backend, ...) so stale cells re-run
/// instead of being served from cache.
pub const ENGINE_VERSION: &str = concat!("flsim-", env!("CARGO_PKG_VERSION"), "+engine.v4");

/// Schema tag of one stored cell document. v3 added checkpoint sidecars
/// (resumable rung-stopped cells) and the worker-coordination files; v2
/// (partial entries) still reads as a hit; v1 reads as a miss and re-runs.
const CELL_SCHEMA: &str = "flsim-cell-v3";
const CELL_SCHEMA_V2: &str = "flsim-cell-v2";

/// Schema tag of one failure marker (`<store>/failed/<key>.json`).
const FAILED_SCHEMA: &str = "flsim-failed-v1";

/// Subdirectory of the result store holding failure markers.
pub const FAILED_DIR: &str = "failed";

fn schema_ok(s: Option<&str>) -> bool {
    s == Some(CELL_SCHEMA) || s == Some(CELL_SCHEMA_V2)
}

/// The content-addressed key of a resolved job config.
pub fn cell_key(job: &JobConfig) -> String {
    let doc = format!("{}\n{}", ENGINE_VERSION, job.canonical_json());
    hash::sha256_hex(doc.as_bytes())
}

/// One cell execution's result, ready to commit: the report plus its
/// provenance and (for rung-stopped cells) the resumable model state.
/// Build with [`CellOutcome::new`] and chain the optional fields:
///
/// ```ignore
/// store.commit(&key, CellOutcome::new(&job, &report)
///     .cell("lr=0.01/seed=1")
///     .campaign("sweep")
///     .checkpoint(&ckpt))?;
/// ```
#[derive(Clone, Copy)]
pub struct CellOutcome<'a> {
    job: &'a JobConfig,
    report: &'a RunReport,
    cell: &'a str,
    campaign: &'a str,
    checkpoint: Option<&'a Checkpoint>,
}

impl<'a> CellOutcome<'a> {
    pub fn new(job: &'a JobConfig, report: &'a RunReport) -> CellOutcome<'a> {
        CellOutcome {
            job,
            report,
            cell: "",
            campaign: "",
            checkpoint: None,
        }
    }

    /// Cell name within its campaign (provenance, surfaced by `list`).
    pub fn cell(mut self, name: &'a str) -> CellOutcome<'a> {
        self.cell = name;
        self
    }

    /// Which campaign computed this result (provenance only — content
    /// addressing shares identically-configured cells across campaigns).
    pub fn campaign(mut self, name: &'a str) -> CellOutcome<'a> {
        self.campaign = name;
        self
    }

    /// Attach resumable model state to a rung-stopped report. The blob's
    /// depth must match the report's (`commit` enforces it).
    pub fn checkpoint(mut self, ckpt: &'a Checkpoint) -> CellOutcome<'a> {
        self.checkpoint = Some(ckpt);
        self
    }
}

/// What `campaign gc` did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcStats {
    pub scanned: usize,
    pub evicted: usize,
    pub kept: usize,
    /// Crash/cancel residue (`.tmp` files) removed alongside.
    pub tmp_removed: usize,
    /// Checkpoint blobs removed (with their evicted entry, or orphaned).
    pub ckpt_removed: usize,
    /// Expired lease files swept.
    pub leases_swept: usize,
}

/// Eviction policy for [`ResultStore::gc`]. Entries matching *either* bound
/// are evicted (protected keys always survive).
#[derive(Clone, Copy, Debug, Default)]
pub struct GcOptions {
    /// Evict entries whose file is older than this.
    pub max_age: Option<Duration>,
    /// Keep at most this many newest unprotected entries.
    pub keep_last: Option<usize>,
    /// Sweep `.tmp` residue older than this (`None` = one hour). A young
    /// temp file may belong to a *live* writer between its write and
    /// rename — deleting it would fail that writer's atomic commit — so
    /// only residue older than the bound is treated as crash debris.
    pub tmp_max_age: Option<Duration>,
    /// Leases whose heartbeat is younger than this are *live*: their
    /// entries, checkpoints, and temp files are never swept (`None` = the
    /// default lease expiry). Must match the workers' `--expiry-secs`.
    pub lease_expiry: Option<Duration>,
}

/// An on-disk result store rooted at one directory.
pub struct ResultStore {
    dir: PathBuf,
}

impl ResultStore {
    pub fn open(dir: impl AsRef<Path>) -> Result<ResultStore> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating result store {dir:?}"))?;
        Ok(ResultStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn shard(&self, key: &str) -> PathBuf {
        self.dir.join(&key[..2])
    }

    /// Where a cell with this key lives (whether or not it exists yet).
    pub fn path_of(&self, key: &str) -> PathBuf {
        self.shard(key).join(format!("{key}.json"))
    }

    /// Where a cell's checkpoint blob lives (whether or not it exists yet).
    pub fn checkpoint_path(&self, key: &str) -> PathBuf {
        self.shard(key).join(format!("{key}.ckpt"))
    }

    /// Whether a *loadable, complete* entry exists — delegates to
    /// [`ResultStore::get`] so `campaign list`'s cached/pending column
    /// agrees with what `run` will actually do (a corrupt, stale-schema, or
    /// rung-stopped partial file is not "cached" for a full run).
    pub fn contains(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    /// Load the raw stored report regardless of depth. Missing, corrupt, or
    /// stale-schema entries all read as a miss.
    fn get_any(&self, key: &str) -> Option<RunReport> {
        let src = std::fs::read_to_string(self.path_of(key)).ok()?;
        let doc = Json::parse(&src).ok()?;
        if !schema_ok(doc.get("schema").and_then(Json::as_str)) {
            return None;
        }
        if doc.get("engine").and_then(Json::as_str) != Some(ENGINE_VERSION) {
            return None;
        }
        RunReport::from_json(doc.get("report")?).ok()
    }

    /// Load a cached **complete** cell report. Missing, corrupt,
    /// stale-schema, or partial (rung-stopped) entries all read as a miss
    /// (the cell simply re-runs and overwrites/deepens).
    pub fn get(&self, key: &str) -> Option<RunReport> {
        self.get_any(key).filter(|r| !r.stopped_early)
    }

    /// Load a cached report with at least `rounds` completed rounds — a
    /// complete run, or a partial entry stopped at (or beyond) that depth.
    /// The caller gets the stored report as-is (possibly deeper than
    /// `rounds`); truncate with [`RunReport::truncated`] when a rung-exact
    /// prefix is needed.
    pub fn get_at_least(&self, key: &str, rounds: u64) -> Option<RunReport> {
        self.get_any(key)
            .filter(|r| !r.stopped_early || r.rounds_completed() >= rounds)
    }

    /// Commit one cell execution (atomic: temp file + rename, so a
    /// concurrent or crashed campaign never leaves a half-written entry).
    /// This is the single write path:
    ///
    /// * a **complete** report (`!stopped_early`) always writes, removes
    ///   any now-redundant checkpoint blob, and clears the key's failure
    ///   marker;
    /// * a **partial** (rung-stopped) report only *deepens*: an existing
    ///   complete entry, or a partial at least as deep, is left untouched —
    ///   replaying a rung never downgrades the store. An attached
    ///   [`Checkpoint`] is written first (sidecar, then the doc rename as
    ///   the commit point).
    ///
    /// Returns whether a write happened.
    ///
    /// The check-then-rename is atomic only within one process. Two
    /// *processes* racing on the same key can interleave so a partial lands
    /// over a just-committed complete entry — never a torn file, and never
    /// wrong results: the next full-run lookup simply misses and the cell
    /// re-executes (wasted compute, not corruption). The lease layer
    /// ([`crate::campaign::lease`]) exists to make that race rare.
    pub fn commit(&self, key: &str, outcome: CellOutcome<'_>) -> Result<bool> {
        let report = outcome.report;
        if report.stopped_early {
            if let Some(existing) = self.get_any(key) {
                if !existing.stopped_early
                    || existing.rounds_completed() >= report.rounds_completed()
                {
                    return Ok(false);
                }
            }
        }
        if let Some(ckpt) = outcome.checkpoint {
            if !report.stopped_early {
                bail!("commit: a complete report needs no checkpoint");
            }
            if ckpt.key != key || ckpt.rounds != report.rounds_completed() {
                bail!(
                    "commit: checkpoint (key {}.., round {}) does not match the \
                     report (key {}.., round {})",
                    &ckpt.key[..8.min(ckpt.key.len())],
                    ckpt.rounds,
                    &key[..8.min(key.len())],
                    report.rounds_completed()
                );
            }
            self.put_checkpoint(ckpt)?;
        }
        let doc = Json::obj(vec![
            ("schema", Json::from(CELL_SCHEMA)),
            ("key", Json::from(key)),
            ("engine", Json::from(ENGINE_VERSION)),
            ("cell", Json::from(outcome.cell)),
            ("campaign", Json::from(outcome.campaign)),
            ("rounds", Json::from(report.rounds_completed() as f64)),
            ("checkpoint", Json::from(outcome.checkpoint.is_some())),
            ("config", outcome.job.canonical_json()),
            ("report", report.to_json()),
        ]);
        let shard = self.shard(key);
        std::fs::create_dir_all(&shard)
            .with_context(|| format!("creating store shard {shard:?}"))?;
        // Per-process temp name: two *processes* sharing a store and racing
        // on the same key must not interleave writes into one temp file
        // (within a process, grid dedup guarantees distinct keys).
        let tmp = shard.join(format!(".{key}.{}.tmp", std::process::id()));
        let path = self.path_of(key);
        std::fs::write(&tmp, format!("{doc}\n"))
            .with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing {path:?}"))?;
        if !report.stopped_early {
            self.remove_checkpoint(key);
        }
        self.clear_failure(key);
        Ok(true)
    }

    /// Persist a checkpoint blob (atomic sidecar write). Normally called
    /// via [`ResultStore::commit`] with [`CellOutcome::checkpoint`].
    pub fn put_checkpoint(&self, ckpt: &Checkpoint) -> Result<()> {
        let shard = self.shard(&ckpt.key);
        std::fs::create_dir_all(&shard)
            .with_context(|| format!("creating store shard {shard:?}"))?;
        let tmp = shard.join(format!(".{}.{}.ckpt.tmp", ckpt.key, std::process::id()));
        let path = self.checkpoint_path(&ckpt.key);
        std::fs::write(&tmp, format!("{}\n", ckpt.to_json()))
            .with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing checkpoint {path:?}"))?;
        Ok(())
    }

    /// Load a cell's checkpoint blob. Missing, corrupt, stale-engine, or
    /// wrong-key blobs all read as a miss (the cell replays from scratch —
    /// slower, never wrong).
    pub fn get_checkpoint(&self, key: &str) -> Option<Checkpoint> {
        let src = std::fs::read_to_string(self.checkpoint_path(key)).ok()?;
        let doc = Json::parse(&src).ok()?;
        let ckpt = Checkpoint::from_json(&doc).ok()?;
        (ckpt.key == key).then_some(ckpt)
    }

    /// Best-effort removal (a complete entry makes the blob redundant).
    pub fn remove_checkpoint(&self, key: &str) {
        let _ = std::fs::remove_file(self.checkpoint_path(key));
    }

    fn failed_path(&self, key: &str) -> PathBuf {
        self.dir.join(FAILED_DIR).join(format!("{key}.json"))
    }

    /// Record that a cell execution failed. Workers consult these so one
    /// process's failure unblocks every process's rung barrier (instead of
    /// the survivors polling a cell that will never complete). Cleared by
    /// the next successful [`ResultStore::commit`] of the key; `campaign
    /// run` (non-worker) ignores markers and simply retries.
    pub fn record_failure(&self, key: &str, cell: &str, campaign: &str, error: &str) -> Result<()> {
        let dir = self.dir.join(FAILED_DIR);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating failure dir {dir:?}"))?;
        let doc = Json::obj(vec![
            ("schema", Json::from(FAILED_SCHEMA)),
            ("key", Json::from(key)),
            ("cell", Json::from(cell)),
            ("campaign", Json::from(campaign)),
            ("error", Json::from(error)),
        ]);
        let tmp = dir.join(format!(".{key}.{}.tmp", std::process::id()));
        std::fs::write(&tmp, format!("{doc}\n"))
            .with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, self.failed_path(key))
            .with_context(|| format!("recording failure for {key}"))?;
        Ok(())
    }

    /// The recorded failure for a key, if any.
    pub fn failure(&self, key: &str) -> Option<String> {
        let src = std::fs::read_to_string(self.failed_path(key)).ok()?;
        let doc = Json::parse(&src).ok()?;
        if doc.get("schema").and_then(Json::as_str) != Some(FAILED_SCHEMA) {
            return None;
        }
        doc.get("error").and_then(Json::as_str).map(str::to_string)
    }

    /// Best-effort removal of a failure marker.
    pub fn clear_failure(&self, key: &str) {
        let _ = std::fs::remove_file(self.failed_path(key));
    }

    /// Which campaign first computed the stored entry. `None` for misses,
    /// corrupt/stale entries, and entries predating the provenance field
    /// (which still serve as cache hits — provenance is informational).
    pub fn origin(&self, key: &str) -> Option<String> {
        let src = std::fs::read_to_string(self.path_of(key)).ok()?;
        let doc = Json::parse(&src).ok()?;
        if !schema_ok(doc.get("schema").and_then(Json::as_str)) {
            return None;
        }
        if doc.get("engine").and_then(Json::as_str) != Some(ENGINE_VERSION) {
            return None;
        }
        doc.get("campaign")
            .and_then(Json::as_str)
            .map(str::to_string)
    }

    /// Store-wide provenance census: origin campaign → number of loadable
    /// entries it first computed. Entries without the provenance field are
    /// counted under `"(unattributed)"`. Drives `campaign list`'s
    /// cross-campaign dedup summary.
    pub fn census(&self) -> std::collections::BTreeMap<String, usize> {
        let mut out = std::collections::BTreeMap::new();
        for (key, _, _) in self.entries() {
            if self.get_any(&key).is_none() {
                continue; // corrupt or stale-engine: not servable, not counted
            }
            let origin = self
                .origin(&key)
                .unwrap_or_else(|| "(unattributed)".to_string());
            *out.entry(origin).or_insert(0) += 1;
        }
        out
    }

    /// The two-hex-char shard directories (skips `leases/`, `failed/`, and
    /// any stray non-shard directory — their contents are not entries).
    fn shard_dirs(&self) -> Vec<PathBuf> {
        let mut out = Vec::new();
        let Ok(shards) = std::fs::read_dir(&self.dir) else { return out };
        for shard in shards.flatten() {
            let path = shard.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            if name.len() == 2 && name.chars().all(|c| c.is_ascii_hexdigit()) && path.is_dir() {
                out.push(path);
            }
        }
        out
    }

    /// Every entry in the store: `(key, path, mtime)`, unordered.
    /// Unparseable file names are skipped (they are not store entries).
    pub fn entries(&self) -> Vec<(String, PathBuf, SystemTime)> {
        let mut out = Vec::new();
        for shard in self.shard_dirs() {
            let Ok(files) = std::fs::read_dir(&shard) else { continue };
            for f in files.flatten() {
                let path = f.path();
                let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
                let Some(key) = name.strip_suffix(".json") else { continue };
                if key.len() != 64 || !key.chars().all(|c| c.is_ascii_hexdigit()) {
                    continue;
                }
                let mtime = f
                    .metadata()
                    .and_then(|m| m.modified())
                    .unwrap_or(UNIX_EPOCH);
                out.push((key.to_string(), path, mtime));
            }
        }
        out
    }

    /// Garbage-collect the store: evict unprotected entries older than
    /// `max_age` and/or beyond the `keep_last` newest, and sweep `.tmp`
    /// residue left by crashed/cancelled writers. Keys in `protect` — the
    /// cells of the campaign(s) being resumed — are **never** evicted
    /// (test-enforced), so a gc'd store still resumes them from cache.
    ///
    /// Worker coordination is honored (test-enforced): a key with a *live*
    /// lease (heartbeat younger than `opts.lease_expiry`) keeps its entry,
    /// its checkpoint blob, and its in-flight `.tmp` files regardless of
    /// age. Evicting an entry also drops its checkpoint; orphaned
    /// checkpoints (no entry, no live lease) and expired lease files are
    /// swept as debris.
    pub fn gc(&self, opts: &GcOptions, protect: &BTreeSet<String>) -> Result<GcStats> {
        let mut stats = GcStats::default();
        let now = SystemTime::now();
        let expiry = opts.lease_expiry.unwrap_or(LeaseConfig::default().expiry);
        let leased = lease::live(&self.dir, expiry);

        // Newest-first so `keep_last` keeps the most recent results.
        let mut entries = self.entries();
        entries.sort_by(|a, b| b.2.cmp(&a.2).then_with(|| a.0.cmp(&b.0)));

        let mut kept_unprotected = 0usize;
        let mut live_keys: BTreeSet<&str> = BTreeSet::new();
        for (key, path, mtime) in &entries {
            stats.scanned += 1;
            if protect.contains(key) || leased.contains_key(key) {
                stats.kept += 1;
                live_keys.insert(key);
                continue;
            }
            let too_old = match opts.max_age {
                Some(max) => now
                    .duration_since(*mtime)
                    .map(|age| age > max)
                    .unwrap_or(false),
                None => false,
            };
            let over_count = match opts.keep_last {
                Some(k) => kept_unprotected >= k,
                None => false,
            };
            if too_old || over_count {
                std::fs::remove_file(path)
                    .with_context(|| format!("evicting {path:?}"))?;
                stats.evicted += 1;
                let ckpt = self.checkpoint_path(key);
                if ckpt.exists() {
                    std::fs::remove_file(&ckpt)
                        .with_context(|| format!("evicting checkpoint {ckpt:?}"))?;
                    stats.ckpt_removed += 1;
                }
            } else {
                kept_unprotected += 1;
                stats.kept += 1;
                live_keys.insert(key);
            }
        }

        // Orphaned checkpoints: no entry and no live lease means nothing
        // will ever resume from the blob.
        for shard in self.shard_dirs() {
            let Ok(files) = std::fs::read_dir(&shard) else { continue };
            for f in files.flatten() {
                let path = f.path();
                let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
                let Some(key) = name.strip_suffix(".ckpt") else { continue };
                if key.len() != 64 || !key.chars().all(|c| c.is_ascii_hexdigit()) {
                    continue;
                }
                if live_keys.contains(key) || leased.contains_key(key) {
                    continue;
                }
                std::fs::remove_file(&path)
                    .with_context(|| format!("sweeping orphan checkpoint {path:?}"))?;
                stats.ckpt_removed += 1;
            }
        }

        // `.tmp` residue: a crash or hard cancel between write and rename
        // leaves these behind — but a *young* temp file may be a live
        // writer mid-commit, and a live-leased key's temp file *is* a live
        // writer's, so sweep only unleased residue past the age bound.
        let tmp_bound = opts.tmp_max_age.unwrap_or(Duration::from_secs(3600));
        if let Ok(shards) = std::fs::read_dir(&self.dir) {
            for shard in shards.flatten() {
                if !shard.path().is_dir() {
                    continue;
                }
                if let Ok(files) = std::fs::read_dir(shard.path()) {
                    for f in files.flatten() {
                        let path = f.path();
                        let is_tmp = path.extension().map(|e| e == "tmp").unwrap_or(false);
                        if !is_tmp {
                            continue;
                        }
                        // Temp names embed their key (`.{key}.{pid}...tmp`).
                        let embedded_key = path
                            .file_name()
                            .and_then(|n| n.to_str())
                            .map(|n| n.trim_start_matches('.'))
                            .filter(|n| n.len() >= 64)
                            .map(|n| &n[..64]);
                        if embedded_key.map(|k| leased.contains_key(k)).unwrap_or(false) {
                            continue;
                        }
                        let stale = f
                            .metadata()
                            .and_then(|m| m.modified())
                            .ok()
                            .and_then(|m| now.duration_since(m).ok())
                            .map(|age| age > tmp_bound)
                            .unwrap_or(false);
                        if stale {
                            std::fs::remove_file(&path)
                                .with_context(|| format!("sweeping {path:?}"))?;
                            stats.tmp_removed += 1;
                        }
                    }
                }
            }
        }

        // Expired lease files are debris too (a dead worker's lease that no
        // survivor ever needed to reclaim).
        let lease_dir = self.dir.join(lease::LEASE_DIR);
        if let Ok(files) = std::fs::read_dir(&lease_dir) {
            for f in files.flatten() {
                let path = f.path();
                let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
                let Some(key) = name.strip_suffix(".lease") else { continue };
                if leased.contains_key(key) {
                    continue;
                }
                let stale = f
                    .metadata()
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|m| now.duration_since(m).ok())
                    .map(|age| age > expiry)
                    .unwrap_or(false);
                if stale {
                    std::fs::remove_file(&path)
                        .with_context(|| format!("sweeping expired lease {path:?}"))?;
                    stats.leases_swept += 1;
                }
            }
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::lease::{Acquire, LeaseManager};
    use crate::metrics::report::RoundMetrics;

    fn tmp_store(tag: &str) -> (ResultStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "flsim_cache_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        (ResultStore::open(&dir).unwrap(), dir)
    }

    fn round(n: u64) -> RoundMetrics {
        RoundMetrics {
            round: n,
            test_accuracy: 0.2 + 0.1 * n as f64,
            test_loss: 1.5 - 0.1 * n as f64,
            wall_secs: 0.8,
            net_bytes: 1024,
            model_hash: format!("hash{n}"),
            ..Default::default()
        }
    }

    fn report_of(rounds: u64, stopped_early: bool) -> RunReport {
        RunReport {
            label: "cell_a".into(),
            strategy: "fedavg".into(),
            topology: "client_server".into(),
            backend: "cnn".into(),
            n_clients: 4,
            n_workers: 1,
            seed: 1,
            stopped_early,
            rounds: (1..=rounds).map(round).collect(),
        }
    }

    fn report() -> RunReport {
        report_of(1, false)
    }

    fn commit_simple(store: &ResultStore, key: &str, campaign: &str, job: &JobConfig, r: &RunReport) {
        store
            .commit(key, CellOutcome::new(job, r).cell("c").campaign(campaign))
            .unwrap();
    }

    #[test]
    fn commit_then_get_roundtrips() {
        let (store, dir) = tmp_store("roundtrip");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        assert!(!store.contains(&key));
        assert!(store.get(&key).is_none());
        commit_simple(&store, &key, "camp", &job, &report());
        assert!(store.contains(&key));
        let back = store.get(&key).unwrap();
        assert_eq!(back.to_json().to_string(), report().to_json().to_string());
        // Content-addressed layout: two-char shard prefix.
        assert!(store.path_of(&key).starts_with(dir.join(&key[..2])));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn v2_entries_still_read_as_hits() {
        let (store, dir) = tmp_store("v2compat");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        let doc = Json::obj(vec![
            ("schema", Json::from(CELL_SCHEMA_V2)),
            ("key", Json::from(key.as_str())),
            ("engine", Json::from(ENGINE_VERSION)),
            ("cell", Json::from("c")),
            ("campaign", Json::from("old")),
            ("config", job.canonical_json()),
            ("report", report().to_json()),
        ]);
        std::fs::create_dir_all(store.path_of(&key).parent().unwrap()).unwrap();
        std::fs::write(store.path_of(&key), format!("{doc}\n")).unwrap();
        assert!(store.contains(&key), "v2 entries must keep serving");
        assert_eq!(store.origin(&key).as_deref(), Some("old"));
        // ... and of course have no checkpoint.
        assert!(store.get_checkpoint(&key).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_entries_read_as_miss() {
        let (store, dir) = tmp_store("corrupt");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        std::fs::create_dir_all(store.path_of(&key).parent().unwrap()).unwrap();
        std::fs::write(store.path_of(&key), "not json at all").unwrap();
        assert!(store.get(&key).is_none());
        // A wrong-schema document is also a miss.
        std::fs::write(store.path_of(&key), "{\"schema\":\"other\"}").unwrap();
        assert!(store.get(&key).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn keys_are_hex_sha256() {
        let key = cell_key(&JobConfig::default_cnn("fedavg"));
        assert_eq!(key.len(), 64);
        assert!(key.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn partial_entries_hit_their_rung_but_not_full_lookups() {
        let (store, dir) = tmp_store("partial");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);

        commit_simple(&store, &key, "camp", &job, &report_of(2, true));
        // A rung-stopped prefix is not a complete run ...
        assert!(store.get(&key).is_none());
        assert!(!store.contains(&key));
        // ... but is a hit at (or below) its own depth.
        assert_eq!(store.get_at_least(&key, 2).unwrap().rounds_completed(), 2);
        assert!(store.get_at_least(&key, 1).is_some());
        assert!(store.get_at_least(&key, 3).is_none());

        // A complete entry satisfies every depth.
        commit_simple(&store, &key, "camp", &job, &report_of(3, false));
        assert!(store.get(&key).is_some());
        assert!(store.get_at_least(&key, 99).is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn partial_commits_only_deepen() {
        let (store, dir) = tmp_store("deepen");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        let commit = |r: &RunReport| {
            store
                .commit(&key, CellOutcome::new(&job, r).cell("c").campaign("camp"))
                .unwrap()
        };

        assert!(commit(&report_of(1, true)));
        // Same depth again: no write.
        assert!(!commit(&report_of(1, true)));
        // Deeper partial: upgrades.
        assert!(commit(&report_of(2, true)));
        assert_eq!(store.get_at_least(&key, 2).unwrap().rounds_completed(), 2);
        // Shallower partial: refused.
        assert!(!commit(&report_of(1, true)));
        assert_eq!(store.get_at_least(&key, 2).unwrap().rounds_completed(), 2);
        // A complete entry is never downgraded by any partial.
        assert!(commit(&report_of(3, false)));
        assert!(!commit(&report_of(2, true)));
        assert!(store.get(&key).is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoints_ride_partial_commits_and_complete_removes_them() {
        let (store, dir) = tmp_store("ckpt");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        let ckpt = Checkpoint::new(&key, 2, vec![0.5, -1.25, 3.0]);

        // Depth mismatch between blob and report is a programmer error.
        assert!(store
            .commit(
                &key,
                CellOutcome::new(&job, &report_of(1, true)).checkpoint(&ckpt)
            )
            .is_err());

        assert!(store
            .commit(
                &key,
                CellOutcome::new(&job, &report_of(2, true))
                    .cell("c")
                    .campaign("camp")
                    .checkpoint(&ckpt)
            )
            .unwrap());
        let back = store.get_checkpoint(&key).unwrap();
        assert_eq!(back.rounds, 2);
        assert_eq!(back.params, vec![0.5, -1.25, 3.0]);

        // Completing the cell removes the now-redundant blob.
        commit_simple(&store, &key, "camp", &job, &report_of(3, false));
        assert!(store.get_checkpoint(&key).is_none());
        assert!(!store.checkpoint_path(&key).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn failure_markers_record_and_clear() {
        let (store, dir) = tmp_store("failures");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        assert!(store.failure(&key).is_none());
        store.record_failure(&key, "c", "camp", "boom").unwrap();
        assert_eq!(store.failure(&key).as_deref(), Some("boom"));
        // Failure markers are not entries (census/gc must not count them).
        assert!(store.entries().is_empty());
        // The next successful commit clears the marker.
        commit_simple(&store, &key, "camp", &job, &report());
        assert!(store.failure(&key).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_respects_protection_and_sweeps_tmp() {
        let (store, dir) = tmp_store("gc");
        let mut keys = Vec::new();
        for seed in 0..4u64 {
            let mut job = JobConfig::default_cnn("fedavg");
            job.seed = seed;
            let key = cell_key(&job);
            commit_simple(&store, &key, "camp", &job, &report());
            keys.push(key);
        }
        // Fake crash residue.
        let tmp = store.path_of(&keys[0]).with_file_name(".junk.123.tmp");
        std::fs::write(&tmp, "torn").unwrap();

        let protect: BTreeSet<String> = keys[..2].iter().cloned().collect();
        let opts = GcOptions {
            keep_last: Some(0),
            max_age: None,
            // Sweep even fresh residue in the test (production default is
            // an hour, sparing live writers mid-commit).
            tmp_max_age: Some(Duration::ZERO),
            lease_expiry: None,
        };
        let stats = store.gc(&opts, &protect).unwrap();
        assert_eq!(stats.scanned, 4);
        assert_eq!(stats.evicted, 2, "only unprotected entries evicted");
        assert_eq!(stats.kept, 2);
        assert_eq!(stats.tmp_removed, 1);
        assert!(store.contains(&keys[0]) && store.contains(&keys[1]));
        assert!(!store.contains(&keys[2]) && !store.contains(&keys[3]));
        assert!(!tmp.exists());

        // max_age = 0 evicts everything unprotected regardless of count.
        let opts = GcOptions {
            keep_last: None,
            max_age: Some(Duration::from_secs(0)),
            tmp_max_age: None,
            lease_expiry: None,
        };
        let stats = store.gc(&opts, &BTreeSet::new()).unwrap();
        assert_eq!(stats.evicted, 2);
        assert!(store.entries().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_protects_leased_cells_their_checkpoints_and_tmp_files() {
        let (store, dir) = tmp_store("gc_lease");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        let ckpt = Checkpoint::new(&key, 2, vec![1.0, 2.0]);
        store
            .commit(
                &key,
                CellOutcome::new(&job, &report_of(2, true))
                    .cell("c")
                    .campaign("camp")
                    .checkpoint(&ckpt),
            )
            .unwrap();
        // An in-flight writer's temp file for the leased key.
        let tmp = store
            .path_of(&key)
            .with_file_name(format!(".{key}.999.tmp"));
        std::fs::write(&tmp, "in flight").unwrap();

        let mgr = LeaseManager::open(store.dir(), "w1", LeaseConfig::default()).unwrap();
        let lease = match mgr.try_acquire(&key).unwrap() {
            Acquire::Acquired(l) => l,
            _ => panic!("fresh key must acquire"),
        };

        // The most aggressive policy possible: evict everything, sweep all
        // residue. The live-leased cell must survive untouched.
        let opts = GcOptions {
            max_age: Some(Duration::ZERO),
            keep_last: Some(0),
            tmp_max_age: Some(Duration::ZERO),
            lease_expiry: None, // default expiry: the lease is live
        };
        let stats = store.gc(&opts, &BTreeSet::new()).unwrap();
        assert_eq!(stats.evicted, 0, "leased entry must not be evicted");
        assert_eq!(stats.ckpt_removed, 0, "leased checkpoint must survive");
        assert!(store.get_at_least(&key, 2).is_some());
        assert!(store.get_checkpoint(&key).is_some());
        assert!(tmp.exists(), "leased cell's tmp file must survive");

        // Released (dropped) lease + zero expiry: everything is collectable.
        drop(lease);
        let opts = GcOptions {
            lease_expiry: Some(Duration::ZERO),
            ..opts
        };
        std::thread::sleep(Duration::from_millis(20));
        let stats = store.gc(&opts, &BTreeSet::new()).unwrap();
        assert_eq!(stats.evicted, 1);
        assert_eq!(stats.ckpt_removed, 1);
        assert!(stats.tmp_removed >= 1);
        assert!(store.get_at_least(&key, 1).is_none());
        assert!(store.get_checkpoint(&key).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_sweeps_orphan_checkpoints_and_expired_leases() {
        let (store, dir) = tmp_store("gc_orphans");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        // A checkpoint with no entry (e.g. its entry was evicted by an old
        // flsim) and no lease: debris.
        store
            .put_checkpoint(&Checkpoint::new(&key, 1, vec![0.0]))
            .unwrap();
        // An expired lease file from a dead worker nobody reclaimed.
        let mgr = LeaseManager::open(
            store.dir(),
            "dead",
            LeaseConfig {
                heartbeat: Duration::from_millis(5),
                expiry: Duration::from_millis(10),
            },
        )
        .unwrap();
        let l = match mgr.try_acquire(&key).unwrap() {
            Acquire::Acquired(l) => l,
            _ => panic!(),
        };
        std::mem::forget(l); // "crash"
        std::thread::sleep(Duration::from_millis(40));

        let opts = GcOptions {
            max_age: Some(Duration::ZERO),
            lease_expiry: Some(Duration::from_millis(10)),
            ..GcOptions::default()
        };
        let stats = store.gc(&opts, &BTreeSet::new()).unwrap();
        assert_eq!(stats.ckpt_removed, 1);
        assert_eq!(stats.leases_swept, 1);
        assert!(store.get_checkpoint(&key).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn origin_and_census_track_provenance() {
        let (store, dir) = tmp_store("provenance");
        let mut keys = Vec::new();
        for (seed, campaign) in [(1u64, "alpha"), (2, "alpha"), (3, "beta")] {
            let mut job = JobConfig::default_cnn("fedavg");
            job.seed = seed;
            let key = cell_key(&job);
            commit_simple(&store, &key, campaign, &job, &report());
            keys.push(key);
        }
        assert_eq!(store.origin(&keys[0]).as_deref(), Some("alpha"));
        assert_eq!(store.origin(&keys[2]).as_deref(), Some("beta"));
        assert_eq!(store.origin("ff".repeat(32).as_str()), None);

        // An entry predating the provenance field still serves but reads
        // unattributed.
        let mut job = JobConfig::default_cnn("fedavg");
        job.seed = 4;
        let legacy_key = cell_key(&job);
        let doc = Json::obj(vec![
            ("schema", Json::from(CELL_SCHEMA)),
            ("key", Json::from(legacy_key.as_str())),
            ("engine", Json::from(ENGINE_VERSION)),
            ("cell", Json::from("c")),
            ("config", job.canonical_json()),
            ("report", report().to_json()),
        ]);
        std::fs::create_dir_all(store.path_of(&legacy_key).parent().unwrap()).unwrap();
        std::fs::write(store.path_of(&legacy_key), format!("{doc}\n")).unwrap();
        assert!(store.contains(&legacy_key));
        assert_eq!(store.origin(&legacy_key), None);

        let census = store.census();
        assert_eq!(census.get("alpha"), Some(&2));
        assert_eq!(census.get("beta"), Some(&1));
        assert_eq!(census.get("(unattributed)"), Some(&1));
        assert_eq!(census.values().sum::<usize>(), 4);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn entries_lists_keys_with_mtimes() {
        let (store, dir) = tmp_store("entries");
        assert!(store.entries().is_empty());
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        commit_simple(&store, &key, "camp", &job, &report());
        // A stray non-entry file is ignored, and so are the coordination
        // directories (leases/failed hold key-named files that are *not*
        // entries).
        std::fs::write(dir.join("README"), "not an entry").unwrap();
        store.record_failure(&key, "c", "camp", "x").unwrap();
        let entries = store.entries();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].0, key);
        assert!(entries[0].2 > UNIX_EPOCH);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
