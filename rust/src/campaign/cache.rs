//! Content-addressed on-disk result store.
//!
//! Every completed cell is written to `<store>/<k[0..2]>/<key>.json`, where
//! `key = SHA-256(ENGINE_VERSION ‖ canonical JobConfig JSON)`. Because the
//! key covers every result-relevant config field (and the engine version)
//! but *not* wall-clock knobs like `parallelism`, re-running a campaign
//! resumes instantly: unchanged cells are cache hits at any schedule, and
//! spec edits re-run exactly the cells they touch.
//!
//! A stored cell carries the full [`RunReport`] (including first-run wall
//! times), so a resumed campaign reproduces its report **byte-identically**
//! — enforced by `rust/tests/campaign.rs`.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::job::JobConfig;
use crate::metrics::report::RunReport;
use crate::util::hash;
use crate::util::json::Json;

/// Bumped whenever the engine's numeric contract changes (a new reduction
/// semantics, a retrained reference backend, ...) so stale cells re-run
/// instead of being served from cache.
pub const ENGINE_VERSION: &str = concat!("flsim-", env!("CARGO_PKG_VERSION"), "+engine.v3");

/// Schema tag of one stored cell document.
const CELL_SCHEMA: &str = "flsim-cell-v1";

/// The content-addressed key of a resolved job config.
pub fn cell_key(job: &JobConfig) -> String {
    let doc = format!("{}\n{}", ENGINE_VERSION, job.canonical_json());
    hash::sha256_hex(doc.as_bytes())
}

/// An on-disk result store rooted at one directory.
pub struct ResultStore {
    dir: PathBuf,
}

impl ResultStore {
    pub fn open(dir: impl AsRef<Path>) -> Result<ResultStore> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating result store {dir:?}"))?;
        Ok(ResultStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn shard(&self, key: &str) -> PathBuf {
        self.dir.join(&key[..2])
    }

    /// Where a cell with this key lives (whether or not it exists yet).
    pub fn path_of(&self, key: &str) -> PathBuf {
        self.shard(key).join(format!("{key}.json"))
    }

    /// Whether a *loadable* entry exists — delegates to [`ResultStore::get`]
    /// so `campaign list`'s cached/pending column agrees with what `run`
    /// will actually do (a corrupt or stale-schema file is not "cached").
    pub fn contains(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    /// Load a cached cell report. Missing, corrupt, or stale-schema entries
    /// all read as a miss (the cell simply re-runs and overwrites).
    pub fn get(&self, key: &str) -> Option<RunReport> {
        let src = std::fs::read_to_string(self.path_of(key)).ok()?;
        let doc = Json::parse(&src).ok()?;
        if doc.get("schema").and_then(Json::as_str) != Some(CELL_SCHEMA) {
            return None;
        }
        if doc.get("engine").and_then(Json::as_str) != Some(ENGINE_VERSION) {
            return None;
        }
        RunReport::from_json(doc.get("report")?).ok()
    }

    /// Persist one completed cell (atomic: temp file + rename, so a
    /// concurrent or crashed campaign never leaves a half-written entry).
    pub fn put(&self, key: &str, cell: &str, job: &JobConfig, report: &RunReport) -> Result<()> {
        let doc = Json::obj(vec![
            ("schema", Json::from(CELL_SCHEMA)),
            ("key", Json::from(key)),
            ("engine", Json::from(ENGINE_VERSION)),
            ("cell", Json::from(cell)),
            ("config", job.canonical_json()),
            ("report", report.to_json()),
        ]);
        let shard = self.shard(key);
        std::fs::create_dir_all(&shard)
            .with_context(|| format!("creating store shard {shard:?}"))?;
        // Per-process temp name: two *processes* sharing a store and racing
        // on the same key must not interleave writes into one temp file
        // (within a process, grid dedup guarantees distinct keys).
        let tmp = shard.join(format!(".{key}.{}.tmp", std::process::id()));
        let path = self.path_of(key);
        std::fs::write(&tmp, format!("{doc}\n"))
            .with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing {path:?}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::report::RoundMetrics;

    fn tmp_store(tag: &str) -> (ResultStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "flsim_cache_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        (ResultStore::open(&dir).unwrap(), dir)
    }

    fn report() -> RunReport {
        RunReport {
            label: "cell_a".into(),
            strategy: "fedavg".into(),
            topology: "client_server".into(),
            backend: "cnn".into(),
            n_clients: 4,
            n_workers: 1,
            seed: 1,
            rounds: vec![RoundMetrics {
                round: 1,
                test_accuracy: 0.42,
                test_loss: 1.3,
                wall_secs: 0.8,
                net_bytes: 1024,
                model_hash: "abc123".into(),
                ..Default::default()
            }],
        }
    }

    #[test]
    fn put_then_get_roundtrips() {
        let (store, dir) = tmp_store("roundtrip");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        assert!(!store.contains(&key));
        assert!(store.get(&key).is_none());
        store.put(&key, "cell_a", &job, &report()).unwrap();
        assert!(store.contains(&key));
        let back = store.get(&key).unwrap();
        assert_eq!(back.to_json().to_string(), report().to_json().to_string());
        // Content-addressed layout: two-char shard prefix.
        assert!(store.path_of(&key).starts_with(dir.join(&key[..2])));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_entries_read_as_miss() {
        let (store, dir) = tmp_store("corrupt");
        let job = JobConfig::default_cnn("fedavg");
        let key = cell_key(&job);
        std::fs::create_dir_all(store.path_of(&key).parent().unwrap()).unwrap();
        std::fs::write(store.path_of(&key), "not json at all").unwrap();
        assert!(store.get(&key).is_none());
        // A wrong-schema document is also a miss.
        std::fs::write(store.path_of(&key), "{\"schema\":\"other\"}").unwrap();
        assert!(store.get(&key).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn keys_are_hex_sha256() {
        let key = cell_key(&JobConfig::default_cnn("fedavg"));
        assert_eq!(key.len(), 64);
        assert!(key.chars().all(|c| c.is_ascii_hexdigit()));
    }
}
