//! SIMD-friendly blocked elementwise kernels for the aggregation hot path.
//!
//! Every kernel here is a *map* over the element axis: output element `j`
//! depends only on the inputs at index `j`, and the per-element operation
//! sequence is exactly the scalar loop it replaced. Restructuring the loop
//! into fixed-width [`LANES`]-element blocks (with a scalar tail) therefore
//! cannot change a single bit of the result — the same IEEE-754 ops run on
//! the same operands in the same per-element order; only the *iteration
//! grouping* changes, which is what lets LLVM's auto-vectorizer emit one
//! f32x8-style SIMD op per block instead of eight scalar ops.
//!
//! The reduction *across models* — the bit-exactness contract behind
//! `ReductionOrder` (the paper's Tables 1–2 hardware profiles) — lives
//! entirely in `mean.rs`'s call order. These kernels never reduce across the
//! element axis, so they are safe under every profile, including Kahan
//! (Rust never contracts `a * b + c` into an FMA or reassociates floats, so
//! the compensation algebra survives verbatim in each lane).
//!
//! `chunks_exact(LANES)` is the whole trick: the compiler sees a
//! constant-length body with no bounds checks and no cross-iteration
//! dependence, which is the exact shape the SLP/loop vectorizers look for.
//! Bitwise equality against the scalar forms is pinned by the property
//! tests in `tests/agg_kernels.rs` at tail dims (`dim % LANES != 0`).

/// Fixed SIMD block width: 8 × f32 = one AVX2 register (two NEON
/// registers) — the widest unit every tier-1 target auto-vectorizes.
pub const LANES: usize = 8;

/// `out[j] += a * x[j]` — the weighted-accumulate at the core of
/// `Sequential` / `Reversed` aggregation and `StreamingMean::push`.
#[inline]
pub fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len() - out.len() % LANES;
    for (o, v) in out[..n].chunks_exact_mut(LANES).zip(x[..n].chunks_exact(LANES)) {
        for j in 0..LANES {
            o[j] += a * v[j];
        }
    }
    for (o, &v) in out[n..].iter_mut().zip(&x[n..]) {
        *o += a * v;
    }
}

/// `out[j] = a * x[j]` — the weighted leaf of the pairwise tree (both the
/// top-down recursion and the streaming binary counter).
#[inline]
pub fn scale(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len() - out.len() % LANES;
    for (o, v) in out[..n].chunks_exact_mut(LANES).zip(x[..n].chunks_exact(LANES)) {
        for j in 0..LANES {
            o[j] = a * v[j];
        }
    }
    for (o, &v) in out[n..].iter_mut().zip(&x[n..]) {
        *o = a * v;
    }
}

/// `out[j] += x[j]` — the pairwise-tree merge (recursive and carry-style).
#[inline]
pub fn add_assign(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len() - out.len() % LANES;
    for (o, v) in out[..n].chunks_exact_mut(LANES).zip(x[..n].chunks_exact(LANES)) {
        for j in 0..LANES {
            o[j] += v[j];
        }
    }
    for (o, &v) in out[n..].iter_mut().zip(&x[n..]) {
        *o += v;
    }
}

/// One blocked Kahan-compensated accumulate:
/// `y = a·x[j] − comp[j]; t = acc[j] + y; comp[j] = (t − acc[j]) − y;
/// acc[j] = t` — the exact scalar compensation algebra, per lane.
#[inline]
pub fn kahan_axpy(acc: &mut [f32], comp: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    debug_assert_eq!(acc.len(), comp.len());
    let n = acc.len() - acc.len() % LANES;
    for ((ac, cc), xc) in acc[..n]
        .chunks_exact_mut(LANES)
        .zip(comp[..n].chunks_exact_mut(LANES))
        .zip(x[..n].chunks_exact(LANES))
    {
        for j in 0..LANES {
            let y = a * xc[j] - cc[j];
            let t = ac[j] + y;
            cc[j] = (t - ac[j]) - y;
            ac[j] = t;
        }
    }
    for j in n..acc.len() {
        let y = a * x[j] - comp[j];
        let t = acc[j] + y;
        comp[j] = (t - acc[j]) - y;
        acc[j] = t;
    }
}

/// `out[j] = w[j] − a·g[j]` — the SGD weight update of the reference
/// engine's train steps (per batch, per client, per round on the fallback
/// backend).
#[inline]
pub fn sub_scaled_into(out: &mut [f32], w: &[f32], a: f32, g: &[f32]) {
    debug_assert_eq!(out.len(), w.len());
    debug_assert_eq!(out.len(), g.len());
    let n = out.len() - out.len() % LANES;
    for ((o, wc), gc) in out[..n]
        .chunks_exact_mut(LANES)
        .zip(w[..n].chunks_exact(LANES))
        .zip(g[..n].chunks_exact(LANES))
    {
        for j in 0..LANES {
            o[j] = wc[j] - a * gc[j];
        }
    }
    for j in n..out.len() {
        out[j] = w[j] - a * g[j];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vals(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::seed_from(seed);
        (0..n).map(|_| rng.normal_f32() * 3.0).collect()
    }

    /// Every kernel vs its scalar form, at dims exercising empty, sub-block,
    /// exact-block and tail shapes. `assert_eq!` on f32 slices is bitwise
    /// (no NaNs generated here), which is the whole contract.
    #[test]
    fn blocked_kernels_match_scalar_forms_bitwise() {
        for dim in [0usize, 1, 3, 7, 8, 9, 16, 23, 64, 127, 1000] {
            let x = vals(dim as u64 + 1, dim);
            let base = vals(dim as u64 + 1000, dim);
            let a = 0.37f32;

            let mut blocked = base.clone();
            axpy(&mut blocked, a, &x);
            let mut scalar = base.clone();
            for (o, &v) in scalar.iter_mut().zip(&x) {
                *o += a * v;
            }
            assert_eq!(blocked, scalar, "axpy dim={dim}");

            let mut blocked = base.clone();
            scale(&mut blocked, a, &x);
            let scalar: Vec<f32> = x.iter().map(|&v| a * v).collect();
            assert_eq!(blocked, scalar, "scale dim={dim}");

            let mut blocked = base.clone();
            add_assign(&mut blocked, &x);
            let scalar: Vec<f32> = base.iter().zip(&x).map(|(&b, &v)| b + v).collect();
            assert_eq!(blocked, scalar, "add_assign dim={dim}");

            let mut acc_b = base.clone();
            let mut comp_b = vals(dim as u64 + 2000, dim);
            let mut acc_s = acc_b.clone();
            let mut comp_s = comp_b.clone();
            kahan_axpy(&mut acc_b, &mut comp_b, a, &x);
            for j in 0..dim {
                let y = a * x[j] - comp_s[j];
                let t = acc_s[j] + y;
                comp_s[j] = (t - acc_s[j]) - y;
                acc_s[j] = t;
            }
            assert_eq!(acc_b, acc_s, "kahan acc dim={dim}");
            assert_eq!(comp_b, comp_s, "kahan comp dim={dim}");

            let mut blocked = vec![0f32; dim];
            sub_scaled_into(&mut blocked, &base, a, &x);
            let scalar: Vec<f32> = base.iter().zip(&x).map(|(&w, &g)| w - a * g).collect();
            assert_eq!(blocked, scalar, "sub_scaled_into dim={dim}");
        }
    }
}
