//! Communication-efficient FL (paper §1's cited direction [15, 16]):
//! update compressors clients can apply before upload — top-k
//! sparsification and stochastic uniform quantization — with exact
//! on-the-wire byte accounting so the bandwidth figures reflect the
//! compression honestly.

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// A compressed model update (delta vs. the global model).
#[derive(Clone, Debug, PartialEq)]
pub enum CompressedUpdate {
    /// Dense f32 delta (no compression).
    Dense(Vec<f32>),
    /// Top-k sparsification: (index, value) pairs + original dim.
    TopK { dim: usize, entries: Vec<(u32, f32)> },
    /// Stochastic uniform quantization to `bits` bits with per-vector scale.
    Quantized {
        dim: usize,
        bits: u8,
        min: f32,
        max: f32,
        codes: Vec<u32>,
    },
}

impl CompressedUpdate {
    /// Bytes on the wire.
    pub fn wire_bytes(&self) -> u64 {
        64 + match self {
            CompressedUpdate::Dense(v) => (v.len() * 4) as u64,
            CompressedUpdate::TopK { entries, .. } => (entries.len() * 8) as u64 + 4,
            CompressedUpdate::Quantized { dim, bits, .. } => {
                (*dim as u64 * *bits as u64).div_ceil(8) + 12
            }
        }
    }

    /// Reconstruct the dense delta.
    pub fn decompress(&self) -> Vec<f32> {
        match self {
            CompressedUpdate::Dense(v) => v.clone(),
            CompressedUpdate::TopK { dim, entries } => {
                let mut out = vec![0f32; *dim];
                for &(i, v) in entries {
                    out[i as usize] = v;
                }
                out
            }
            CompressedUpdate::Quantized {
                dim,
                bits,
                min,
                max,
                codes,
            } => {
                let levels = (1u32 << bits) - 1;
                let span = (max - min).max(1e-12);
                (0..*dim)
                    .map(|i| min + (codes[i] as f32 / levels as f32) * span)
                    .collect()
            }
        }
    }
}

/// Keep only the `k` largest-magnitude coordinates of `delta`.
pub fn top_k(delta: &[f32], k: usize) -> CompressedUpdate {
    let k = k.min(delta.len());
    if k == 0 {
        // Empty delta (or k == 0): nothing survives selection. Bailing out
        // here also keeps `delta.len() - 1` below from underflowing.
        return CompressedUpdate::TopK {
            dim: delta.len(),
            entries: Vec::new(),
        };
    }
    let mut idx: Vec<u32> = (0..delta.len() as u32).collect();
    // Partial selection by magnitude, via `total_cmp` so the order stays
    // total (no `partial_cmp(..).unwrap()` abort) when an adversarial or
    // diverged client uploads NaN/±inf. NaN magnitudes rank strictly last
    // (mapped below every finite and infinite magnitude), so NaN
    // coordinates are only kept once every non-NaN coordinate is; ties
    // break on the lower index, making the selection fully deterministic.
    let magnitude = |i: u32| {
        let v = delta[i as usize];
        if v.is_nan() {
            f32::NEG_INFINITY
        } else {
            v.abs()
        }
    };
    let nth = (k - 1).min(delta.len() - 1);
    idx.select_nth_unstable_by(nth, |&a, &b| {
        magnitude(b).total_cmp(&magnitude(a)).then(a.cmp(&b))
    });
    let mut entries: Vec<(u32, f32)> =
        idx[..k].iter().map(|&i| (i, delta[i as usize])).collect();
    entries.sort_by_key(|&(i, _)| i);
    CompressedUpdate::TopK {
        dim: delta.len(),
        entries,
    }
}

/// Stochastic uniform quantization to `bits` ∈ [1, 16].
pub fn quantize(delta: &[f32], bits: u8, rng: &mut Rng) -> Result<CompressedUpdate> {
    if !(1..=16).contains(&bits) {
        bail!("quantize: bits {bits} out of [1, 16]");
    }
    if delta.is_empty() {
        // Without this guard the min/max folds below leak their identities
        // (`min = +inf, max = −inf`) into the struct. Canonical empty
        // encoding instead, mirroring `top_k`'s empty-delta guard.
        return Ok(CompressedUpdate::Quantized {
            dim: 0,
            bits,
            min: 0.0,
            max: 0.0,
            codes: Vec::new(),
        });
    }
    if let Some(pos) = delta.iter().position(|v| !v.is_finite()) {
        // NaN/±inf would poison min/max and turn every code into garbage.
        bail!(
            "quantize: non-finite value {} at index {pos}",
            delta[pos]
        );
    }
    let min = delta.iter().cloned().fold(f32::INFINITY, f32::min);
    let max = delta.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let levels = (1u32 << bits) - 1;
    let span = (max - min).max(1e-12);
    let codes = delta
        .iter()
        .map(|&v| {
            let t = ((v - min) / span) * levels as f32;
            let lo = t.floor();
            // Stochastic rounding: unbiased in expectation.
            let up = rng.next_f32() < (t - lo);
            (lo as u32 + up as u32).min(levels)
        })
        .collect();
    Ok(CompressedUpdate::Quantized {
        dim: delta.len(),
        bits,
        min,
        max,
        codes,
    })
}

/// Compression error ‖delta − decompress‖₂ (diagnostics/ablation).
pub fn compression_error(delta: &[f32], c: &CompressedUpdate) -> f64 {
    crate::util::stats::l2_dist(delta, &c.decompress())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn topk_keeps_largest_and_shrinks_wire() {
        let mut d = vec![0.001f32; 100];
        d[7] = -5.0;
        d[42] = 3.0;
        let c = top_k(&d, 2);
        let back = c.decompress();
        assert_eq!(back[7], -5.0);
        assert_eq!(back[42], 3.0);
        assert_eq!(back[0], 0.0);
        assert!(c.wire_bytes() < CompressedUpdate::Dense(d).wire_bytes());
    }

    #[test]
    fn topk_full_k_is_lossless() {
        let d = delta(100, 1);
        let c = top_k(&d, 100);
        assert_eq!(c.decompress(), d);
    }

    #[test]
    fn quantize_bounded_error_and_bytes() {
        let d = delta(1000, 2);
        let mut rng = Rng::seed_from(3);
        let c8 = quantize(&d, 8, &mut rng).unwrap();
        let c2 = quantize(&d, 2, &mut rng).unwrap();
        // More bits => lower error, more bytes.
        assert!(compression_error(&d, &c8) < compression_error(&d, &c2));
        assert!(c8.wire_bytes() > c2.wire_bytes());
        // 8-bit is 4x smaller than dense (modulo header).
        assert!(c8.wire_bytes() < 1000 * 4 / 3);
        // Reconstruction stays within the quantization cell.
        let span = d.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
            - d.iter().cloned().fold(f32::INFINITY, f32::min);
        let cell = span / 255.0;
        for (orig, rec) in d.iter().zip(c8.decompress()) {
            assert!((orig - rec).abs() <= cell * 1.001);
        }
    }

    #[test]
    fn quantize_is_unbiased_in_expectation() {
        let d = vec![0.5f32; 2000];
        // With min==max degenerate span, decompress returns min — use a
        // vector with spread instead.
        let mut d = d;
        d[0] = 0.0;
        d[1] = 1.0;
        let mut rng = Rng::seed_from(7);
        let c = quantize(&d, 1, &mut rng).unwrap();
        let rec = c.decompress();
        let mean_rec: f64 =
            rec[2..].iter().map(|&x| x as f64).sum::<f64>() / (rec.len() - 2) as f64;
        assert!((mean_rec - 0.5).abs() < 0.05, "biased: {mean_rec}");
    }

    #[test]
    fn bad_bits_rejected() {
        let mut rng = Rng::seed_from(0);
        assert!(quantize(&[1.0], 0, &mut rng).is_err());
        assert!(quantize(&[1.0], 17, &mut rng).is_err());
    }

    #[test]
    fn topk_empty_delta_and_zero_k_do_not_panic() {
        // Regression: `delta.len() - 1` underflowed on an empty delta.
        let c = top_k(&[], 5);
        assert_eq!(c.decompress(), Vec::<f32>::new());
        assert!(matches!(&c, CompressedUpdate::TopK { dim: 0, entries } if entries.is_empty()));
        let d = delta(10, 4);
        let c = top_k(&d, 0);
        assert_eq!(c.decompress(), vec![0f32; 10]);
        assert!(matches!(&c, CompressedUpdate::TopK { dim: 10, entries } if entries.is_empty()));
    }

    #[test]
    fn topk_non_finite_deltas_select_deterministically() {
        // Regression: the old `partial_cmp(..).unwrap()` comparator aborted
        // the whole run on the first NaN an adversarial client uploaded.
        let mut d = vec![0.001f32; 64];
        d[3] = f32::NAN;
        d[9] = f32::INFINITY;
        d[17] = f32::NEG_INFINITY;
        d[30] = -7.0;
        let c = top_k(&d, 3);
        let CompressedUpdate::TopK { dim, entries } = &c else {
            panic!("top_k must return TopK");
        };
        assert_eq!(*dim, 64);
        // ±inf outrank every finite magnitude; NaN ranks last and is never
        // selected while any non-NaN coordinate remains.
        let kept: Vec<u32> = entries.iter().map(|&(i, _)| i).collect();
        assert_eq!(kept, vec![9, 17, 30]);
        assert_eq!(top_k(&d, 3), c, "selection must be deterministic");
        // k large enough to exhaust non-NaN coordinates keeps the NaN too
        // (decompress reproduces it in place) — still no panic.
        let full = top_k(&d, 64);
        let back = full.decompress();
        assert!(back[3].is_nan());
        assert_eq!(back[9], f32::INFINITY);
        // All-NaN delta: the degenerate worst case, selection still total.
        let all_nan = vec![f32::NAN; 8];
        let c = top_k(&all_nan, 2);
        assert!(c.decompress().iter().take(2).all(|v| v.is_nan()));
    }

    #[test]
    fn quantize_empty_delta_round_trips_canonically() {
        // Regression: the min/max fold identities (`+inf` / `−inf`)
        // survived into the struct for an empty delta.
        let mut rng = Rng::seed_from(0);
        let c = quantize(&[], 8, &mut rng).unwrap();
        let CompressedUpdate::Quantized {
            dim,
            bits,
            min,
            max,
            codes,
        } = &c
        else {
            panic!("quantize must return Quantized");
        };
        assert_eq!((*dim, *bits), (0, 8));
        assert_eq!((*min, *max), (0.0, 0.0), "canonical empty encoding");
        assert!(codes.is_empty());
        assert_eq!(c.decompress(), Vec::<f32>::new());
        assert_eq!(c.wire_bytes(), 64 + 12);
    }

    #[test]
    fn quantize_rejects_non_finite_inputs() {
        let mut rng = Rng::seed_from(0);
        assert!(quantize(&[1.0, f32::NAN], 8, &mut rng).is_err());
        assert!(quantize(&[f32::INFINITY, 0.0], 8, &mut rng).is_err());
        assert!(quantize(&[f32::NEG_INFINITY], 8, &mut rng).is_err());
        quantize(&[1.0, -1.0], 8, &mut rng).unwrap();
    }

    #[test]
    fn deterministic_under_seed() {
        let d = delta(500, 9);
        let a = quantize(&d, 4, &mut Rng::seed_from(1)).unwrap();
        let b = quantize(&d, 4, &mut Rng::seed_from(1)).unwrap();
        assert_eq!(a.decompress(), b.decompress());
    }
}
