//! Adaptive federated (server-side) optimization — Reddi et al. [6], cited
//! by the paper as one of the FL directions FLsim must support: FedAdagrad,
//! FedAdam and FedYogi applied to the averaged client *pseudo-gradient*.

use anyhow::{bail, Result};

/// Which adaptive rule to run on the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerOptKind {
    Adagrad,
    Adam,
    Yogi,
}

impl ServerOptKind {
    pub fn parse(s: &str) -> Result<ServerOptKind> {
        Ok(match s {
            "adagrad" | "fedadagrad" => ServerOptKind::Adagrad,
            "adam" | "fedadam" => ServerOptKind::Adam,
            "yogi" | "fedyogi" => ServerOptKind::Yogi,
            _ => bail!("unknown server optimizer '{s}'"),
        })
    }

    /// Canonical config-file key (the inverse of [`ServerOptKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            ServerOptKind::Adagrad => "adagrad",
            ServerOptKind::Adam => "adam",
            ServerOptKind::Yogi => "yogi",
        }
    }
}

/// Server optimizer state (first/second moments over the parameter vector).
#[derive(Clone, Debug)]
pub struct ServerOpt {
    pub kind: ServerOptKind,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub tau: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    step: u64,
}

impl ServerOpt {
    pub fn new(kind: ServerOptKind, lr: f32) -> ServerOpt {
        ServerOpt {
            kind,
            lr,
            beta1: 0.9,
            beta2: 0.99,
            tau: 1e-3,
            m: Vec::new(),
            v: Vec::new(),
            step: 0,
        }
    }

    /// One server step: `delta = w_avg − w_global` is the pseudo-gradient
    /// direction; returns the new global parameters.
    pub fn apply(&mut self, global: &[f32], aggregated: &[f32]) -> Vec<f32> {
        let dim = global.len();
        assert_eq!(aggregated.len(), dim);
        if self.m.len() != dim {
            self.m = vec![0.0; dim];
            self.v = vec![self.tau * self.tau; dim];
        }
        self.step += 1;
        let mut out = Vec::with_capacity(dim);
        for i in 0..dim {
            let g = aggregated[i] - global[i]; // ascent direction
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = match self.kind {
                ServerOptKind::Adagrad => self.v[i] + g * g,
                ServerOptKind::Adam => self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g,
                ServerOptKind::Yogi => {
                    let sign = (g * g - self.v[i]).signum();
                    self.v[i] + (1.0 - self.beta2) * g * g * sign
                }
            };
            out.push(global[i] + self.lr * self.m[i] / (self.v[i].sqrt() + self.tau));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_steps(kind: ServerOptKind, n: usize) -> Vec<f32> {
        let mut opt = ServerOpt::new(kind, 0.1);
        let mut w = vec![0f32; 4];
        for _ in 0..n {
            // Clients consistently pull toward 1.0.
            let agg: Vec<f32> = w.iter().map(|&x| x + 0.1 * (1.0 - x)).collect();
            w = opt.apply(&w, &agg);
        }
        w
    }

    #[test]
    fn all_rules_move_toward_client_consensus() {
        for kind in [ServerOptKind::Adagrad, ServerOptKind::Adam, ServerOptKind::Yogi] {
            let w = run_steps(kind, 50);
            assert!(w[0] > 0.5, "{kind:?} stalled at {}", w[0]);
            assert!(w[0] < 1.5, "{kind:?} overshot to {}", w[0]);
        }
    }

    #[test]
    fn zero_delta_is_stationary() {
        let mut opt = ServerOpt::new(ServerOptKind::Adam, 0.1);
        let w = vec![0.3f32; 8];
        let w2 = opt.apply(&w, &w);
        for (a, b) in w.iter().zip(&w2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(ServerOptKind::parse("fedyogi").unwrap(), ServerOptKind::Yogi);
        assert_eq!(ServerOptKind::parse("adam").unwrap(), ServerOptKind::Adam);
        assert!(ServerOptKind::parse("sgd").is_err());
    }

    #[test]
    fn adagrad_accumulates_monotonically() {
        let mut opt = ServerOpt::new(ServerOptKind::Adagrad, 0.1);
        let w = vec![0f32; 2];
        let agg = vec![1f32; 2];
        let _ = opt.apply(&w, &agg);
        let v1 = opt.v[0];
        let _ = opt.apply(&w, &agg);
        assert!(opt.v[0] > v1);
    }
}
