//! Robust aggregators (extension features beyond plain FedAvg): coordinate
//! median and trimmed mean — useful baselines next to the consensus-based
//! poisoning defence of Fig 10.

use anyhow::{bail, Result};

/// Coordinate-wise median of client parameter vectors.
pub fn coordinate_median(params: &[&[f32]]) -> Result<Vec<f32>> {
    if params.is_empty() {
        bail!("median of zero models");
    }
    let dim = params[0].len();
    if params.iter().any(|p| p.len() != dim) {
        bail!("dimension mismatch");
    }
    let mut out = Vec::with_capacity(dim);
    let mut col = vec![0f32; params.len()];
    for j in 0..dim {
        for (i, p) in params.iter().enumerate() {
            col[i] = p[j];
        }
        col.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = col.len();
        out.push(if n % 2 == 1 {
            col[n / 2]
        } else {
            0.5 * (col[n / 2 - 1] + col[n / 2])
        });
    }
    Ok(out)
}

/// Coordinate-wise trimmed mean dropping `trim` extremes from each side.
pub fn trimmed_mean(params: &[&[f32]], trim: usize) -> Result<Vec<f32>> {
    if params.is_empty() {
        bail!("trimmed mean of zero models");
    }
    if params.len() <= 2 * trim {
        bail!("trim {trim} too large for {} models", params.len());
    }
    let dim = params[0].len();
    if params.iter().any(|p| p.len() != dim) {
        bail!("dimension mismatch");
    }
    let mut out = Vec::with_capacity(dim);
    let mut col = vec![0f32; params.len()];
    for j in 0..dim {
        for (i, p) in params.iter().enumerate() {
            col[i] = p[j];
        }
        col.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let kept = &col[trim..col.len() - trim];
        out.push(kept.iter().sum::<f32>() / kept.len() as f32);
    }
    Ok(out)
}

/// Krum (Blanchard et al.): select the single client model whose summed
/// distance to its n−f−2 nearest neighbours is smallest — a strong robust
/// baseline next to the consensus defence of Fig 10. Returns the index.
pub fn krum(params: &[&[f32]], n_byzantine: usize) -> Result<usize> {
    let n = params.len();
    if n == 0 {
        bail!("krum over zero models");
    }
    if n <= 2 * n_byzantine + 2 {
        bail!("krum needs n > 2f + 2 (n = {n}, f = {n_byzantine})");
    }
    let dim = params[0].len();
    if params.iter().any(|p| p.len() != dim) {
        bail!("dimension mismatch");
    }
    let k = n - n_byzantine - 2;
    let mut best = (f64::INFINITY, 0usize);
    for i in 0..n {
        let mut dists: Vec<f64> = (0..n)
            .filter(|&j| j != i)
            .map(|j| {
                let d = crate::util::stats::l2_dist(params[i], params[j]);
                d * d
            })
            .collect();
        dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let score: f64 = dists.iter().take(k).sum();
        if score < best.0 {
            best = (score, i);
        }
    }
    Ok(best.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn krum_picks_clustered_model() {
        let honest: Vec<Vec<f32>> = (0..5)
            .map(|i| vec![1.0 + 0.01 * i as f32; 8])
            .collect();
        let mut all: Vec<Vec<f32>> = honest.clone();
        all.push(vec![50.0; 8]); // byzantine
        let refs: Vec<&[f32]> = all.iter().map(|v| v.as_slice()).collect();
        let idx = krum(&refs, 1).unwrap();
        assert!(idx < 5, "krum picked the byzantine model");
    }

    #[test]
    fn krum_tie_breaks_on_earliest_index() {
        // Two identical clusters of equal score: strict `<` keeps the first
        // minimum, so the winner is the earliest index — deterministic no
        // matter how the updates were produced.
        let v = vec![1.0f32; 4];
        let refs: Vec<&[f32]> = vec![&v, &v, &v, &v, &v, &v];
        assert_eq!(krum(&refs, 1).unwrap(), 0);
        // And a permuted-but-equivalent layout still picks the earliest of
        // the tied minima.
        let far = vec![9.0f32; 4];
        let all: Vec<&[f32]> = vec![&far, &v, &v, &v, &v, &v];
        assert_eq!(krum(&all, 1).unwrap(), 1);
    }

    #[test]
    fn krum_requires_enough_models() {
        let a = vec![1.0f32];
        let refs: Vec<&[f32]> = vec![&a, &a, &a];
        assert!(krum(&refs, 1).is_err());
        assert!(krum(&[], 0).is_err());
    }

    #[test]
    fn median_ignores_outlier() {
        let honest1 = vec![1.0f32, 1.0];
        let honest2 = vec![1.1f32, 0.9];
        let poisoned = vec![100.0f32, -100.0];
        let m = coordinate_median(&[&honest1, &honest2, &poisoned]).unwrap();
        assert!(m[0] < 2.0 && m[1] > -2.0);
    }

    #[test]
    fn median_even_count_averages_middle() {
        let a = vec![0.0f32];
        let b = vec![1.0f32];
        let c = vec![2.0f32];
        let d = vec![3.0f32];
        let m = coordinate_median(&[&a, &b, &c, &d]).unwrap();
        assert!((m[0] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let vs: Vec<Vec<f32>> = vec![
            vec![-100.0],
            vec![1.0],
            vec![2.0],
            vec![3.0],
            vec![100.0],
        ];
        let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
        let m = trimmed_mean(&refs, 1).unwrap();
        assert!((m[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn error_cases() {
        assert!(coordinate_median(&[]).is_err());
        let a = vec![1.0f32];
        assert!(trimmed_mean(&[&a], 1).is_err());
        let b = vec![1.0f32, 2.0];
        assert!(coordinate_median(&[&a, &b]).is_err());
    }
}
