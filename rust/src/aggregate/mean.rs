//! Weighted parameter averaging — the FedAvg core — with a configurable
//! floating-point reduction order.
//!
//! The reduction order is FLsim's stand-in for the paper's four hardware
//! configurations (Tables 1-2): the paper attributes the small cross-hardware
//! metric drift to "variations in the floating-point arithmetic", and
//! summation order is exactly that mechanism. Each profile is deterministic,
//! so trials on the *same* profile reproduce bitwise (the tables' headline
//! property), while different profiles drift by ~1e-7 per element, compounding
//! over rounds to the sub-percent differences the paper reports.
//!
//! ## Execution model
//!
//! The aggregation hot path (up to 1000 client models × ~1e5 parameters per
//! round) is computed in fixed element chunks. Every output element depends
//! only on the model values at its own index, and each chunk is reduced with
//! the exact per-element operation order its `ReductionOrder` defines — so
//! chunks are embarrassingly parallel *without* changing a single bit of the
//! result. [`AggPlan::parallelism`] > 1 spreads chunks over a scoped thread
//! pool; `parallelism == 1` runs them inline. Both produce bitwise-identical
//! output (asserted by tests), which is what lets the orchestrator expose a
//! free `parallelism` knob while keeping the RQ6 reproducibility contract.
//!
//! The pairwise tree is reduced with a chunked recursion over `O(log n)`
//! bounded scratch buffers instead of the previous one-`Vec`-per-leaf
//! construction (which allocated `n_models × dim` floats per call).
//!
//! The inner element loops (`axpy`, the Kahan compensation, the pairwise
//! leaf/merge) are the SIMD-blocked kernels of [`super::kernel`]: fixed
//! 8-lane blocks plus a scalar tail. Blocking the *element* axis never
//! touches the per-element operation order, so each profile's bit pattern
//! is unchanged (pinned by the goldens below and `tests/agg_kernels.rs`).

use anyhow::{bail, Result};

use super::kernel::{add_assign, axpy, kahan_axpy, scale};

/// Floating-point reduction order = simulated hardware profile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReductionOrder {
    /// Plain left-to-right accumulation ("x86 Single CPU").
    Sequential,
    /// Pairwise tree reduction, as a parallel/distributed stack would
    /// produce ("x86 Dist CPU").
    PairwiseTree,
    /// Reversed client order ("x86 Single GPU" — different launch order).
    Reversed,
    /// Kahan-compensated summation ("aarch64 Single CPU" — different FMA
    /// contraction behaviour).
    Kahan,
}

impl ReductionOrder {
    pub const ALL: [ReductionOrder; 4] = [
        ReductionOrder::Sequential,
        ReductionOrder::PairwiseTree,
        ReductionOrder::Reversed,
        ReductionOrder::Kahan,
    ];

    pub fn profile_name(&self) -> &'static str {
        match self {
            ReductionOrder::Sequential => "x86 Single CPU",
            ReductionOrder::PairwiseTree => "x86 Dist CPU",
            ReductionOrder::Reversed => "x86 Single GPU",
            ReductionOrder::Kahan => "aarch64 Single CPU",
        }
    }

    pub fn parse(s: &str) -> Result<ReductionOrder> {
        Ok(match s {
            "sequential" => ReductionOrder::Sequential,
            "pairwise" | "pairwise_tree" => ReductionOrder::PairwiseTree,
            "reversed" => ReductionOrder::Reversed,
            "kahan" => ReductionOrder::Kahan,
            _ => bail!("unknown reduction order '{s}'"),
        })
    }

    /// Canonical config-file key (the inverse of [`ReductionOrder::parse`]);
    /// used by the campaign cache's canonical job serialization.
    pub fn key(&self) -> &'static str {
        match self {
            ReductionOrder::Sequential => "sequential",
            ReductionOrder::PairwiseTree => "pairwise",
            ReductionOrder::Reversed => "reversed",
            ReductionOrder::Kahan => "kahan",
        }
    }
}

/// How to execute an aggregation: which bit-exact reduction order (the
/// simulated hardware profile) and how many worker threads may cooperate.
/// Parallelism never changes the result — only the wall clock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AggPlan {
    pub order: ReductionOrder,
    pub parallelism: usize,
}

impl AggPlan {
    pub fn new(order: ReductionOrder, parallelism: usize) -> AggPlan {
        AggPlan {
            order,
            parallelism: parallelism.max(1),
        }
    }

    pub fn sequential(order: ReductionOrder) -> AggPlan {
        AggPlan::new(order, 1)
    }
}

impl From<ReductionOrder> for AggPlan {
    fn from(order: ReductionOrder) -> AggPlan {
        AggPlan::sequential(order)
    }
}

/// Element chunk each reduction task covers (also bounds scratch memory:
/// `O(log n_models × CHUNK)` floats per worker).
const CHUNK: usize = 4096;

/// Weighted mean of parameter vectors: `sum_i w_i * p_i / sum_i w_i`,
/// accumulated per the given reduction order (single-threaded).
pub fn weighted_mean(
    params: &[&[f32]],
    weights: &[f64],
    order: ReductionOrder,
) -> Result<Vec<f32>> {
    weighted_mean_plan(params, weights, AggPlan::sequential(order))
}

/// [`weighted_mean`] under an execution plan; `plan.parallelism` block-
/// parallelizes over element chunks with bitwise-identical results.
pub fn weighted_mean_plan(
    params: &[&[f32]],
    weights: &[f64],
    plan: AggPlan,
) -> Result<Vec<f32>> {
    if params.is_empty() {
        bail!("weighted_mean of zero models");
    }
    if params.len() != weights.len() {
        bail!("{} models vs {} weights", params.len(), weights.len());
    }
    let dim = params[0].len();
    for (i, p) in params.iter().enumerate() {
        if p.len() != dim {
            bail!("model {i} has dim {} != {dim}", p.len());
        }
    }
    let wsum: f64 = weights.iter().sum();
    if wsum <= 0.0 {
        bail!("non-positive total weight {wsum}");
    }
    let norm: Vec<f32> = weights.iter().map(|&w| (w / wsum) as f32).collect();

    let mut out = vec![0f32; dim];
    let n_chunks = dim.div_ceil(CHUNK).max(1);
    // Spawning is only worth its cost when every worker gets several chunks
    // of real work; small vectors always reduce inline. Thread count never
    // affects the result, only the wall clock.
    const MIN_CHUNKS_PER_THREAD: usize = 4;
    let threads = plan
        .parallelism
        .max(1)
        .min(n_chunks / MIN_CHUNKS_PER_THREAD)
        .max(1);
    if threads <= 1 {
        let mut scratch = Vec::new();
        for (ci, chunk) in out.chunks_mut(CHUNK).enumerate() {
            fill_chunk(params, &norm, plan.order, ci * CHUNK, chunk, &mut scratch);
        }
    } else {
        let norm = &norm;
        std::thread::scope(|s| {
            let mut buckets: Vec<Vec<(usize, &mut [f32])>> =
                (0..threads).map(|_| Vec::new()).collect();
            for (ci, chunk) in out.chunks_mut(CHUNK).enumerate() {
                buckets[ci % threads].push((ci, chunk));
            }
            for bucket in buckets {
                s.spawn(move || {
                    let mut scratch = Vec::new();
                    for (ci, chunk) in bucket {
                        fill_chunk(params, norm, plan.order, ci * CHUNK, chunk, &mut scratch);
                    }
                });
            }
        });
    }
    Ok(out)
}

/// Reduce one element range `[lo, lo + out.len())` of the weighted sum into
/// `out`, using exactly the per-element operation order the profile defines.
fn fill_chunk(
    params: &[&[f32]],
    w: &[f32],
    order: ReductionOrder,
    lo: usize,
    out: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    let len = out.len();
    match order {
        ReductionOrder::Sequential => {
            out.fill(0.0);
            for (p, &wi) in params.iter().zip(w) {
                axpy(out, wi, &p[lo..lo + len]);
            }
        }
        ReductionOrder::Reversed => {
            out.fill(0.0);
            for i in (0..params.len()).rev() {
                axpy(out, w[i], &params[i][lo..lo + len]);
            }
        }
        ReductionOrder::Kahan => {
            out.fill(0.0);
            scratch.clear();
            scratch.resize(len, 0.0);
            for (p, &wi) in params.iter().zip(w) {
                kahan_axpy(out, scratch, wi, &p[lo..lo + len]);
            }
        }
        ReductionOrder::PairwiseTree => {
            // ceil(log2 n) recursion levels, one chunk-sized buffer each.
            let n = params.len();
            let depth = if n <= 1 {
                1
            } else {
                (usize::BITS - (n - 1).leading_zeros()) as usize
            };
            scratch.clear();
            scratch.resize(depth * len, 0.0);
            pairwise_into(params, w, 0, n, lo, out, scratch);
        }
    }
}

/// Adjacent-pair tree reduction of models `[mlo, mhi)` over one element
/// chunk. Splitting at the largest power of two strictly below `n`
/// reproduces, top-down, exactly the tree the old bottom-up level-by-level
/// pairing built — same association, same bits (golden-tested below).
fn pairwise_into(
    params: &[&[f32]],
    w: &[f32],
    mlo: usize,
    mhi: usize,
    lo: usize,
    out: &mut [f32],
    scratch: &mut [f32],
) {
    let n = mhi - mlo;
    let len = out.len();
    if n == 1 {
        scale(out, w[mlo], &params[mlo][lo..lo + len]);
        return;
    }
    let split = 1usize << (n - 1).ilog2();
    let (tmp, rest) = scratch.split_at_mut(len);
    pairwise_into(params, w, mlo, mlo + split, lo, out, rest);
    pairwise_into(params, w, mlo + split, mhi, lo, tmp, rest);
    add_assign(out, tmp);
}

/// Online weighted-mean accumulator: folds one client model at a time in
/// O(model) server memory while reproducing [`weighted_mean_plan`]'s output
/// **bit for bit** for the same reduction order and arrival order (at any
/// `parallelism` — the block-parallel plan is per-element identical to the
/// serial one).
///
/// Normalized weights are a function of the *total* weight, so the
/// accumulator needs `total_weight` up front: the left-to-right `f64` sum of
/// the weights that will be pushed, in push order (the exact sum
/// `weighted_mean_plan` computes). [`StreamingMean::finish`] cross-checks it
/// against the weights actually seen.
///
/// Per-order state:
/// * `Sequential` / `Kahan` — one running sum (plus one compensation vector
///   for Kahan): O(dim).
/// * `PairwiseTree` — a binary-counter stack of partial sums, one buffer per
///   set bit of the model count: O(dim × log n). Merging carry-style (older
///   partial on the left) reproduces exactly the split-at-largest-power-of-2
///   tree [`pairwise_into`] builds top-down (golden-tested below).
/// * `Reversed` — inherently non-streamable (the *last* arrival folds
///   first); the models are collected and reduced at `finish`, documented as
///   the O(cohort) fallback.
pub struct StreamingMean {
    order: ReductionOrder,
    dim: usize,
    total_weight: f64,
    seen_weight: f64,
    count: usize,
    /// Running sum (`Sequential` / `Kahan`).
    acc: Vec<f32>,
    /// Kahan compensation terms.
    comp: Vec<f32>,
    /// Binary-counter partial sums for `PairwiseTree`: `(level, partial)`
    /// where a level-`l` partial covers `2^l` consecutive models. Levels are
    /// strictly decreasing bottom-to-top.
    stack: Vec<(u32, Vec<f32>)>,
    /// Leaf buffers freed by carry merges, recycled by later pushes — the
    /// pairwise fold allocates O(log n) buffers total instead of one per
    /// model.
    free: Vec<Vec<f32>>,
    /// Collected `(model, weight)` pairs for the `Reversed` fallback.
    collected: Vec<(Vec<f32>, f64)>,
}

impl StreamingMean {
    pub fn new(dim: usize, total_weight: f64, order: ReductionOrder) -> Result<StreamingMean> {
        if dim == 0 {
            bail!("streaming mean of zero-dimensional models");
        }
        if !(total_weight > 0.0 && total_weight.is_finite()) {
            bail!("non-positive total weight {total_weight}");
        }
        Ok(StreamingMean {
            order,
            dim,
            total_weight,
            seen_weight: 0.0,
            count: 0,
            acc: match order {
                ReductionOrder::Sequential | ReductionOrder::Kahan => vec![0f32; dim],
                _ => Vec::new(),
            },
            comp: match order {
                ReductionOrder::Kahan => vec![0f32; dim],
                _ => Vec::new(),
            },
            stack: Vec::new(),
            free: Vec::new(),
            collected: Vec::new(),
        })
    }

    /// Models folded so far.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Fold one client model into the accumulator.
    pub fn push(&mut self, params: &[f32], weight: f64) -> Result<()> {
        if params.len() != self.dim {
            bail!("model {} has dim {} != {}", self.count, params.len(), self.dim);
        }
        let wi = (weight / self.total_weight) as f32;
        self.seen_weight += weight;
        self.count += 1;
        match self.order {
            ReductionOrder::Sequential => axpy(&mut self.acc, wi, params),
            ReductionOrder::Kahan => kahan_axpy(&mut self.acc, &mut self.comp, wi, params),
            ReductionOrder::PairwiseTree => {
                // Leaf: exactly `pairwise_into`'s n == 1 case (`wi * v`),
                // written into a recycled buffer when a merge freed one.
                let mut leaf = self
                    .free
                    .pop()
                    .unwrap_or_else(|| vec![0f32; self.dim]);
                scale(&mut leaf, wi, params);
                self.stack.push((0, leaf));
                // Carry: merge equal-level partials, older (left) + newer.
                while self.stack.len() >= 2
                    && self.stack[self.stack.len() - 1].0 == self.stack[self.stack.len() - 2].0
                {
                    let (_, newer) = self.stack.pop().unwrap();
                    let (level, older) = self.stack.last_mut().unwrap();
                    add_assign(older, &newer);
                    *level += 1;
                    self.free.push(newer);
                }
            }
            ReductionOrder::Reversed => self.collected.push((params.to_vec(), weight)),
        }
        Ok(())
    }

    /// Complete the reduction and return the weighted mean.
    pub fn finish(mut self) -> Result<Vec<f32>> {
        if self.count == 0 {
            bail!("weighted_mean of zero models");
        }
        if self.seen_weight.to_bits() != self.total_weight.to_bits() {
            bail!(
                "streaming mean saw total weight {} but was constructed for {}",
                self.seen_weight,
                self.total_weight
            );
        }
        match self.order {
            ReductionOrder::Sequential | ReductionOrder::Kahan => Ok(self.acc),
            ReductionOrder::PairwiseTree => {
                // Combine leftovers newest-to-oldest with the older (larger)
                // partial on the left — the order the top-down recursion
                // adds its right-hand suffixes.
                let (_, mut running) = self.stack.pop().expect("count > 0 implies partials");
                while let Some((_, mut older)) = self.stack.pop() {
                    add_assign(&mut older, &running);
                    running = older;
                }
                Ok(running)
            }
            ReductionOrder::Reversed => {
                let refs: Vec<&[f32]> = self.collected.iter().map(|(p, _)| p.as_slice()).collect();
                let weights: Vec<f64> = self.collected.iter().map(|(_, w)| *w).collect();
                weighted_mean_plan(&refs, &weights, AggPlan::sequential(ReductionOrder::Reversed))
            }
        }
    }
}

/// Server-side momentum (FedAvgM, Hsu et al. [2]):
/// `v <- beta * v + (w_global - w_avg)`, `w_global <- w_global - v`.
pub fn apply_server_momentum(
    global: &[f32],
    aggregated: &[f32],
    velocity: &mut Vec<f32>,
    beta: f32,
) -> Vec<f32> {
    assert_eq!(global.len(), aggregated.len());
    if velocity.len() != global.len() {
        *velocity = vec![0f32; global.len()];
    }
    let mut out = Vec::with_capacity(global.len());
    for i in 0..global.len() {
        let delta = global[i] - aggregated[i];
        velocity[i] = beta * velocity[i] + delta;
        out.push(global[i] - velocity[i]);
    }
    out
}

/// SCAFFOLD control-variate update (option II of Karimireddy et al. [5]):
/// `ci' = ci - c + (w_start - w_end) / (K * lr)`.
pub fn scaffold_cv_update(
    c_local: &[f32],
    c_global: &[f32],
    w_start: &[f32],
    w_end: &[f32],
    k_steps: usize,
    lr: f32,
) -> Vec<f32> {
    let scale = 1.0 / (k_steps.max(1) as f32 * lr);
    (0..c_local.len())
        .map(|i| c_local[i] - c_global[i] + (w_start[i] - w_end[i]) * scale)
        .collect()
}

/// The Gaussian mechanism on an aggregate (DP-FedAvg, Geyer et al. [7]):
/// per-coordinate noise with std `sigma·clip/n`, drawn from the round's
/// `"dp_noise"` stream. This is *the* shared noise step behind both the
/// legacy `dpfl` strategy and the `channel.dp` path — any change here moves
/// both in lockstep (their bitwise identity is pinned by test).
pub fn apply_dp_noise(
    agg: &mut [f32],
    clip: f64,
    sigma: f64,
    n_updates: usize,
    round_rng: &mut crate::util::rng::Rng,
) {
    let std = (sigma * clip / n_updates.max(1) as f64) as f32;
    let mut noise_rng = round_rng.derive("dp_noise", 0);
    for v in agg.iter_mut() {
        *v += std * noise_rng.normal_f32();
    }
}

/// DP-FedAvg (Geyer et al. [7]) server-side treatment of one client delta:
/// clip the update to `clip_norm`, then (the caller) adds Gaussian noise.
pub fn clip_update(global: &[f32], client: &[f32], clip_norm: f64) -> Vec<f32> {
    let delta: Vec<f32> = client
        .iter()
        .zip(global)
        .map(|(&c, &g)| c - g)
        .collect();
    let norm = crate::util::stats::l2_norm(&delta);
    let scale = if norm > clip_norm && norm > 0.0 {
        (clip_norm / norm) as f32
    } else {
        1.0
    };
    global
        .iter()
        .zip(&delta)
        .map(|(&g, &d)| g + d * scale)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx_eq(a: &[f32], b: &[f32], tol: f32) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol)
    }

    fn random_models(seed: u64, n: usize, dim: usize) -> (Vec<Vec<f32>>, Vec<f64>) {
        let mut rng = crate::util::rng::Rng::seed_from(seed);
        let params: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal_f32() * 3.0).collect())
            .collect();
        let weights: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        (params, weights)
    }

    /// The pre-refactor pairwise implementation (one Vec per leaf, bottom-up
    /// level pairing) — kept verbatim as the golden reference the new
    /// allocation-free recursion must match bit for bit.
    fn pairwise_golden(params: &[&[f32]], weights: &[f64]) -> Vec<f32> {
        let wsum: f64 = weights.iter().sum();
        let w: Vec<f32> = weights.iter().map(|&x| (x / wsum) as f32).collect();
        let dim = params[0].len();
        let mut level: Vec<Vec<f32>> = params
            .iter()
            .zip(&w)
            .map(|(p, &wi)| p.iter().map(|&v| wi * v).collect())
            .collect();
        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            let mut it = level.into_iter();
            while let Some(mut a) = it.next() {
                if let Some(b) = it.next() {
                    for (x, y) in a.iter_mut().zip(&b) {
                        *x += *y;
                    }
                }
                next.push(a);
            }
            level = next;
        }
        level.pop().unwrap_or_else(|| vec![0f32; dim])
    }

    #[test]
    fn equal_weights_is_mean() {
        let p1 = vec![1.0f32, 2.0];
        let p2 = vec![3.0f32, 6.0];
        for order in ReductionOrder::ALL {
            let m = weighted_mean(&[&p1, &p2], &[1.0, 1.0], order).unwrap();
            assert!(approx_eq(&m, &[2.0, 4.0], 1e-6), "{order:?}: {m:?}");
        }
    }

    #[test]
    fn weights_respected() {
        let p1 = vec![0.0f32];
        let p2 = vec![10.0f32];
        let m = weighted_mean(&[&p1, &p2], &[3.0, 1.0], ReductionOrder::Sequential).unwrap();
        assert!((m[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn orders_agree_within_fp_tolerance_but_can_differ_bitwise() {
        // Many uneven contributions to tickle rounding differences.
        let (params, weights) = random_models(5, 33, 101);
        let refs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        let base = weighted_mean(&refs, &weights, ReductionOrder::Sequential).unwrap();
        for order in [
            ReductionOrder::PairwiseTree,
            ReductionOrder::Reversed,
            ReductionOrder::Kahan,
        ] {
            let other = weighted_mean(&refs, &weights, order).unwrap();
            assert!(approx_eq(&base, &other, 1e-4));
        }
    }

    #[test]
    fn same_order_is_bitwise_reproducible() {
        let (params, _) = random_models(6, 9, 50);
        let refs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        let w = vec![1.0; 9];
        for order in ReductionOrder::ALL {
            let a = weighted_mean(&refs, &w, order).unwrap();
            let b = weighted_mean(&refs, &w, order).unwrap();
            assert_eq!(a, b, "{order:?} not deterministic");
        }
    }

    #[test]
    fn pairwise_matches_golden_per_leaf_implementation() {
        // Cover n around every power-of-two boundary and chunk boundaries.
        for n in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 33] {
            let (params, weights) = random_models(100 + n as u64, n, CHUNK + 37);
            let refs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
            let golden = pairwise_golden(&refs, &weights);
            let new = weighted_mean(&refs, &weights, ReductionOrder::PairwiseTree).unwrap();
            assert_eq!(new, golden, "pairwise tree shape changed at n={n}");
        }
    }

    #[test]
    fn parallel_plan_is_bitwise_equal_to_sequential_plan() {
        // Large enough that the worker pool actually engages (the spawn
        // threshold keeps small vectors inline).
        let (params, weights) = random_models(7, 13, 16 * CHUNK + 11);
        let refs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        for order in ReductionOrder::ALL {
            let seq = weighted_mean_plan(&refs, &weights, AggPlan::new(order, 1)).unwrap();
            for par in [2usize, 4, 8] {
                let p = weighted_mean_plan(&refs, &weights, AggPlan::new(order, par)).unwrap();
                assert_eq!(seq, p, "{order:?} diverges at parallelism {par}");
            }
        }
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let p1 = vec![1.0f32, 2.0];
        let p2 = vec![1.0f32];
        assert!(weighted_mean(&[&p1, &p2], &[1.0, 1.0], ReductionOrder::Sequential).is_err());
        assert!(weighted_mean(&[], &[], ReductionOrder::Sequential).is_err());
        assert!(weighted_mean(&[&p1], &[0.0], ReductionOrder::Sequential).is_err());
    }

    #[test]
    fn streaming_is_bitwise_equal_to_weighted_mean_plan() {
        // Every reduction order, model counts around power-of-two
        // boundaries, a dim spanning several chunks, and both the inline
        // and block-parallel plans: the streaming fold must reproduce the
        // collected reduction bit for bit.
        for n in [1usize, 2, 3, 5, 7, 8, 9, 13, 16, 17, 33] {
            let (params, weights) = random_models(900 + n as u64, n, 2 * CHUNK + 37);
            let refs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
            let total: f64 = weights.iter().sum();
            for order in ReductionOrder::ALL {
                let mut stream = StreamingMean::new(refs[0].len(), total, order).unwrap();
                for (p, &w) in refs.iter().zip(&weights) {
                    stream.push(p, w).unwrap();
                }
                let streamed = stream.finish().unwrap();
                for par in [1usize, 4] {
                    let plan = AggPlan::new(order, par);
                    let collected = weighted_mean_plan(&refs, &weights, plan).unwrap();
                    assert_eq!(
                        streamed, collected,
                        "{order:?} streaming diverges at n={n} parallelism={par}"
                    );
                }
            }
        }
    }

    #[test]
    fn streaming_pairwise_stack_is_logarithmic() {
        // The binary-counter stack holds one partial per set bit of the
        // model count — O(model × log cohort), never O(cohort × model).
        let dim = 64;
        let mut stream = StreamingMean::new(dim, 1000.0, ReductionOrder::PairwiseTree).unwrap();
        let model = vec![1.0f32; dim];
        let mut peak = 0;
        for _ in 0..1000 {
            stream.push(&model, 1.0).unwrap();
            peak = peak.max(stream.stack.len());
        }
        assert!(peak <= 10, "stack grew to {peak} partials for 1000 models");
        assert_eq!(stream.stack.len(), 1000usize.count_ones() as usize);
        let out = stream.finish().unwrap();
        assert!(approx_eq(&out, &model, 1e-5));
    }

    #[test]
    fn streaming_validates_inputs() {
        assert!(StreamingMean::new(0, 1.0, ReductionOrder::Sequential).is_err());
        assert!(StreamingMean::new(4, 0.0, ReductionOrder::Sequential).is_err());
        assert!(StreamingMean::new(4, f64::NAN, ReductionOrder::Sequential).is_err());
        // Dim mismatch on push.
        let mut s = StreamingMean::new(2, 1.0, ReductionOrder::Sequential).unwrap();
        assert!(s.push(&[1.0, 2.0, 3.0], 1.0).is_err());
        // Zero models.
        let s = StreamingMean::new(2, 1.0, ReductionOrder::Sequential).unwrap();
        assert!(s.finish().is_err());
        // A total weight that disagrees with the pushed weights is a bug in
        // the caller's bookkeeping — caught at finish.
        let mut s = StreamingMean::new(1, 5.0, ReductionOrder::Sequential).unwrap();
        s.push(&[1.0], 1.0).unwrap();
        assert!(s.finish().is_err());
    }

    #[test]
    fn momentum_accelerates_along_consistent_direction() {
        let global = vec![1.0f32; 4];
        let aggregated = vec![0.9f32; 4]; // delta 0.1 each round
        let mut v = Vec::new();
        let g1 = apply_server_momentum(&global, &aggregated, &mut v, 0.9);
        assert!(approx_eq(&g1, &aggregated, 1e-6)); // first round: v = delta
        let g2 = apply_server_momentum(&g1, &aggregated, &mut v, 0.9);
        // Second round with repeated delta must overshoot plain averaging.
        assert!(g2[0] < aggregated[0]);
    }

    #[test]
    fn momentum_zero_beta_is_plain_average() {
        let global = vec![2.0f32; 3];
        let agg = vec![1.0f32; 3];
        let mut v = Vec::new();
        let g = apply_server_momentum(&global, &agg, &mut v, 0.0);
        assert!(approx_eq(&g, &agg, 1e-6));
    }

    #[test]
    fn scaffold_cv_formula() {
        let ci = vec![0.1f32; 2];
        let c = vec![0.05f32; 2];
        let w0 = vec![1.0f32; 2];
        let w1 = vec![0.8f32; 2];
        let out = scaffold_cv_update(&ci, &c, &w0, &w1, 10, 0.1);
        // 0.1 - 0.05 + 0.2/(10*0.1) = 0.05 + 0.2 = 0.25
        assert!(approx_eq(&out, &[0.25, 0.25], 1e-6));
    }

    #[test]
    fn clip_update_bounds_norm() {
        let global = vec![0.0f32; 3];
        let client = vec![3.0f32, 4.0, 0.0]; // delta norm 5
        let clipped = clip_update(&global, &client, 1.0);
        let norm = crate::util::stats::l2_norm(&clipped);
        assert!((norm - 1.0).abs() < 1e-5);
        // Within-budget updates pass through untouched.
        let small = vec![0.1f32, 0.0, 0.0];
        assert_eq!(clip_update(&global, &small, 1.0), small);
    }
}
