//! Agglomerative hierarchical clustering of client updates — the core of
//! Briggs et al. [26] (FL+HC): after a few warm-up rounds, cluster clients
//! by the similarity of their model updates and train one model per cluster.

use crate::util::stats;

/// Linkage for merging clusters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Linkage {
    Average,
    Single,
    Complete,
}

/// Agglomerative clustering of vectors until `n_clusters` remain or the
/// closest pair is farther than `max_dist` (whichever stops first).
/// Returns cluster id per input, ids compacted to 0..k.
pub fn agglomerative_clusters(
    vectors: &[Vec<f32>],
    n_clusters: usize,
    max_dist: f64,
    linkage: Linkage,
) -> Vec<usize> {
    let n = vectors.len();
    if n == 0 {
        return Vec::new();
    }
    let n_clusters = n_clusters.max(1);

    // Pairwise distance matrix (euclidean).
    let mut dist = vec![vec![0f64; n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let d = stats::l2_dist(&vectors[i], &vectors[j]);
            dist[i][j] = d;
            dist[j][i] = d;
        }
    }

    // members[c] = indices in cluster c (None = merged away).
    let mut members: Vec<Option<Vec<usize>>> = (0..n).map(|i| Some(vec![i])).collect();
    let mut active = n;

    while active > n_clusters {
        // Find closest active pair under the linkage.
        let mut best: Option<(f64, usize, usize)> = None;
        for a in 0..n {
            let Some(ma) = &members[a] else { continue };
            for b in (a + 1)..n {
                let Some(mb) = &members[b] else { continue };
                let d = linkage_dist(ma, mb, &dist, linkage);
                if best.map(|(bd, _, _)| d < bd).unwrap_or(true) {
                    best = Some((d, a, b));
                }
            }
        }
        let Some((d, a, b)) = best else { break };
        if d > max_dist {
            break;
        }
        let mb = members[b].take().unwrap();
        members[a].as_mut().unwrap().extend(mb);
        active -= 1;
    }

    // Compact ids.
    let mut out = vec![0usize; n];
    let mut next = 0usize;
    for m in members.iter().flatten() {
        for &i in m {
            out[i] = next;
        }
        next += 1;
    }
    out
}

fn linkage_dist(a: &[usize], b: &[usize], dist: &[Vec<f64>], linkage: Linkage) -> f64 {
    let mut acc: f64 = match linkage {
        Linkage::Single => f64::INFINITY,
        Linkage::Complete => f64::NEG_INFINITY,
        Linkage::Average => 0.0,
    };
    for &i in a {
        for &j in b {
            let d = dist[i][j];
            acc = match linkage {
                Linkage::Single => acc.min(d),
                Linkage::Complete => acc.max(d),
                Linkage::Average => acc + d,
            };
        }
    }
    if linkage == Linkage::Average {
        acc / (a.len() * b.len()) as f64
    } else {
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs() -> Vec<Vec<f32>> {
        // Two well-separated blobs of 3 vectors each.
        vec![
            vec![0.0, 0.0],
            vec![0.1, -0.1],
            vec![-0.1, 0.1],
            vec![10.0, 10.0],
            vec![10.1, 9.9],
            vec![9.9, 10.1],
        ]
    }

    #[test]
    fn separates_blobs() {
        for linkage in [Linkage::Average, Linkage::Single, Linkage::Complete] {
            let ids = agglomerative_clusters(&blobs(), 2, f64::INFINITY, linkage);
            assert_eq!(ids[0], ids[1]);
            assert_eq!(ids[1], ids[2]);
            assert_eq!(ids[3], ids[4]);
            assert_eq!(ids[4], ids[5]);
            assert_ne!(ids[0], ids[3], "{linkage:?}");
        }
    }

    #[test]
    fn max_dist_stops_merging() {
        // With a tiny distance threshold nothing merges.
        let ids = agglomerative_clusters(&blobs(), 1, 1e-9, Linkage::Average);
        let distinct: std::collections::BTreeSet<usize> = ids.iter().cloned().collect();
        assert_eq!(distinct.len(), 6);
    }

    #[test]
    fn k_one_merges_everything() {
        let ids = agglomerative_clusters(&blobs(), 1, f64::INFINITY, Linkage::Average);
        assert!(ids.iter().all(|&c| c == ids[0]));
    }

    #[test]
    fn empty_and_singleton() {
        assert!(agglomerative_clusters(&[], 2, 1.0, Linkage::Average).is_empty());
        let one = agglomerative_clusters(&[vec![1.0]], 2, 1.0, Linkage::Average);
        assert_eq!(one, vec![0]);
    }

    #[test]
    fn ids_are_compact() {
        let ids = agglomerative_clusters(&blobs(), 2, f64::INFINITY, Linkage::Average);
        let mx = *ids.iter().max().unwrap();
        let distinct: std::collections::BTreeSet<usize> = ids.iter().cloned().collect();
        assert_eq!(distinct.len(), mx + 1);
    }
}
