//! Aggregation algorithms over flat parameter vectors: weighted averaging
//! with controllable floating-point reduction order (the Tables 1-2
//! "hardware profile" mechanism), server momentum (FedAvgM), robust
//! aggregators, and the agglomerative clustering used by FL+HC.

pub mod cluster;
pub mod compress;
pub mod kernel;
pub mod mean;
pub mod robust;
pub mod server_opt;

pub use cluster::agglomerative_clusters;
pub use mean::{weighted_mean, weighted_mean_plan, AggPlan, ReductionOrder};
pub use robust::{coordinate_median, krum, trimmed_mean};
pub use server_opt::{ServerOpt, ServerOptKind};
