//! Job configuration (paper §2.2, Fig 2): the YAML schema users scaffold an
//! FL experiment from, plus programmatic presets for every paper experiment.

pub mod adversary;
pub mod channel;
pub mod job;

pub use adversary::{
    AdversaryConfig, AttackKind, ChurnConfig, FaultsConfig, RobustAggConfig, RobustAggKind,
};
pub use channel::{ChannelConfig, CompressConfig, CompressKind, DpConfig, SecureAggConfig};
pub use job::{ChainConfig, ConsensusConfig, JobConfig, TrainParams};
